//! Autoregressive generation over the AOT forward graph.
//!
//! Uses `forward_b1` with full-sequence recompute per emitted token (no KV
//! cache in the exported graph — fine at seq ≤ 256; the serving product of
//! this repo is scoring, generation is a demo/debug surface). Sampling is
//! greedy or temperature/top-k with the repo's seeded RNG.

use crate::data::{decode, encode, PAD};
use crate::eval::ParamLiterals;
use crate::runtime::{self, ArtifactSet, Runtime};
use crate::util::Rng;
use anyhow::{anyhow, Result};

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SampleCfg {
    /// 0.0 ⇒ greedy argmax.
    pub temperature: f32,
    /// 0 ⇒ no top-k truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.8,
            top_k: 8,
            seed: 0,
        }
    }
}

/// Generate `n_tokens` continuation tokens for a text prompt.
pub fn generate(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    prompt: &str,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Result<String> {
    let m = &arts.manifest;
    let exe = arts.executable(rt, "forward_b1")?;
    let mut rng = Rng::new(cfg.seed);
    let mut tokens = encode(prompt);
    if tokens.is_empty() {
        tokens.push(PAD as i32);
    }
    let start_len = tokens.len();

    for _ in 0..n_tokens {
        // Window: last seq_len tokens, right-padded.
        let ctx_start = tokens.len().saturating_sub(m.seq_len);
        let ctx = &tokens[ctx_start..];
        let pos = ctx.len() - 1; // logits index predicting the next token
        let mut row = ctx.to_vec();
        row.resize(m.seq_len, PAD as i32);

        let lit = runtime::i32_literal(&row, &[1, m.seq_len])?;
        let mut args: Vec<&xla::Literal> = vec![&lit];
        args.extend(params.literals.iter());
        let out = exe.run(&args)?;
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let slice = &logits[pos * m.vocab..(pos + 1) * m.vocab];
        let next = sample(slice, cfg, &mut rng);
        tokens.push(next as i32);
    }
    Ok(decode(&tokens[start_len..]))
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], cfg: &SampleCfg, rng: &mut Rng) -> usize {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k + temperature softmax in f64.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(cfg.top_k);
    }
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max) as f64;
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - max) / cfg.temperature as f64).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1f32, 5.0, -2.0, 4.9];
        let cfg = SampleCfg {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
        };
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0f32, 9.0, -100.0, -100.0];
        let cfg = SampleCfg {
            temperature: 1.0,
            top_k: 2,
            seed: 0,
        };
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = sample(&logits, &cfg, &mut rng);
            assert!(s < 2, "sampled outside top-k: {s}");
        }
    }

    #[test]
    fn temperature_spreads_distribution() {
        let logits = vec![2.0f32, 1.0, 0.0];
        let mut hot = std::collections::HashSet::new();
        let cfg = SampleCfg {
            temperature: 5.0,
            top_k: 0,
            seed: 0,
        };
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            hot.insert(sample(&logits, &cfg, &mut rng));
        }
        assert_eq!(hot.len(), 3, "high temperature should hit all tokens");
    }
}
