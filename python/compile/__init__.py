"""Build-time compile path: L1 kernels, L2 model, AOT lowering.

Never imported at runtime — the rust binary consumes only the emitted
artifacts (HLO text + manifest).
"""
