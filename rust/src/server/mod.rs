//! Elastic inference server: request queue → continuous batcher → worker
//! pool.
//!
//! The deployment story the paper motivates (§1): one device, one anchor
//! checkpoint, and the *numeric format chosen per request* based on current
//! load. The server owns a pool of [`ServerConfig::workers`] worker threads
//! sharing **one** [`ElasticEngine`] — and therefore one weight
//! `FormatCache` — via `Arc` (the [`crate::backend::Backend`] trait is
//! `Send + Sync`); clients submit requests over a channel. Two request
//! lanes share the queue:
//!
//! * [`ScoreRequest`] — NLL scoring of a token window; each worker gathers
//!   up to `train_batch` requests inside a gather window and executes them
//!   as per-format sub-batches, one execution each.
//! * [`GenerateRequest`] — sampled continuations. Under the default
//!   [`GenBatching::Continuous`] mode each worker keeps **one persistent
//!   in-flight decode** ([`crate::backend::DecodeSession`]) and drains the
//!   queue *every decode step*: new prompts prefill into free rows while
//!   their neighbours keep decoding (prefill-on-join), every row carries
//!   its **own element format** — assigned per-row by the [`policy`] at
//!   admission — and its own token budget and sampling config, rows finish
//!   and respond independently, and freed rows are reused by the next
//!   join. Each row's tokens are identical to a solo
//!   [`crate::backend::Backend::generate`] call at that row's format.
//!   [`GenBatching::Gather`] keeps the legacy behaviour (requests grouped
//!   by `(format, n_tokens, cfg)` at gather time into fixed-membership
//!   batched decodes) for comparison benchmarks and for backends without
//!   an incremental-decode surface.
//!
//! The [`policy`] maps queue depth (a shared atomic counter — exact under
//! concurrent workers) to the serving format. Telemetry flows through
//! [`metrics::ServerObs`], a lock-free recorder over the [`crate::obs`]
//! registry: workers feed atomic counters/gauges/histograms per request and
//! per decode step (no shared mutex on the hot path), per-request lifecycle
//! spans — queue-wait, TTFT, inter-token gap, each per element format —
//! land in labelled histograms, and when tracing is enabled
//! ([`ServerConfig::trace`] / [`ServerConfig::trace_out`]) every lifecycle
//! edge also lands in a Chrome-trace [`crate::obs::TraceSink`] (one track
//! per worker, one lane per row). [`ServerConfig::metrics_out`] adds a
//! periodic JSON + Prometheus snapshot written by a sampler thread;
//! [`Server::metrics`] / [`Client::metrics_snapshot`] expose the same state
//! as a point-in-time [`Metrics`] view.

pub mod costmodel;
pub mod metrics;
pub mod policy;

pub use costmodel::HwModel;
pub use metrics::{FormatSpanHists, Metrics, ServerObs};
pub use policy::{Policy, SloState};

use crate::backend::DecodeSession;
use crate::coordinator::ElasticEngine;
use crate::eval::generate::{RowStepKind, SampleCfg};
use crate::formats::ElementFormat;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A scoring request: one token window of width `seq_len + 1` (shorter
/// windows are right-padded by the caller). `format` pins a precision;
/// `None` lets the policy decide.
pub struct ScoreRequest {
    /// Token window to score (width `seq_len + 1`).
    pub tokens: Vec<i32>,
    /// Optional precision pin (`None` = policy pick).
    pub format: Option<ElementFormat>,
    /// Where the response goes.
    pub respond: Sender<Result<ScoreResponse, String>>,
    /// Enqueue timestamp (latency accounting).
    pub enqueued: Instant,
}

/// The scoring response: per-sequence mean NLL plus serving telemetry.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    /// Mean NLL of the scored window.
    pub nll: f32,
    /// Format the request was served at.
    pub format: ElementFormat,
    /// Requests in the executed sub-batch.
    pub batch_size: usize,
    /// Queue depth the batcher observed.
    pub queue_depth: usize,
    /// End-to-end latency (enqueue to response).
    pub latency: Duration,
}

/// A generation request: sampled continuation of a text prompt. Under
/// continuous batching the request joins a worker's in-flight decode as
/// its own row — with its own format, budget and sampling config — as soon
/// as a slot frees; under gather batching, requests with equal
/// `(format, n_tokens, cfg)` in one gather window decode as a single
/// fixed-membership batched pass.
pub struct GenerateRequest {
    /// Prompt text.
    pub prompt: String,
    /// Continuation tokens to emit.
    pub n_tokens: usize,
    /// Optional precision pin (`None` = per-row policy pick).
    pub format: Option<ElementFormat>,
    /// Sampling configuration.
    pub cfg: SampleCfg,
    /// Where the response goes.
    pub respond: Sender<Result<GenerateResponse, String>>,
    /// Enqueue timestamp (latency accounting).
    pub enqueued: Instant,
}

/// The generation response: continuation text plus serving telemetry.
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    /// The sampled continuation (prompt excluded).
    pub text: String,
    /// Element format this request's row decoded at.
    pub format: ElementFormat,
    /// Rows sharing the decode when this request completed (continuous
    /// mode) or the gathered group size (gather mode).
    pub batch_size: usize,
    /// Queue depth observed when the request was admitted.
    pub queue_depth: usize,
    /// End-to-end latency (enqueue → response).
    pub latency: Duration,
}

/// One queued request (either lane).
pub enum Request {
    /// A scoring-lane request.
    Score(ScoreRequest),
    /// A generation-lane request.
    Generate(GenerateRequest),
}

/// How the generate lane forms decode batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GenBatching {
    /// Continuous batching (default): each worker keeps one persistent
    /// in-flight decode, drains the queue every step, admits prompts into
    /// free rows mid-flight (prefill-on-join), assigns formats per row and
    /// completes rows independently. Falls back to [`GenBatching::Gather`]
    /// on backends without an incremental-decode surface.
    #[default]
    Continuous,
    /// Legacy gather batching: generation requests group by
    /// `(format, n_tokens, cfg)` at gather time and decode as one
    /// fixed-membership batch — new requests wait for the next gather.
    Gather,
}

impl GenBatching {
    /// Parse `continuous` | `gather`.
    pub fn parse(s: &str) -> Result<GenBatching> {
        match s.trim().to_ascii_lowercase().as_str() {
            "continuous" | "cb" => Ok(GenBatching::Continuous),
            "gather" | "grouped" => Ok(GenBatching::Gather),
            other => anyhow::bail!("unknown batching mode '{other}' (continuous|gather)"),
        }
    }

    /// Stable identifier for logs and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            GenBatching::Continuous => "continuous",
            GenBatching::Gather => "gather",
        }
    }
}

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Queue-depth → precision policy (applied per request row).
    pub policy: Policy,
    /// How long the batcher waits to fill a batch.
    pub gather_window: Duration,
    /// Worker threads sharing the engine (≥ 1). Each worker gathers and
    /// executes its own batches; weights and metrics are shared.
    pub workers: usize,
    /// Generate-lane batching mode.
    pub batching: GenBatching,
    /// Sequence rows in each worker's continuous decode session
    /// (`0` ⇒ the model's `train_batch`).
    pub decode_slots: usize,
    /// KV page-pool sizing for each worker's decode session: page
    /// granularity (`--kv-page` / `MFQAT_KV_PAGE`) and optional page
    /// budget. With a budget below the dense-equivalent pool, generation
    /// admission becomes **memory-aware**: queued prompts wait while the
    /// pool cannot fund another worst-case row, instead of claiming a slot
    /// the memory cannot back.
    pub kv_page: crate::backend::KvPageCfg,
    /// Collect request-lifecycle trace events even without a
    /// [`ServerConfig::trace_out`] path (the sink is then read through
    /// [`ServerObs::trace`] — tests and benches). Tracing off means the
    /// hot path pays one `Option` check.
    pub trace: bool,
    /// Write a Chrome-trace-event JSON file (Perfetto-loadable; one track
    /// per worker, one lane per decode row) here at shutdown. Implies
    /// trace collection.
    pub trace_out: Option<std::path::PathBuf>,
    /// Write a machine-readable metrics snapshot here periodically and at
    /// shutdown: JSON at the given path, Prometheus text exposition at the
    /// same path with a `.prom` extension.
    pub metrics_out: Option<std::path::PathBuf>,
    /// Telemetry sampling interval: queue depth / KV residency / cache
    /// counter time-series points, and [`ServerConfig::metrics_out`]
    /// rewrites.
    pub metrics_every: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: Policy::default_ladder(),
            gather_window: Duration::from_millis(2),
            workers: 1,
            batching: GenBatching::Continuous,
            decode_slots: 0,
            kv_page: crate::backend::KvPageCfg::from_env(),
            trace: false,
            trace_out: None,
            metrics_out: None,
            metrics_every: Duration::from_millis(250),
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<Request>,
    obs: Arc<ServerObs>,
    config: ServerConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    sampler: Option<std::thread::JoinHandle<()>>,
    sampler_tx: Option<Sender<()>>,
    alive: Arc<AtomicBool>,
    stopped: bool,
}

/// Client handle (cheap to clone).
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    width: usize,
    depth: Arc<AtomicUsize>,
    obs: Arc<ServerObs>,
    /// Cleared on shutdown — a live client must not enqueue into a queue
    /// nobody drains (its own `tx` clone keeps the channel open).
    alive: Arc<AtomicBool>,
}

impl Client {
    /// Submit a scoring request and wait. `tokens` is truncated /
    /// right-padded to the window.
    pub fn score(&self, tokens: &[i32], format: Option<ElementFormat>) -> Result<ScoreResponse> {
        let rx = self.submit(tokens, format)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a scoring request without waiting; returns the response
    /// channel.
    pub fn submit(
        &self,
        tokens: &[i32],
        format: Option<ElementFormat>,
    ) -> Result<Receiver<Result<ScoreResponse, String>>> {
        let mut t = tokens.to_vec();
        t.truncate(self.width);
        t.resize(self.width, crate::data::PAD as i32);
        let (tx, rx) = mpsc::channel();
        self.send(Request::Score(ScoreRequest {
            tokens: t,
            format,
            respond: tx,
            enqueued: Instant::now(),
        }))?;
        Ok(rx)
    }

    /// Submit a generation request and wait.
    pub fn generate(
        &self,
        prompt: &str,
        n_tokens: usize,
        format: Option<ElementFormat>,
        cfg: SampleCfg,
    ) -> Result<GenerateResponse> {
        let rx = self.submit_generate(prompt, n_tokens, format, cfg)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a generation request without waiting; returns the response
    /// channel.
    pub fn submit_generate(
        &self,
        prompt: &str,
        n_tokens: usize,
        format: Option<ElementFormat>,
        cfg: SampleCfg,
    ) -> Result<Receiver<Result<GenerateResponse, String>>> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::Generate(GenerateRequest {
            prompt: prompt.to_string(),
            n_tokens,
            format,
            cfg,
            respond: tx,
            enqueued: Instant::now(),
        }))?;
        Ok(rx)
    }

    /// Point-in-time snapshot of the pool's serving metrics — request
    /// counts, latency/TTFT/inter-token distributions, KV residency,
    /// cache counters — without stopping the server.
    pub fn metrics_snapshot(&self) -> Metrics {
        self.obs.snapshot()
    }

    fn send(&self, req: Request) -> Result<()> {
        if !self.alive.load(Ordering::Acquire) {
            anyhow::bail!("server is shut down");
        }
        self.depth.fetch_add(1, Ordering::AcqRel);
        self.tx.send(req).map_err(|_| {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            anyhow::anyhow!("server is shut down")
        })
    }
}

/// Write the JSON metrics snapshot to `path` and the Prometheus text
/// exposition next to it (`.prom` extension).
fn write_metrics_files(obs: &ServerObs, path: &std::path::Path) {
    if let Err(e) = std::fs::write(path, obs.export_json().pretty()) {
        log::warn!("could not write metrics snapshot {}: {e:#}", path.display());
    }
    let prom = path.with_extension("prom");
    if let Err(e) = std::fs::write(&prom, obs.prometheus()) {
        log::warn!("could not write Prometheus snapshot {}: {e:#}", prom.display());
    }
}

impl Server {
    /// Start the worker pool.
    ///
    /// `factory` runs on the first worker thread (PJRT-style backends want
    /// construction off the caller's thread) and its error (if any) is
    /// returned from `start`; the resulting engine is `Arc`-shared across
    /// all `config.workers` workers — one weight cache, one metrics sink.
    /// `width` is `seq_len + 1` of the serving model (used for client-side
    /// padding).
    pub fn start<F>(width: usize, factory: F, config: ServerConfig) -> Result<(Server, Client)>
    where
        F: FnOnce() -> Result<ElasticEngine> + Send + 'static,
    {
        if config.workers == 0 {
            anyhow::bail!("server wants at least one worker (got workers=0)");
        }
        let (tx, rx) = mpsc::channel::<Request>();
        let queue = Arc::new(Mutex::new(rx));
        let trace = config.trace || config.trace_out.is_some();
        let obs = Arc::new(ServerObs::new(config.workers, trace));
        let depth = Arc::new(AtomicUsize::new(0));
        let alive = Arc::new(AtomicBool::new(true));
        let slo = Arc::new(Mutex::new(SloState::default()));
        let mut workers = Vec::with_capacity(config.workers);

        // Worker 0 builds the engine and hands an Arc back for the rest of
        // the pool (startup errors surface from `start` exactly as before).
        type Ready = std::result::Result<Arc<ElasticEngine>, String>;
        let (ready_tx, ready_rx) = mpsc::channel::<Ready>();
        {
            let (queue, obs, depth, alive, slo, config) = (
                queue.clone(),
                obs.clone(),
                depth.clone(),
                alive.clone(),
                slo.clone(),
                config.clone(),
            );
            workers.push(
                std::thread::Builder::new()
                    .name("mfqat-worker-0".into())
                    .spawn(move || {
                        let engine = match factory() {
                            Ok(e) => {
                                let e = Arc::new(e);
                                let _ = ready_tx.send(Ok(e.clone()));
                                e
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(format!("{e:#}")));
                                alive.store(false, Ordering::Release);
                                return;
                            }
                        };
                        worker_loop(0, &engine, &config, &queue, &obs, &depth, &alive, &slo);
                    })
                    .expect("spawn server worker"),
            );
        }
        let engine = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine init failed: {e}"))?;
        for i in 1..config.workers {
            let engine = engine.clone();
            let (queue, obs, depth, alive, slo, config) = (
                queue.clone(),
                obs.clone(),
                depth.clone(),
                alive.clone(),
                slo.clone(),
                config.clone(),
            );
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mfqat-worker-{i}"))
                    .spawn(move || {
                        worker_loop(i, &engine, &config, &queue, &obs, &depth, &alive, &slo);
                    })
                    .expect("spawn server worker"),
            );
        }
        // Telemetry sampler: a periodic time-series point (queue depth, KV
        // residency, cache counters) and the `metrics_out` file rewrite.
        // Dropping `sampler_tx` wakes it immediately at shutdown.
        let (sampler_tx, sampler_rx) = mpsc::channel::<()>();
        let sampler = {
            let obs = obs.clone();
            let depth = depth.clone();
            let every = config.metrics_every.max(Duration::from_millis(10));
            let metrics_out = config.metrics_out.clone();
            std::thread::Builder::new()
                .name("mfqat-obs-sampler".into())
                .spawn(move || {
                    while let Err(RecvTimeoutError::Timeout) = sampler_rx.recv_timeout(every) {
                        obs.sample(depth.load(Ordering::Acquire));
                        if let Some(path) = &metrics_out {
                            write_metrics_files(&obs, path);
                        }
                    }
                })
                .expect("spawn obs sampler")
        };
        let client = Client {
            tx: tx.clone(),
            width,
            depth,
            obs: obs.clone(),
            alive: alive.clone(),
        };
        Ok((
            Server {
                tx,
                obs,
                config,
                workers,
                sampler: Some(sampler),
                sampler_tx: Some(sampler_tx),
                alive,
                stopped: false,
            },
            client,
        ))
    }

    /// Point-in-time snapshot of the pool's serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.obs.snapshot()
    }

    /// The pool's live telemetry recorder (registry, exporters, trace
    /// sink).
    pub fn obs(&self) -> Arc<ServerObs> {
        self.obs.clone()
    }

    /// Graceful shutdown: close the queue and join the pool.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.stopped {
            return;
        }
        self.stopped = true;
        // Mark dead first so live clients stop enqueueing (their tx clones
        // keep the channel open), then drop our sender and join.
        self.alive.store(false, Ordering::Release);
        drop(std::mem::replace(&mut self.tx, mpsc::channel().0));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.sampler_tx.take();
        if let Some(s) = self.sampler.take() {
            let _ = s.join();
        }
        // Final time-series point and exports now that the pool is quiet.
        self.obs.sample(0);
        if let Some(path) = &self.config.metrics_out {
            write_metrics_files(&self.obs, path);
        }
        if let Some(path) = &self.config.trace_out {
            if let Some(sink) = self.obs.trace() {
                if let Err(e) = std::fs::write(path, sink.to_json().pretty()) {
                    log::warn!("could not write trace {}: {e:#}", path.display());
                }
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Gathered batch: at most `cap` requests, first one waited for (poll loop
/// honours shutdown), the rest collected inside the gather window. Anything
/// beyond `cap` stays queued for the other workers. Returns `None` on
/// shutdown/disconnect.
fn gather(
    queue: &Mutex<Receiver<Request>>,
    cap: usize,
    window: Duration,
    alive: &AtomicBool,
) -> Option<Vec<Request>> {
    let mut batch = Vec::new();
    let rx = queue.lock().unwrap();
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => {
                batch.push(r);
                break;
            }
            Err(RecvTimeoutError::Timeout) => {
                if alive.load(Ordering::Acquire) {
                    continue;
                }
                return None; // shutdown requested
            }
            Err(RecvTimeoutError::Disconnected) => return None, // all senders gone
        }
    }
    let deadline = Instant::now() + window;
    while batch.len() < cap {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    // Top up from anything already queued, still capped so concurrent
    // workers share the backlog.
    while batch.len() < cap {
        match rx.try_recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// Non-blocking drain for a worker with an in-flight decode: take the
/// queue lock only if it is free (an idle worker may be blocked inside
/// [`gather`] holding it — it will pick those requests up itself) and pop
/// whatever is already queued, up to `cap`.
fn drain_ready(queue: &Mutex<Receiver<Request>>, cap: usize) -> Vec<Request> {
    let mut batch = Vec::new();
    if let Ok(rx) = queue.try_lock() {
        while batch.len() < cap {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
    }
    batch
}

/// Group requests by their effective format (pin, else the policy pick for
/// the current depth): pinned requests must be served at their pin, so one
/// gathered batch splits into per-format sub-batches instead of letting
/// the first pin silently win for everyone.
fn group_scores(
    reqs: Vec<ScoreRequest>,
    policy_fmt: ElementFormat,
) -> Vec<(ElementFormat, Vec<ScoreRequest>)> {
    let mut groups: Vec<(ElementFormat, Vec<ScoreRequest>)> = Vec::new();
    for r in reqs {
        let fmt = r.format.unwrap_or(policy_fmt);
        match groups.iter_mut().find(|(f, _)| *f == fmt) {
            Some((_, g)) => g.push(r),
            None => groups.push((fmt, vec![r])),
        }
    }
    groups
}

/// Trace lane for scoring batches (not tied to a decode row).
const SCORE_TID: u64 = 1000;
/// Trace lane for legacy gather-mode generation batches.
const GATHER_TID: u64 = 1001;
/// Trace lane for queue-side events (admission deferrals).
const QUEUE_TID: u64 = 1002;

/// Execute one per-format scoring sub-batch and respond to every request
/// in it (shared by both worker-loop flavours).
#[allow(clippy::too_many_arguments)]
fn execute_score_group(
    worker: usize,
    engine: &ElasticEngine,
    config: &ServerConfig,
    obs: &ServerObs,
    slo: &Mutex<SloState>,
    queue_depth: usize,
    fmt: ElementFormat,
    group: Vec<ScoreRequest>,
) {
    let t0 = Instant::now();
    // Sub-batches execute at their true size; only the PJRT graph pads
    // internally to its fixed batch shape.
    let width = engine.dims().seq_len + 1;
    let mut flat = Vec::with_capacity(group.len() * width);
    for r in &group {
        flat.extend_from_slice(&r.tokens);
    }
    let result = engine.score_batch(&flat, fmt);
    let elapsed = t0.elapsed();
    slo.lock().unwrap().observe(&config.policy, elapsed.as_secs_f64());
    if let Some(sink) = obs.trace() {
        sink.complete(
            "score_batch",
            worker as u64,
            SCORE_TID,
            sink.ts_us(t0),
            elapsed.as_micros() as u64,
            vec![
                ("format", Json::from(fmt.name())),
                ("batch", Json::from(group.len())),
            ],
        );
    }

    match result {
        Ok(nlls) => {
            let bs = group.len();
            let latencies: Vec<Duration> = group.iter().map(|r| r.enqueued.elapsed()).collect();
            for latency in &latencies {
                obs.record_score(fmt, latency.as_secs_f64(), bs, elapsed.as_secs_f64());
            }
            obs.set_cache(engine.cache_stats());
            for ((j, req), latency) in group.into_iter().enumerate().zip(latencies) {
                let _ = req.respond.send(Ok(ScoreResponse {
                    nll: nlls[j],
                    format: fmt,
                    batch_size: bs,
                    queue_depth,
                    latency,
                }));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e:#}");
            log::error!("{msg}");
            for req in group {
                let _ = req.respond.send(Err(msg.clone()));
            }
        }
    }
}

/// Execute one legacy gather-mode generation group (fixed membership, one
/// shared format/budget/cfg) and respond to every request in it.
#[allow(clippy::too_many_arguments)]
fn execute_gen_group(
    worker: usize,
    engine: &ElasticEngine,
    config: &ServerConfig,
    obs: &ServerObs,
    slo: &Mutex<SloState>,
    queue_depth: usize,
    fmt: ElementFormat,
    n_tokens: usize,
    cfg: SampleCfg,
    group: Vec<GenerateRequest>,
) {
    let t0 = Instant::now();
    let result = {
        let prompts: Vec<&str> = group.iter().map(|r| r.prompt.as_str()).collect();
        engine.generate_batch(&prompts, fmt, n_tokens, &cfg)
    };
    let elapsed = t0.elapsed();
    // The SLO ladder tracks *batch execution* latency. A whole decode is
    // `n_tokens` step-synchronized passes, so feed the per-step time —
    // feeding the full decode duration would let a single long generation
    // blow the EWMA past any scoring-scale target and pin the ladder at
    // the bottom rung.
    slo.lock()
        .unwrap()
        .observe(&config.policy, elapsed.as_secs_f64() / n_tokens.max(1) as f64);
    if let Some(sink) = obs.trace() {
        sink.complete(
            "gen_batch",
            worker as u64,
            GATHER_TID,
            sink.ts_us(t0),
            elapsed.as_micros() as u64,
            vec![
                ("format", Json::from(fmt.name())),
                ("batch", Json::from(group.len())),
                ("n_tokens", Json::from(n_tokens)),
            ],
        );
    }

    match result {
        Ok(texts) => {
            let bs = group.len();
            let latencies: Vec<Duration> = group.iter().map(|r| r.enqueued.elapsed()).collect();
            for latency in &latencies {
                obs.record_generate(
                    fmt,
                    latency.as_secs_f64(),
                    bs,
                    elapsed.as_secs_f64(),
                    n_tokens as u64,
                );
            }
            obs.set_cache(engine.cache_stats());
            for ((req, text), latency) in group.into_iter().zip(texts).zip(latencies) {
                let _ = req.respond.send(Ok(GenerateResponse {
                    text,
                    format: fmt,
                    batch_size: bs,
                    queue_depth,
                    latency,
                }));
            }
        }
        Err(e) => {
            let msg = format!("batched generation failed: {e:#}");
            log::error!("{msg}");
            for req in group {
                let _ = req.respond.send(Err(msg.clone()));
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    engine: &ElasticEngine,
    config: &ServerConfig,
    queue: &Mutex<Receiver<Request>>,
    obs: &ServerObs,
    depth: &AtomicUsize,
    alive: &AtomicBool,
    slo: &Mutex<SloState>,
) {
    if config.batching == GenBatching::Continuous {
        let slots = if config.decode_slots == 0 {
            engine.dims().train_batch
        } else {
            config.decode_slots
        };
        match engine.decode_session_cfg(slots, config.kv_page) {
            Ok(session) => {
                continuous_loop(worker, engine, config, queue, obs, depth, alive, slo, session);
                log::info!("server worker exiting; {}", obs.snapshot().summary());
                return;
            }
            Err(e) => log::warn!(
                "backend '{}' has no continuous-decode surface ({e:#}); \
                 generate lane falls back to gather batching",
                engine.backend_name()
            ),
        }
    }
    gather_loop(worker, engine, config, queue, obs, depth, alive, slo);
    log::info!("server worker exiting; {}", obs.snapshot().summary());
}

/// Legacy batching loop: gather → split into per-format (and, for
/// generation, per-budget/cfg) groups → execute each group to completion.
#[allow(clippy::too_many_arguments)]
fn gather_loop(
    worker: usize,
    engine: &ElasticEngine,
    config: &ServerConfig,
    queue: &Mutex<Receiver<Request>>,
    obs: &ServerObs,
    depth: &AtomicUsize,
    alive: &AtomicBool,
    slo: &Mutex<SloState>,
) {
    let b = engine.dims().train_batch;
    loop {
        let Some(batch) = gather(queue, b, config.gather_window, alive) else {
            break;
        };
        // Depth *before* this worker hands its gathered requests to the
        // engine — pending elsewhere plus this batch (the policy signal).
        let queue_depth = depth.load(Ordering::Acquire);
        depth.fetch_sub(batch.len(), Ordering::AcqRel);

        let policy_fmt = config.policy.choose_with(queue_depth, &slo.lock().unwrap());
        let mut scores: Vec<ScoreRequest> = Vec::new();
        let mut gen_groups: Vec<(ElementFormat, usize, SampleCfg, Vec<GenerateRequest>)> =
            Vec::new();
        for req in batch {
            match req {
                Request::Score(r) => scores.push(r),
                Request::Generate(r) => {
                    let fmt = r.format.unwrap_or(policy_fmt);
                    match gen_groups
                        .iter_mut()
                        .find(|g| g.0 == fmt && g.1 == r.n_tokens && g.2 == r.cfg)
                    {
                        Some(g) => g.3.push(r),
                        None => gen_groups.push((fmt, r.n_tokens, r.cfg.clone(), vec![r])),
                    }
                }
            }
        }
        for (fmt, group) in group_scores(scores, policy_fmt) {
            execute_score_group(worker, engine, config, obs, slo, queue_depth, fmt, group);
        }
        for (fmt, n_tokens, cfg, group) in gen_groups {
            execute_gen_group(
                worker,
                engine,
                config,
                obs,
                slo,
                queue_depth,
                fmt,
                n_tokens,
                cfg,
                group,
            );
        }
    }
}

/// Server-side bookkeeping for one live row of a worker's continuous
/// decode session.
struct GenRow {
    respond: Sender<std::result::Result<GenerateResponse, String>>,
    enqueued: Instant,
    joined: Instant,
    fmt: ElementFormat,
    n_tokens: usize,
    queue_depth: usize,
    /// When this row's most recent token landed (TTFT vs inter-token gap).
    last_token: Option<Instant>,
    /// Tokens sampled so far (trace annotation).
    emitted: usize,
}

/// Look up (or register and cache) the TTFT/inter-token histograms for
/// `fmt` — the per-step path touches only the cached atomic handles.
fn spans_for<'c>(
    cache: &'c mut Vec<(ElementFormat, FormatSpanHists)>,
    obs: &ServerObs,
    fmt: ElementFormat,
) -> &'c FormatSpanHists {
    match cache.iter().position(|(f, _)| *f == fmt) {
        Some(i) => &cache[i].1,
        None => {
            cache.push((fmt, obs.span_hists(fmt)));
            &cache.last().unwrap().1
        }
    }
}

/// Continuous-batching loop: one persistent in-flight decode per worker.
///
/// Every iteration (a) drains whatever is already queued — without
/// blocking while rows are decoding, (b) executes scoring sub-batches,
/// (c) admits queued generation requests into free rows (prefill-on-join,
/// per-row format from the policy at admission time), and (d) advances the
/// decode by **one step**, responding to rows that completed. Queue
/// latency for a new prompt is therefore one decode step, not one whole
/// batched decode.
///
/// Observability: admission records queue-wait (and deferral/downshift
/// counts), each step's [`crate::eval::generate::RowStepEvent`]s attribute
/// prefill vs decode vs overflow re-prefill per row and feed the
/// per-format TTFT / inter-token histograms, and — when tracing is on —
/// every edge lands in the trace sink as a span on `pid = worker`,
/// `tid = row slot`. None of this perturbs decode state: events are
/// bookkeeping emitted by the same step the session already ran.
#[allow(clippy::too_many_arguments)]
fn continuous_loop<'e>(
    worker: usize,
    engine: &'e ElasticEngine,
    config: &ServerConfig,
    queue: &Mutex<Receiver<Request>>,
    obs: &ServerObs,
    depth: &AtomicUsize,
    alive: &AtomicBool,
    slo: &Mutex<SloState>,
    mut session: Box<dyn DecodeSession + 'e>,
) {
    let b = engine.dims().train_batch;
    let wid = worker as u64;
    // Backlogged requests carry a "deferral already counted" flag so a
    // request deferred across many steps counts once.
    let mut backlog: VecDeque<(GenerateRequest, bool)> = VecDeque::new();
    let mut rows: Vec<Option<GenRow>> = (0..session.capacity()).map(|_| None).collect();
    let mut span_cache: Vec<(ElementFormat, FormatSpanHists)> = Vec::new();
    // The policy's unloaded pick — the yardstick for counting downshifts
    // (rows admitted below it because of queue depth / SLO pressure).
    let baseline_fmt = config.policy.choose_with(0, &SloState::default());
    loop {
        // (a) Take work from the shared queue. Idle workers block exactly
        // like the gather loop (so shutdown and wakeup semantics match);
        // workers with live rows only sweep what is already queued so the
        // decode never stalls on an empty queue. A worker whose session is
        // *full* stops draining while it has pool peers: anything it pulled
        // would sit in its private backlog for whole decodes while an idle
        // peer could serve it now (a lone worker keeps draining — there is
        // nobody else, and interleaving score batches between steps beats
        // letting them wait for a row to finish).
        let busy = session.active() > 0 || !backlog.is_empty();
        // Shutdown must not wait out arbitrarily long in-flight budgets
        // (n_tokens is client-controlled): fail the live rows and exit.
        if busy && !alive.load(Ordering::Acquire) {
            let msg = "server is shutting down".to_string();
            for slot in rows.iter_mut() {
                if let Some(row) = slot.take() {
                    let _ = row.respond.send(Err(msg.clone()));
                }
            }
            for (r, _) in backlog.drain(..) {
                let _ = r.respond.send(Err(msg.clone()));
            }
            break;
        }
        let batch = if busy {
            if config.workers > 1 && session.active() == session.capacity() {
                Vec::new()
            } else {
                drain_ready(queue, b)
            }
        } else {
            match gather(queue, b, config.gather_window, alive) {
                Some(batch) => batch,
                None => break,
            }
        };
        let queue_depth = depth.load(Ordering::Acquire);
        if !batch.is_empty() {
            depth.fetch_sub(batch.len(), Ordering::AcqRel);
        }
        let mut scores: Vec<ScoreRequest> = Vec::new();
        for req in batch {
            match req {
                Request::Score(r) => scores.push(r),
                Request::Generate(r) => backlog.push_back((r, false)),
            }
        }

        // (b) Scoring executes between decode steps, exactly as before.
        if !scores.is_empty() {
            let policy_fmt = config.policy.choose_with(queue_depth, &slo.lock().unwrap());
            for (fmt, group) in group_scores(scores, policy_fmt) {
                execute_score_group(worker, engine, config, obs, slo, queue_depth, fmt, group);
            }
        }

        // (c) Admit queued prompts into free rows: they prefill on the very
        // next step while their neighbours keep decoding. The precision
        // policy runs per row at admission time, so one in-flight decode
        // carries as many formats as the load swung through. Admission is
        // memory-aware: `can_admit` also checks that the KV page pool can
        // fund another worst-case row, so under a constrained page budget
        // queued prompts *defer* (stay backlogged) until a live row retires
        // and returns its pages, instead of failing.
        while session.can_admit() {
            let Some((r, _)) = backlog.pop_front() else { break };
            let d = depth.load(Ordering::Acquire) + backlog.len();
            let fmt = match r.format {
                Some(f) => f,
                None => config.policy.choose_with(d, &slo.lock().unwrap()),
            };
            if r.format.is_none() && fmt != baseline_fmt {
                obs.record_downshift();
            }
            match session.join(&r.prompt, fmt, r.n_tokens, &r.cfg) {
                Ok(slot) => {
                    let admitted = Instant::now();
                    let wait = admitted.saturating_duration_since(r.enqueued);
                    obs.record_queue_wait(wait.as_secs_f64());
                    if let Some(sink) = obs.trace() {
                        sink.complete(
                            "queue_wait",
                            wid,
                            slot as u64,
                            sink.ts_us(r.enqueued),
                            wait.as_micros() as u64,
                            vec![("format", Json::from(fmt.name()))],
                        );
                        let mut args = vec![
                            ("format", Json::from(fmt.name())),
                            ("queue_depth", Json::from(d)),
                        ];
                        if r.format.is_none() && fmt != baseline_fmt {
                            args.push(("downshift_from", Json::from(baseline_fmt.name())));
                        }
                        sink.instant("admit", wid, slot as u64, args);
                    }
                    rows[slot] = Some(GenRow {
                        respond: r.respond,
                        enqueued: r.enqueued,
                        joined: admitted,
                        fmt,
                        n_tokens: r.n_tokens,
                        queue_depth: d,
                        last_token: None,
                        emitted: 0,
                    });
                }
                Err(e) => {
                    let msg = format!("generation admission failed: {e:#}");
                    log::error!("{msg}");
                    let _ = r.respond.send(Err(msg));
                }
            }
        }
        // Whatever is still backlogged was deferred by a full session or an
        // exhausted KV page budget — count each request's deferral once.
        if !backlog.is_empty() && !session.can_admit() {
            let reason = if session.active() >= session.capacity() {
                "slots"
            } else {
                "kv_pages"
            };
            for (_, counted) in backlog.iter_mut() {
                if !*counted {
                    *counted = true;
                    obs.record_deferral();
                    if let Some(sink) = obs.trace() {
                        sink.instant("defer", wid, QUEUE_TID, vec![("reason", Json::from(reason))]);
                    }
                }
            }
        }

        // (d) One decode step for every live row; completed rows respond
        // immediately and free their slots for the next iteration's joins.
        if session.active() == 0 {
            continue;
        }
        let bs = session.active();
        let t_step = Instant::now();
        match session.step_with_events() {
            Ok((finished, events)) => {
                let step_end = Instant::now();
                let dur_us = step_end.saturating_duration_since(t_step).as_micros() as u64;
                // Per-row lifecycle accounting *before* finished rows are
                // taken: a row that completes this step still attributes
                // its final token. Every fed row sampled one token, so the
                // first event after admission closes the TTFT span and
                // later ones measure inter-token gaps.
                for ev in &events {
                    let Some(row) = rows.get_mut(ev.slot).and_then(|s| s.as_mut()) else {
                        continue;
                    };
                    let spans = spans_for(&mut span_cache, obs, row.fmt);
                    match row.last_token {
                        None => {
                            let ttft = step_end.saturating_duration_since(row.enqueued);
                            spans.ttft.record(ttft.as_secs_f64());
                        }
                        Some(prev) => {
                            let gap = step_end.saturating_duration_since(prev);
                            spans.inter_token.record(gap.as_secs_f64());
                        }
                    }
                    row.last_token = Some(step_end);
                    row.emitted += 1;
                    if ev.kind == RowStepKind::Reprefill {
                        obs.record_reprefill();
                    }
                    if let Some(sink) = obs.trace() {
                        let name = match ev.kind {
                            RowStepKind::Prefill => "prefill",
                            RowStepKind::Decode => "decode",
                            RowStepKind::Reprefill => "reprefill",
                        };
                        sink.complete(
                            name,
                            wid,
                            ev.slot as u64,
                            sink.ts_us(t_step),
                            dur_us,
                            vec![
                                ("format", Json::from(row.fmt.name())),
                                ("fed", Json::from(ev.fed_tokens)),
                                ("token", Json::from(row.emitted)),
                            ],
                        );
                    }
                }
                let mut done = Vec::with_capacity(finished.len());
                for f in finished {
                    if let Some(row) = rows[f.slot].take() {
                        let latency = row.enqueued.elapsed();
                        let service = row.joined.elapsed();
                        done.push((row, f.slot, f.text, latency, service));
                    }
                }
                // Snapshot paged-KV residency after the step (per-worker
                // gauges — the pool view aggregates across workers). The
                // snapshot carries the cache's allocation-time high-water
                // mark, so rows that mapped pages and retired *within* this
                // step still register in the peak reports.
                obs.set_kv(worker, session.kv_memory());
                if done.is_empty() {
                    continue;
                }
                {
                    // Feed the SLO per-step time, not the whole decode's
                    // service time (see `execute_gen_group`): a row's
                    // service spans `n_tokens` step-synchronized passes.
                    let mut s = slo.lock().unwrap();
                    for (row, _, _, _, service) in &done {
                        s.observe(
                            &config.policy,
                            service.as_secs_f64() / row.n_tokens.max(1) as f64,
                        );
                    }
                }
                for (row, slot, _, latency, service) in &done {
                    obs.record_generate(
                        row.fmt,
                        latency.as_secs_f64(),
                        bs,
                        service.as_secs_f64(),
                        row.n_tokens as u64,
                    );
                    if let Some(sink) = obs.trace() {
                        sink.complete(
                            "request",
                            wid,
                            *slot as u64,
                            sink.ts_us(row.enqueued),
                            latency.as_micros() as u64,
                            vec![
                                ("format", Json::from(row.fmt.name())),
                                ("tokens", Json::from(row.n_tokens)),
                            ],
                        );
                        sink.instant(
                            "complete",
                            wid,
                            *slot as u64,
                            vec![("format", Json::from(row.fmt.name()))],
                        );
                    }
                }
                obs.set_cache(engine.cache_stats());
                for (row, _, text, latency, _) in done {
                    let _ = row.respond.send(Ok(GenerateResponse {
                        text,
                        format: row.fmt,
                        batch_size: bs,
                        queue_depth: row.queue_depth,
                        latency,
                    }));
                }
            }
            Err(e) => {
                // A step failure poisons the whole in-flight batch: fail
                // every live row and restart from a fresh session.
                let msg = format!("continuous decode step failed: {e:#}");
                log::error!("{msg}");
                for slot in rows.iter_mut() {
                    if let Some(row) = slot.take() {
                        let _ = row.respond.send(Err(msg.clone()));
                    }
                }
                match engine.decode_session_cfg(session.capacity(), config.kv_page) {
                    Ok(s) => session = s,
                    Err(e) => {
                        log::error!("could not reopen the decode session: {e:#}");
                        break;
                    }
                }
            }
        }
    }
}
