//! Explicit-SIMD inner loops for the integer-MAC GEMM.
//!
//! The hot loop of [`super::kernels::gemm_repacked_int`] is a rank-`kl`
//! update: for one `(k-block, out-block)` tile it accumulates
//! `acc[n] += m[k] · w[k][n]` over aligned activation codes `m` and decoded
//! weight codes `w`, in `i16` (≤4-bit elements) or `i32`. PR 2 left that
//! loop to the autovectorizer; this module hand-writes it:
//!
//! * **AVX2** (x86-64, runtime-detected): `_mm256_mullo_epi16` /
//!   `_mm256_mullo_epi32` broadcast-MACs with the accumulator tile held in
//!   registers across the whole `k` loop — 16 (i16) / 8 (i32) lanes, two
//!   accumulator vectors deep so a 32-wide MX block is one register pass.
//! * **NEON** (aarch64): the same structure over `vmlaq_s16` / `vmlaq_s32`
//!   (8 / 4 lanes, two vectors deep).
//! * **Portable**: the scalar loop the autovectorizer already handled,
//!   retained as the fallback for other ISAs *and as the differential-test
//!   oracle* — the SIMD paths must produce bit-identical accumulators
//!   (all arithmetic is wrapping two's complement, so any reassociation of
//!   the same products is exact).
//!
//! Dispatch is per-call ([`tile_mac_i16`] / [`tile_mac_i32`]) against a
//! once-per-process [`SimdLevel`]. The tiles these kernels chew arrive
//! from any GEMM the forward issues — full-sequence scoring, `rows ≥ 1`
//! KV-batched decode, or a mixed-format continuous-batching step (where
//! one step dispatches several per-format GEMMs); the kernels are
//! oblivious to batching shape, seeing only `[rows, k]` tiles.
//! `MFQAT_SIMD=off` forces the portable path (the forced-fallback leg of
//! CI's differential run); the env-var surface is documented once in
//! [`crate::util::cli`].
//!
//! The same dispatch + differential-oracle contract covers the
//! quantized-KV dequant kernels ([`kv_dequant_i8`] / [`kv_dequant_i4`] /
//! [`kv_dequant_fp8`]) the paged attention gather decodes MX-coded K/V
//! pages through — power-of-two scale multiplies and i8→f32 conversions
//! are exact, so every arm is bit-identical to its portable oracle.

use std::sync::OnceLock;

/// Which instruction set the integer-MAC tile kernels run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Scalar/autovectorized fallback (also the differential oracle).
    Portable,
    /// 256-bit AVX2 integer ops (x86-64, runtime-detected).
    Avx2,
    /// 128-bit NEON integer ops (aarch64).
    Neon,
}

impl SimdLevel {
    /// Stable identifier (`"portable"` / `"avx2"` / `"neon"`) for logs and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Portable => "portable",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

/// What the running CPU supports.
fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Portable
}

/// Resolve the dispatch level from the `MFQAT_SIMD` override and the
/// detected capability. `off`/`0`/`false`/`portable` force the portable
/// path; anything else (including unset) keeps the detected level.
pub fn resolve_level(env: Option<&str>, detected: SimdLevel) -> SimdLevel {
    match env.map(|s| s.trim().to_ascii_lowercase()) {
        Some(v) if matches!(v.as_str(), "off" | "0" | "false" | "portable" | "none") => {
            SimdLevel::Portable
        }
        _ => detected,
    }
}

/// The active dispatch level (`MFQAT_SIMD` consulted once per process).
pub fn level() -> SimdLevel {
    static L: OnceLock<SimdLevel> = OnceLock::new();
    *L.get_or_init(|| resolve_level(std::env::var("MFQAT_SIMD").ok().as_deref(), detect()))
}

#[inline]
fn check_tile(acc_len: usize, kl: usize, w_len: usize, stride: usize) {
    assert!(stride >= acc_len, "row stride shorter than the accumulator");
    assert!(
        kl == 0 || w_len >= (kl - 1) * stride + acc_len,
        "weight tile too short for {kl} rows of stride {stride}"
    );
}

// --------------------------------------------------------------------------
// i16 rank update (narrow path: ≤4-bit weight codes).
// --------------------------------------------------------------------------

/// `acc[n] += Σ_k m[k] · w[k·stride + n]` in wrapping `i16`, dispatched to
/// the active [`SimdLevel`]. Bit-identical to [`tile_mac_i16_portable`] on
/// every input (wrapping integer MACs reassociate exactly).
#[inline]
pub fn tile_mac_i16(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds checked above; AVX2 presence runtime-verified.
        SimdLevel::Avx2 => unsafe { tile_mac_i16_avx2(acc, m, w, stride) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: bounds checked above; NEON presence runtime-verified.
        SimdLevel::Neon => unsafe { tile_mac_i16_neon(acc, m, w, stride) },
        _ => tile_mac_i16_scalar(acc, m, w, stride, 0),
    }
}

/// The portable reference (public for differential tests and benches).
pub fn tile_mac_i16_portable(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    tile_mac_i16_scalar(acc, m, w, stride, 0);
}

/// Scalar core over columns `n0..acc.len()` (also the SIMD tail).
fn tile_mac_i16_scalar(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize, n0: usize) {
    let nl = acc.len();
    for (k, &mk) in m.iter().enumerate() {
        if mk == 0 {
            continue;
        }
        let row = &w[k * stride + n0..k * stride + nl];
        for (a, &c) in acc[n0..].iter_mut().zip(row) {
            *a = a.wrapping_add(mk.wrapping_mul(c));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_mac_i16_avx2(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    use std::arch::x86_64::*;
    let nl = acc.len();
    let mut n = 0usize;
    // Two accumulator vectors deep: a 32-wide MX block is one pass with a
    // single broadcast per k.
    while n + 32 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        let mut a1 = _mm256_loadu_si256(acc.as_ptr().add(n + 16) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = _mm256_set1_epi16(mk);
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            let w1 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n + 16) as *const __m256i);
            a0 = _mm256_add_epi16(a0, _mm256_mullo_epi16(mv, w0));
            a1 = _mm256_add_epi16(a1, _mm256_mullo_epi16(mv, w1));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        _mm256_storeu_si256(acc.as_mut_ptr().add(n + 16) as *mut __m256i, a1);
        n += 32;
    }
    while n + 16 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            a0 = _mm256_add_epi16(a0, _mm256_mullo_epi16(_mm256_set1_epi16(mk), w0));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        n += 16;
    }
    if n < nl {
        tile_mac_i16_scalar(acc, m, w, stride, n);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_mac_i16_neon(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    use std::arch::aarch64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 16 <= nl {
        let mut a0 = vld1q_s16(acc.as_ptr().add(n));
        let mut a1 = vld1q_s16(acc.as_ptr().add(n + 8));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = vdupq_n_s16(mk);
            a0 = vmlaq_s16(a0, mv, vld1q_s16(w.as_ptr().add(k * stride + n)));
            a1 = vmlaq_s16(a1, mv, vld1q_s16(w.as_ptr().add(k * stride + n + 8)));
        }
        vst1q_s16(acc.as_mut_ptr().add(n), a0);
        vst1q_s16(acc.as_mut_ptr().add(n + 8), a1);
        n += 16;
    }
    while n + 8 <= nl {
        let mut a0 = vld1q_s16(acc.as_ptr().add(n));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            a0 = vmlaq_s16(a0, vdupq_n_s16(mk), vld1q_s16(w.as_ptr().add(k * stride + n)));
        }
        vst1q_s16(acc.as_mut_ptr().add(n), a0);
        n += 8;
    }
    if n < nl {
        tile_mac_i16_scalar(acc, m, w, stride, n);
    }
}

// --------------------------------------------------------------------------
// i32 rank update (wide path: 5..8-bit weight codes).
// --------------------------------------------------------------------------

/// `acc[n] += Σ_k m[k] · w[k·stride + n]` in wrapping `i32`, dispatched to
/// the active [`SimdLevel`]. Bit-identical to [`tile_mac_i32_portable`].
#[inline]
pub fn tile_mac_i32(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds checked above; AVX2 presence runtime-verified.
        SimdLevel::Avx2 => unsafe { tile_mac_i32_avx2(acc, m, w, stride) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: bounds checked above; NEON presence runtime-verified.
        SimdLevel::Neon => unsafe { tile_mac_i32_neon(acc, m, w, stride) },
        _ => tile_mac_i32_scalar(acc, m, w, stride, 0),
    }
}

/// The portable reference (public for differential tests and benches).
pub fn tile_mac_i32_portable(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    tile_mac_i32_scalar(acc, m, w, stride, 0);
}

fn tile_mac_i32_scalar(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize, n0: usize) {
    let nl = acc.len();
    for (k, &mk) in m.iter().enumerate() {
        if mk == 0 {
            continue;
        }
        let row = &w[k * stride + n0..k * stride + nl];
        for (a, &c) in acc[n0..].iter_mut().zip(row) {
            *a = a.wrapping_add(mk.wrapping_mul(c));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_mac_i32_avx2(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    use std::arch::x86_64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 16 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        let mut a1 = _mm256_loadu_si256(acc.as_ptr().add(n + 8) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = _mm256_set1_epi32(mk);
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            let w1 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n + 8) as *const __m256i);
            a0 = _mm256_add_epi32(a0, _mm256_mullo_epi32(mv, w0));
            a1 = _mm256_add_epi32(a1, _mm256_mullo_epi32(mv, w1));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        _mm256_storeu_si256(acc.as_mut_ptr().add(n + 8) as *mut __m256i, a1);
        n += 16;
    }
    while n + 8 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            a0 = _mm256_add_epi32(a0, _mm256_mullo_epi32(_mm256_set1_epi32(mk), w0));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        n += 8;
    }
    if n < nl {
        tile_mac_i32_scalar(acc, m, w, stride, n);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_mac_i32_neon(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    use std::arch::aarch64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 8 <= nl {
        let mut a0 = vld1q_s32(acc.as_ptr().add(n));
        let mut a1 = vld1q_s32(acc.as_ptr().add(n + 4));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = vdupq_n_s32(mk);
            a0 = vmlaq_s32(a0, mv, vld1q_s32(w.as_ptr().add(k * stride + n)));
            a1 = vmlaq_s32(a1, mv, vld1q_s32(w.as_ptr().add(k * stride + n + 4)));
        }
        vst1q_s32(acc.as_mut_ptr().add(n), a0);
        vst1q_s32(acc.as_mut_ptr().add(n + 4), a1);
        n += 8;
    }
    while n + 4 <= nl {
        let mut a0 = vld1q_s32(acc.as_ptr().add(n));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            a0 = vmlaq_s32(a0, vdupq_n_s32(mk), vld1q_s32(w.as_ptr().add(k * stride + n)));
        }
        vst1q_s32(acc.as_mut_ptr().add(n), a0);
        n += 4;
    }
    if n < nl {
        tile_mac_i32_scalar(acc, m, w, stride, n);
    }
}

// --------------------------------------------------------------------------
// Quantized-KV dequantization (MX-block K/V pages).
// --------------------------------------------------------------------------
//
// The paged KV cache stores quantized pages as per-position code rows plus
// one E8M0 exponent per `block` channels (`kvpool::KV_SCALE_BLOCK`). The
// attention gather decodes whole position runs through these kernels:
// `out[r*d + i] = code[r][i] as f32 * 2^scale[r][i/block]`. Multiplying by
// a power of two is exact in IEEE f32, and so is the i8→f32 conversion, so
// every SIMD arm is bit-identical to its scalar oracle — the same
// differential-harness contract as the tile MACs above.

/// Reinterpret packed code bytes as two's-complement `i8` lanes.
#[inline]
fn as_i8(bytes: &[u8]) -> &[i8] {
    // SAFETY: u8 and i8 have identical size and alignment; reinterpreting
    // each byte as two's-complement is exactly the stored code semantics.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
}

#[inline]
fn check_kv(
    codes_len: usize,
    row_bytes: usize,
    scales_len: usize,
    d: usize,
    block: usize,
    out_len: usize,
) {
    assert!(d > 0 && block > 0, "empty KV row layout");
    assert_eq!(out_len % d, 0, "output is not a whole number of {d}-channel rows");
    let rows = out_len / d;
    assert_eq!(codes_len, rows * row_bytes, "code bytes disagree with {rows} rows");
    assert_eq!(
        scales_len,
        rows * d.div_ceil(block),
        "one scale per {block}-channel block per row"
    );
}

/// Dequantize rows of MXINT8 KV codes: one signed byte per channel,
/// `out[i] = code[i] × 2^scale[i / block]` per row. Dispatched to the
/// active [`SimdLevel`]; bit-identical to [`kv_dequant_i8_portable`].
#[inline]
pub fn kv_dequant_i8(codes: &[u8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    check_kv(codes.len(), d, scales.len(), d, block, out.len());
    kv_scale_i8_dispatch(as_i8(codes), scales, d, block, out);
}

/// The portable reference for [`kv_dequant_i8`] (public for differential
/// tests and the `MFQAT_SIMD=off` CI leg).
pub fn kv_dequant_i8_portable(
    codes: &[u8],
    scales: &[i8],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    check_kv(codes.len(), d, scales.len(), d, block, out.len());
    kv_scale_i8_scalar(as_i8(codes), scales, d, block, out);
}

/// Dequantize rows of MXINT4 KV codes: two signed nibbles per byte
/// (row-aligned, `packed_len(d, 4)` bytes per row), then the same
/// block-scale multiply as [`kv_dequant_i8`]. Bit-identical to
/// [`kv_dequant_i4_portable`].
pub fn kv_dequant_i4(packed: &[u8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    let row_bytes = crate::formats::pack::packed_len(d, 4);
    check_kv(packed.len(), row_bytes, scales.len(), d, block, out.len());
    let mut codes = vec![0i8; out.len()];
    for (crow, prow) in codes.chunks_exact_mut(d).zip(packed.chunks_exact(row_bytes)) {
        crate::formats::pack::unpack_signed_into(prow, 4, crow);
    }
    kv_scale_i8_dispatch(&codes, scales, d, block, out);
}

/// The portable reference for [`kv_dequant_i4`]: scalar nibble unpack +
/// scalar scale loop.
pub fn kv_dequant_i4_portable(
    packed: &[u8],
    scales: &[i8],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    let row_bytes = crate::formats::pack::packed_len(d, 4);
    check_kv(packed.len(), row_bytes, scales.len(), d, block, out.len());
    let mut codes = vec![0i8; out.len()];
    for (crow, prow) in codes.chunks_exact_mut(d).zip(packed.chunks_exact(row_bytes)) {
        crate::formats::pack::unpack_signed_into(prow, 4, crow);
    }
    kv_scale_i8_scalar(&codes, scales, d, block, out);
}

/// Dequantize rows of MXFP8 (E4M3) KV codes through a 256-entry decode
/// table: `out[i] = lut[code[i]] × 2^scale[i / block]` per row. AVX2 uses
/// a gathered table load; other levels run the scalar loop (the LUT fits
/// in L1, so the scalar path is already load-bound). Bit-identical to
/// [`kv_dequant_fp8_portable`].
pub fn kv_dequant_fp8(
    codes: &[u8],
    scales: &[i8],
    lut: &[f32],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    assert_eq!(lut.len(), 256, "fp8 decode LUT must cover every byte");
    check_kv(codes.len(), d, scales.len(), d, block, out.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds checked above; AVX2 presence runtime-verified.
        SimdLevel::Avx2 => unsafe { kv_lut_f32_avx2(codes, scales, lut, d, block, out) },
        _ => kv_lut_f32_scalar(codes, scales, lut, d, block, out),
    }
}

/// The portable reference for [`kv_dequant_fp8`].
pub fn kv_dequant_fp8_portable(
    codes: &[u8],
    scales: &[i8],
    lut: &[f32],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    assert_eq!(lut.len(), 256, "fp8 decode LUT must cover every byte");
    check_kv(codes.len(), d, scales.len(), d, block, out.len());
    kv_lut_f32_scalar(codes, scales, lut, d, block, out);
}

/// Level-dispatched `code × 2^scale` over unpacked i8 rows.
#[inline]
fn kv_scale_i8_dispatch(codes: &[i8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: lengths validated by the public entry points.
        SimdLevel::Avx2 => unsafe { kv_scale_i8_avx2(codes, scales, d, block, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: lengths validated by the public entry points.
        SimdLevel::Neon => unsafe { kv_scale_i8_neon(codes, scales, d, block, out) },
        _ => kv_scale_i8_scalar(codes, scales, d, block, out),
    }
}

/// Scalar core (also the differential oracle): per row, per scale block,
/// `out = code as f32 × 2^e`.
fn kv_scale_i8_scalar(codes: &[i8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    let sbr = d.div_ceil(block);
    for (r, (orow, crow)) in out.chunks_exact_mut(d).zip(codes.chunks_exact(d)).enumerate() {
        let srow = &scales[r * sbr..(r + 1) * sbr];
        for (b, (ob, cb)) in orow.chunks_mut(block).zip(crow.chunks(block)).enumerate() {
            let scale = crate::formats::exp2i(srow[b] as i32);
            for (o, &c) in ob.iter_mut().zip(cb.iter()) {
                *o = c as f32 * scale;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn kv_scale_i8_avx2(codes: &[i8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let sbr = d.div_ceil(block);
    let rows = out.len() / d;
    for r in 0..rows {
        let crow = codes.as_ptr().add(r * d);
        let orow = out.as_mut_ptr().add(r * d);
        for b in 0..sbr {
            let scale = crate::formats::exp2i(*scales.get_unchecked(r * sbr + b) as i32);
            let sv = _mm256_set1_ps(scale);
            let end = d.min((b + 1) * block);
            let mut i = b * block;
            // 8 lanes: sign-extend i8 → i32, convert, multiply — each step
            // exact, so vector and scalar results are bit-identical.
            while i + 8 <= end {
                let bytes = _mm_loadl_epi64(crow.add(i) as *const __m128i);
                let f = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes));
                _mm256_storeu_ps(orow.add(i), _mm256_mul_ps(f, sv));
                i += 8;
            }
            while i < end {
                *orow.add(i) = *crow.add(i) as f32 * scale;
                i += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn kv_scale_i8_neon(codes: &[i8], scales: &[i8], d: usize, block: usize, out: &mut [f32]) {
    use std::arch::aarch64::*;
    let sbr = d.div_ceil(block);
    let rows = out.len() / d;
    for r in 0..rows {
        let crow = codes.as_ptr().add(r * d);
        let orow = out.as_mut_ptr().add(r * d);
        for b in 0..sbr {
            let scale = crate::formats::exp2i(*scales.get_unchecked(r * sbr + b) as i32);
            let end = d.min((b + 1) * block);
            let mut i = b * block;
            while i + 8 <= end {
                let w = vmovl_s8(vld1_s8(crow.add(i)));
                let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
                let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
                vst1q_f32(orow.add(i), vmulq_n_f32(lo, scale));
                vst1q_f32(orow.add(i + 4), vmulq_n_f32(hi, scale));
                i += 8;
            }
            while i < end {
                *orow.add(i) = *crow.add(i) as f32 * scale;
                i += 1;
            }
        }
    }
}

/// Scalar LUT core for minifloat codes.
fn kv_lut_f32_scalar(
    codes: &[u8],
    scales: &[i8],
    lut: &[f32],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    let sbr = d.div_ceil(block);
    for (r, (orow, crow)) in out.chunks_exact_mut(d).zip(codes.chunks_exact(d)).enumerate() {
        let srow = &scales[r * sbr..(r + 1) * sbr];
        for (b, (ob, cb)) in orow.chunks_mut(block).zip(crow.chunks(block)).enumerate() {
            let scale = crate::formats::exp2i(srow[b] as i32);
            for (o, &c) in ob.iter_mut().zip(cb.iter()) {
                *o = lut[c as usize] * scale;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn kv_lut_f32_avx2(
    codes: &[u8],
    scales: &[i8],
    lut: &[f32],
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let sbr = d.div_ceil(block);
    let rows = out.len() / d;
    for r in 0..rows {
        let crow = codes.as_ptr().add(r * d);
        let orow = out.as_mut_ptr().add(r * d);
        for b in 0..sbr {
            let scale = crate::formats::exp2i(*scales.get_unchecked(r * sbr + b) as i32);
            let sv = _mm256_set1_ps(scale);
            let end = d.min((b + 1) * block);
            let mut i = b * block;
            // Gathered table loads fetch the identical f32 entries the
            // scalar loop indexes, so the multiply stays bit-identical.
            while i + 8 <= end {
                let bytes = _mm_loadl_epi64(crow.add(i) as *const __m128i);
                let idx = _mm256_cvtepu8_epi32(bytes);
                let vals = _mm256_i32gather_ps::<4>(lut.as_ptr(), idx);
                _mm256_storeu_ps(orow.add(i), _mm256_mul_ps(vals, sv));
                i += 8;
            }
            while i < end {
                *orow.add(i) = *lut.get_unchecked(*crow.add(i) as usize) * scale;
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::props::{run_cases, Gen};

    #[test]
    fn env_override_forces_portable() {
        for v in ["off", "OFF", " 0 ", "false", "portable", "none"] {
            assert_eq!(
                resolve_level(Some(v), SimdLevel::Avx2),
                SimdLevel::Portable,
                "MFQAT_SIMD={v}"
            );
        }
        assert_eq!(resolve_level(None, SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(resolve_level(Some("auto"), SimdLevel::Neon), SimdLevel::Neon);
        assert_eq!(resolve_level(Some("on"), SimdLevel::Portable), SimdLevel::Portable);
    }

    #[test]
    fn level_is_consistent_and_named() {
        // Whatever this process resolved to, repeated queries agree and the
        // name round-trips (smoke for the OnceLock path).
        let l = level();
        assert_eq!(level(), l);
        assert!(!l.name().is_empty());
    }

    #[test]
    fn prop_tile_mac_i16_matches_portable_bit_exact() {
        // The dispatched path (whatever this host runs) must produce
        // bit-identical i16 accumulators to the scalar oracle at every
        // tile shape, including ragged widths that exercise the tails.
        run_cases("tile_mac_i16 == portable", 48, |g: &mut Gen| {
            let stride = g.len(1, 40);
            let nl = g.rng.range(1, stride + 1);
            let kl = g.len(0, 33);
            let m: Vec<i16> = (0..kl)
                .map(|_| g.rng.range(0, 255) as i16 - 127)
                .collect();
            let w: Vec<i16> = (0..kl * stride)
                .map(|_| g.rng.range(0, 17) as i16 - 8)
                .collect();
            let init: Vec<i16> = (0..nl).map(|_| g.rng.range(0, 201) as i16 - 100).collect();
            let mut fast = init.clone();
            let mut slow = init;
            tile_mac_i16(&mut fast, &m, &w, stride);
            tile_mac_i16_portable(&mut slow, &m, &w, stride);
            if fast != slow {
                return Err(format!("i16 mismatch (stride={stride} nl={nl} kl={kl})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tile_mac_i32_matches_portable_bit_exact() {
        run_cases("tile_mac_i32 == portable", 48, |g: &mut Gen| {
            let stride = g.len(1, 40);
            let nl = g.rng.range(1, stride + 1);
            let kl = g.len(0, 33);
            let m: Vec<i32> = (0..kl).map(|_| g.rng.range(0, 255) as i32 - 127).collect();
            let w: Vec<i32> = (0..kl * stride)
                .map(|_| g.rng.range(0, 255) as i32 - 127)
                .collect();
            let init: Vec<i32> =
                (0..nl).map(|_| g.rng.range(0, 2001) as i32 - 1000).collect();
            let mut fast = init.clone();
            let mut slow = init;
            tile_mac_i32(&mut fast, &m, &w, stride);
            tile_mac_i32_portable(&mut slow, &m, &w, stride);
            if fast != slow {
                return Err(format!("i32 mismatch (stride={stride} nl={nl} kl={kl})"));
            }
            Ok(())
        });
    }

    #[test]
    fn tile_mac_handles_empty_and_zero_rows() {
        // kl = 0 and all-zero multipliers leave the accumulator untouched.
        let mut acc = vec![3i16; 8];
        tile_mac_i16(&mut acc, &[], &[], 8);
        assert_eq!(acc, vec![3i16; 8]);
        let w = vec![5i16; 2 * 8];
        tile_mac_i16(&mut acc, &[0, 0], &w, 8);
        assert_eq!(acc, vec![3i16; 8]);
        let mut acc32 = vec![-7i32; 5];
        tile_mac_i32(&mut acc32, &[0], &vec![9i32; 5], 5);
        assert_eq!(acc32, vec![-7i32; 5]);
    }

    #[test]
    fn tile_mac_known_values() {
        // 2 rows, stride 6, nl 5: acc[n] = m0*w0[n] + m1*w1[n].
        let w: Vec<i32> = vec![1, 2, 3, 4, 5, 99, -1, -2, -3, -4, -5, 99];
        let mut acc = vec![10i32; 5];
        tile_mac_i32(&mut acc, &[2, 3], &w, 6);
        assert_eq!(acc, vec![10 + 2 - 3, 10 + 4 - 6, 10 + 6 - 9, 10 + 8 - 12, 10 + 10 - 15]);
        let w16: Vec<i16> = w.iter().map(|&v| v as i16).collect();
        let mut acc16 = vec![10i16; 5];
        tile_mac_i16(&mut acc16, &[2, 3], &w16, 6);
        assert_eq!(acc16, vec![9, 8, 7, 6, 5]);
    }

    /// Random `rows × ceil(d/block)` scale rows spanning the full E8M0-ish
    /// exponent range the KV encoder emits.
    fn gen_scales(g: &mut Gen, rows: usize, d: usize, block: usize) -> Vec<i8> {
        (0..rows * d.div_ceil(block))
            .map(|_| (g.rng.range(0, 61) as i32 - 30) as i8)
            .collect()
    }

    #[test]
    fn prop_kv_dequant_i8_matches_portable_bit_exact() {
        // The dispatched dequant (whatever this host runs) must produce
        // bit-identical f32 rows to the scalar oracle at every row shape,
        // including ragged final scale blocks and sub-lane widths.
        run_cases("kv_dequant_i8 == portable", 48, |g: &mut Gen| {
            let d = g.len(1, 80);
            let block = g.len(1, 40);
            let rows = g.len(1, 5);
            let codes: Vec<u8> = (0..rows * d).map(|_| g.rng.range(0, 256) as u8).collect();
            let scales = gen_scales(g, rows, d, block);
            let mut fast = vec![0.0f32; rows * d];
            let mut slow = vec![f32::NAN; rows * d];
            kv_dequant_i8(&codes, &scales, d, block, &mut fast);
            kv_dequant_i8_portable(&codes, &scales, d, block, &mut slow);
            if fast.iter().zip(&slow).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("i8 mismatch (d={d} block={block} rows={rows})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_kv_dequant_i4_matches_portable_bit_exact() {
        run_cases("kv_dequant_i4 == portable", 48, |g: &mut Gen| {
            let d = g.len(1, 80);
            let block = g.len(1, 40);
            let rows = g.len(1, 5);
            let row_bytes = crate::formats::pack::packed_len(d, 4);
            let packed: Vec<u8> = (0..rows * row_bytes)
                .map(|_| g.rng.range(0, 256) as u8)
                .collect();
            let scales = gen_scales(g, rows, d, block);
            let mut fast = vec![0.0f32; rows * d];
            let mut slow = vec![f32::NAN; rows * d];
            kv_dequant_i4(&packed, &scales, d, block, &mut fast);
            kv_dequant_i4_portable(&packed, &scales, d, block, &mut slow);
            if fast.iter().zip(&slow).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("i4 mismatch (d={d} block={block} rows={rows})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_kv_dequant_fp8_matches_portable_bit_exact() {
        let spec = crate::formats::FpSpec::new(4, 3);
        let lut: Vec<f32> = (0..=255u8).map(|b| spec.decode(b)).collect();
        run_cases("kv_dequant_fp8 == portable", 48, |g: &mut Gen| {
            let d = g.len(1, 80);
            let block = g.len(1, 40);
            let rows = g.len(1, 5);
            // Codes stay off the E4M3 NaN encodings (S.1111.111) the way
            // the KV encoder guarantees, so bit-compare is meaningful.
            let codes: Vec<u8> = (0..rows * d)
                .map(|_| loop {
                    let c = g.rng.range(0, 256) as u8;
                    if c & 0x7f != 0x7f {
                        break c;
                    }
                })
                .collect();
            let scales = gen_scales(g, rows, d, block);
            let mut fast = vec![0.0f32; rows * d];
            let mut slow = vec![f32::NAN; rows * d];
            kv_dequant_fp8(&codes, &scales, &lut, d, block, &mut fast);
            kv_dequant_fp8_portable(&codes, &scales, &lut, d, block, &mut slow);
            if fast.iter().zip(&slow).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("fp8 mismatch (d={d} block={block} rows={rows})"));
            }
            Ok(())
        });
    }

    #[test]
    fn kv_dequant_known_values() {
        // d=4, block=2, one row: codes scale per 2-channel block.
        let codes: Vec<u8> = [1i8, -2, 3, 127].iter().map(|&c| c as u8).collect();
        let scales = [1i8, -1];
        let mut out = [0.0f32; 4];
        kv_dequant_i8(&codes, &scales, 4, 2, &mut out);
        assert_eq!(out, [2.0, -4.0, 1.5, 63.5]);

        // Nibble path: pack [-8, 7] into one byte, unit scale.
        let packed = crate::formats::pack::pack(&[-8, 7], 4);
        let mut out4 = [0.0f32; 2];
        kv_dequant_i4(&packed, &[0i8], 2, 32, &mut out4);
        assert_eq!(out4, [-8.0, 7.0]);

        // LUT path: fp8 code 0 decodes to +0 regardless of scale.
        let spec = crate::formats::FpSpec::new(4, 3);
        let lut: Vec<f32> = (0..=255u8).map(|b| spec.decode(b)).collect();
        let one = spec.quantize_code(1.0);
        let mut outf = [9.0f32; 2];
        kv_dequant_fp8(&[0u8, one], &[3i8], &lut, 2, 32, &mut outf);
        assert_eq!(outf, [0.0, 8.0]);
    }
}
