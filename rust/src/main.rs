//! `mfqat` — CLI for the MF-QAT elastic-inference stack.
//!
//! Subcommands:
//!   info                         inspect artifacts + manifest
//!   pretrain                     train the base LM (needs `pjrt`)
//!   train --plan <name>          run a QAT/FT plan (needs `pjrt`)
//!   eval --checkpoint <p>        PPL grid for a checkpoint (native or pjrt)
//!   generate --prompt <s>        sample a continuation (native: KV-cached
//!                                incremental decode; pjrt: AOT forward_b1)
//!   convert --in <p> --format f  Slice-and-Scale convert a checkpoint
//!   inspect --checkpoint <p>     dump checkpoint contents
//!   serve                        run the elastic server demo workload
//!   experiment <id>              regenerate a paper figure/table (or `all`)
//!
//! Global options: --config tiny|small|base (default tiny), --root <dir>,
//! --seed N, --lrs a,b,c, --backend native|pjrt (default native).
//!
//! The default build carries only the native packed-MX backend: `serve` and
//! `eval` work with no AOT artifacts and no XLA install. Training and the
//! full experiment matrix execute AOT graphs and need `--features pjrt`.

use anyhow::{anyhow, Context, Result};
use mfqat::backend::ActMode;
use mfqat::checkpoint::Checkpoint;
use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::runtime::Manifest;
use mfqat::server::{GenBatching, Policy, Server, ServerConfig};
use mfqat::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    mfqat::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn repo_root(args: &Args) -> PathBuf {
    args.get("root")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")))
}

/// Model dims for `--config`: artifact manifest when present, else the
/// built-in config table (native backend needs no artifacts at all).
fn resolve_dims(args: &Args) -> Result<ModelDims> {
    let config = args.get_or("config", "tiny").to_string();
    let arts_dir = repo_root(args).join("artifacts").join(&config);
    if arts_dir.join("manifest.json").exists() {
        Ok(ModelDims::from_manifest(&Manifest::load(&arts_dir)?))
    } else {
        ModelDims::by_name(&config).ok_or_else(|| {
            anyhow!(
                "unknown config '{config}' and no artifacts at {}",
                arts_dir.display()
            )
        })
    }
}

#[cfg(feature = "pjrt")]
fn open_ctx(args: &Args) -> Result<mfqat::experiments::Ctx> {
    let config = args.get_or("config", "tiny").to_string();
    let seed = args.u64("seed", 20260710)?;
    let mut ctx = mfqat::experiments::Ctx::open(&repo_root(args), &config, seed)?;
    if let Some(lrs) = args.list("lrs") {
        ctx.lrs = lrs
            .iter()
            .map(|s| s.parse::<f32>().map_err(|_| anyhow!("bad lr '{s}'")))
            .collect::<Result<_>>()?;
    }
    ctx.pretrain_epochs = args.usize("pretrain-epochs", ctx.pretrain_epochs)?;
    ctx.task_items = args.usize("task-items", ctx.task_items)?;
    Ok(ctx)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "pretrain" => pretrain_cmd(&args),
        "train" => train_cmd(&args),
        "eval" => eval_cmd(&args),
        "generate" => generate_cmd(&args),
        "convert" => convert(&args),
        "inspect" => inspect(&args),
        "serve" => serve(&args),
        "experiment" => experiment_cmd(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "mfqat — Multi-Format QAT for Elastic Inference (paper reproduction)

USAGE: mfqat <command> [--config tiny] [--root DIR] [options]

COMMANDS:
  info                              show model config (+ artifact manifest)
  pretrain [--pretrain-epochs N]    train the base LM (needs --features pjrt)
  train --plan <name> [--lr X]      run a training plan (needs --features pjrt)
  eval --checkpoint P [--formats..] PPL grid for a checkpoint
                                    [--backend native|pjrt] [--act f32|int8]
  generate [--checkpoint P] --prompt S [--format F] [--tokens N] [--temp X]
                                    sample a continuation; the native backend
                                    (default) decodes through the paged KV
                                    cache [--backend native|pjrt]
                                    [--act f32|int8] [--kv-page N]
                                    [--kv-format f32|mxint8|mxfp8|mxint4]
  convert --in P --format F --out Q Slice-and-Scale convert an anchor checkpoint
  inspect --checkpoint P            dump checkpoint metadata
  serve [--policy ladder] [--requests N] [--burst N] [--backend native|pjrt]
        [--checkpoint P] [--cache-mb N] [--act f32|int8] [--workers N]
        [--gen-requests N] [--gen-tokens N]
        [--batching continuous|gather] [--slots N] [--kv-page N]
        [--kv-format f32|mxint8|mxfp8|mxint4]
        [--spec k=4,draft=mxint4[,policy=greedy|stochastic]]
        [--trace-out PATH] [--metrics-out PATH]
                                    run the elastic serving demo workload:
                                    N workers share one engine; scoring and
                                    generation requests interleave. The
                                    generate lane defaults to continuous
                                    batching (per-row formats, mid-flight
                                    joins into --slots decode rows; KV paged
                                    at --kv-page positions per page, stored
                                    at --kv-format: f32 dense by default or
                                    MX-coded int8/fp8/int4 pages that cut
                                    resident KV ~4-8x);
                                    --batching gather restores the legacy
                                    grouped batched decode. --spec turns on
                                    self-speculative decoding: rows draft k
                                    tokens at the cheap format and verify
                                    at their own serving format, emitting
                                    up to k+1 tokens/step. --trace-out
                                    writes a Chrome-trace JSON of every
                                    request lifecycle (Perfetto-loadable);
                                    --metrics-out writes a JSON metrics
                                    snapshot (+ .prom Prometheus text)
                                    periodically and at shutdown
  experiment <id>                   regenerate a paper figure/table; id in
                                    fig1 fig2 fig3 fig4 tab1 tab2 tab3 fig19 fig20 all
                                    (fig19/fig20 run natively; the rest need pjrt)

The native backend serves packed MX weights directly — no XLA install and
no AOT artifacts required.
";

fn info(args: &Args) -> Result<()> {
    let root = repo_root(args);
    let config = args.get_or("config", "tiny");
    let arts_dir = root.join("artifacts").join(config);
    let dims = resolve_dims(args)?;
    println!(
        "config {}: d_model={} layers={} heads={} seq={} vocab={} d_ff={} block={}",
        dims.name,
        dims.d_model,
        dims.n_layers,
        dims.n_heads,
        dims.seq_len,
        dims.vocab,
        dims.d_ff,
        dims.block_size
    );
    let m = dims.to_manifest();
    println!(
        "params: {} tensors, {} total ({} quantized tensors)",
        m.params.len(),
        m.n_params,
        m.quant_indices().len()
    );
    if arts_dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&arts_dir)?;
        println!("artifacts:");
        for (name, a) in &manifest.artifacts {
            println!("  {name:<20} {}", a.file);
        }
    } else {
        println!("artifacts: none (native backend only)");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pretrain_cmd(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let p = ctx.ensure_pretrained()?;
    println!("pretrained: {} params, val ppl {:.3}", p.n_params(), ctx.val_ppl(&p)?);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pretrain_cmd(_args: &Args) -> Result<()> {
    anyhow::bail!("`pretrain` executes AOT train-step graphs — rebuild with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn train_cmd(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let plan = args
        .get("plan")
        .ok_or_else(|| anyhow!("--plan required (e.g. mf_int, qat_int4, ft_fp_int)"))?;
    let params = if let Some(lr) = args.get("lr") {
        ctx.ensure_variant(plan, lr.parse().context("--lr")?)?
    } else {
        ctx.ensure_variant_best(plan)?
    };
    println!("trained {plan}: val ppl {:.3}", ctx.val_ppl(&params)?);
    // Also emit the anchor checkpoints for serving.
    for (anchor, name) in [
        (ElementFormat::int(8), "int8"),
        (ElementFormat::fp_from_bits(8), "fp8"),
    ] {
        let ck = params.to_anchor_checkpoint(&ctx.arts.manifest, anchor)?;
        let path = ctx.runs_dir.join(format!("anchor_{plan}_{name}.mfq"));
        ck.save(&path)?;
        println!(
            "anchor checkpoint ({}): {} ({} KB)",
            anchor,
            path.display(),
            ck.storage_bytes() / 1024
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn train_cmd(_args: &Args) -> Result<()> {
    anyhow::bail!("`train` executes AOT train-step graphs — rebuild with `--features pjrt`")
}

fn eval_cmd(args: &Args) -> Result<()> {
    match args.get_or("backend", "native") {
        "native" => eval_native(args),
        "pjrt" => {
            reject_act_for_pjrt(args)?;
            eval_pjrt(args)
        }
        other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
    }
}

/// `--act` selects the native integer-MAC pipeline; the PJRT graph always
/// executes dequantized f32, so a non-default act mode there would silently
/// measure the wrong thing — refuse instead.
fn reject_act_for_pjrt(args: &Args) -> Result<()> {
    if ActMode::parse(args.get_or("act", "f32"))? != ActMode::F32 {
        anyhow::bail!("--act int8 is a native-backend pipeline; the pjrt backend runs f32 only");
    }
    Ok(())
}

/// Native PPL grid: score the validation split through the packed-MX
/// forward — works with no artifacts and no XLA.
fn eval_native(args: &Args) -> Result<()> {
    use mfqat::backend::NativeWeights;
    let dims = resolve_dims(args)?;
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    let fmts = parse_formats(args)?;
    // Only the validation split is scored; keep the unused splits tiny.
    let corpus = Corpus::generate(CorpusConfig {
        seed: args.u64("seed", 20260710)?,
        width: dims.seq_len + 1,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 64,
    });
    let act = ActMode::parse(args.get_or("act", "f32"))?;
    println!(
        "{:<14} {:>10}   (native backend, act={})",
        "format",
        "val_ppl",
        act.name()
    );
    let dense = NativeWeights::dense_from_checkpoint(&dims, &ck, None)?;
    println!(
        "{:<14} {:>10.3}",
        "fp32",
        mfqat::eval::perplexity_native(&dense, &corpus.val, dims.train_batch)?
    );
    // One shared f32 set for the whole grid; per-format cost is packed
    // planes only.
    let shared = std::sync::Arc::new(mfqat::backend::SharedParams::from_checkpoint(&dims, &ck)?);
    for fmt in fmts {
        let w = NativeWeights::packed_with_shared(&dims, &ck, fmt, shared.clone(), act)?;
        println!(
            "{:<14} {:>10.3}",
            fmt.long_name(),
            mfqat::eval::perplexity_native(&w, &corpus.val, dims.train_batch)?
        );
    }
    Ok(())
}

fn parse_formats(args: &Args) -> Result<Vec<ElementFormat>> {
    match args.list("formats") {
        Some(list) => list
            .iter()
            .map(|s| ElementFormat::parse(s))
            .collect::<Result<_>>(),
        None => Ok(ElementFormat::all_int()),
    }
}

#[cfg(feature = "pjrt")]
fn eval_pjrt(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    let params = ParamSet::from_checkpoint(&ctx.arts.manifest, &ck, None)?;
    let fmts = parse_formats(args)?;
    println!("{:<14} {:>10}   (pjrt backend)", "format", "val_ppl");
    println!("{:<14} {:>10.3}", "fp32", ctx.val_ppl(&params)?);
    for fmt in fmts {
        let q = params.ptq(&ctx.arts.manifest, fmt)?;
        println!("{:<14} {:>10.3}", fmt.long_name(), ctx.val_ppl(&q)?);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn eval_pjrt(_args: &Args) -> Result<()> {
    anyhow::bail!("this build has no PJRT backend — rebuild with `--features pjrt`")
}

/// KV page-pool sizing from `--kv-page` (positions per page; falls back to
/// the `MFQAT_KV_PAGE` env pin, then the 64-position default). `--kv-page`
/// also pins the env var so engine paths that size their own caches (e.g.
/// `generate`'s solo decode) see the same page size. `--prefix-share` turns
/// on content-addressed prefix reuse (and pins `MFQAT_PREFIX_SHARE` for the
/// same reason), `--kv-retain` caps the prefix index's retained pages
/// (pins `MFQAT_KV_RETAIN`), `--kv-budget` caps each worker's
/// worst-case page claims — under multiple continuous workers the server
/// pools those budgets into one cross-worker page ledger — and
/// `--kv-format` selects the K/V page storage format (f32 dense default,
/// or MX-coded `mxint8`/`mxfp8`/`mxint4`; pins `MFQAT_KV_FORMAT`).
fn kv_page_cfg(args: &Args) -> Result<mfqat::backend::KvPageCfg> {
    let mut cfg = match args.get("kv-page") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| anyhow!("--kv-page expects a positive integer, got '{v}'"))?;
            if n == 0 {
                anyhow::bail!("--kv-page expects at least 1 position per page");
            }
            std::env::set_var("MFQAT_KV_PAGE", v);
            mfqat::backend::KvPageCfg::with_page(n)
        }
        None => mfqat::backend::KvPageCfg::from_env(),
    };
    if args.flag("prefix-share") {
        std::env::set_var("MFQAT_PREFIX_SHARE", "1");
        cfg = cfg.share(true);
    }
    if let Some(v) = args.get("kv-retain") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow!("--kv-retain expects an integer, got '{v}'"))?;
        std::env::set_var("MFQAT_KV_RETAIN", v);
        cfg = cfg.retain(n);
    }
    if let Some(v) = args.get("kv-budget") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow!("--kv-budget expects an integer, got '{v}'"))?;
        cfg = cfg.budget(n);
    }
    if let Some(v) = args.get("kv-format") {
        let f = mfqat::backend::KvFormat::parse(v)
            .ok_or_else(|| anyhow!("--kv-format expects f32|mxint8|mxfp8|mxint4, got '{v}'"))?;
        std::env::set_var("MFQAT_KV_FORMAT", f.name());
        cfg = cfg.format(f);
    }
    Ok(cfg)
}

/// Shared sampling knobs for both generation backends.
fn sample_cfg(args: &Args) -> Result<mfqat::eval::generate::SampleCfg> {
    Ok(mfqat::eval::generate::SampleCfg {
        temperature: args.f64("temp", 0.8)? as f32,
        top_k: args.usize("top-k", 8)?,
        seed: args.u64("seed", 0)?,
    })
}

fn generate_cmd(args: &Args) -> Result<()> {
    match args.get_or("backend", "native") {
        "native" => generate_native_cmd(args),
        "pjrt" => {
            reject_act_for_pjrt(args)?;
            generate_pjrt_cmd(args)
        }
        other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
    }
}

/// Native generation: prompt prefill + KV-cached incremental decode over
/// the packed weights — no artifacts, no XLA, no full-window recompute.
fn generate_native_cmd(args: &Args) -> Result<()> {
    let dims = resolve_dims(args)?;
    let ck_path = match args.get("checkpoint") {
        Some(p) => PathBuf::from(p),
        None => default_anchor_checkpoint(args, &dims)?,
    };
    let prompt = args.get_or("prompt", "the color of kova is").to_string();
    // Pins MFQAT_KV_PAGE when --kv-page is given, so the engine's decode
    // cache pages accordingly.
    kv_page_cfg(args)?;
    let act = ActMode::parse(args.get_or("act", "f32"))?;
    let fmt = args
        .get("format")
        .map(ElementFormat::parse)
        .transpose()?
        .unwrap_or(ElementFormat::int(8));
    let cfg = sample_cfg(args)?;
    let n = args.usize("tokens", 64)?;
    let cache_bytes = args.usize("cache-mb", 256)? << 20;
    let engine =
        ElasticEngine::open_native_with_act(dims, &ck_path, cache_bytes, act)?;
    let out = engine.generate(&prompt, fmt, n, &cfg)?;
    println!("{prompt}│{out}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn generate_pjrt_cmd(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let prompt = args.get_or("prompt", "the color of kova is");
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    let fmt = args
        .get("format")
        .map(ElementFormat::parse)
        .transpose()?;
    let params = ParamSet::from_checkpoint(&ctx.arts.manifest, &ck, fmt)?;
    let lits = mfqat::eval::ParamLiterals::build(&params)?;
    let cfg = sample_cfg(args)?;
    let n = args.usize("tokens", 64)?;
    let out = mfqat::eval::generate::generate(&ctx.rt, &ctx.arts, &lits, prompt, n, &cfg)?;
    println!("{prompt}│{out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn generate_pjrt_cmd(_args: &Args) -> Result<()> {
    anyhow::bail!("this build has no PJRT backend — rebuild with `--features pjrt`")
}

fn convert(args: &Args) -> Result<()> {
    let input = args.get("in").ok_or_else(|| anyhow!("--in required"))?;
    let output = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let fmt = ElementFormat::parse(
        args.get("format")
            .ok_or_else(|| anyhow!("--format required"))?,
    )?;
    let ck = Checkpoint::load(&PathBuf::from(input))?;
    let mut out = Checkpoint::new();
    out.meta = ck.meta.clone();
    out.set_meta("anchor", mfqat::util::json::Json::from(fmt.name()));
    out.raw = ck.raw.clone();
    let t = std::time::Instant::now();
    let mut converted = 0usize;
    for (name, tensor) in &ck.tensors {
        let q = if tensor.format.elem == fmt {
            tensor.clone()
        } else {
            tensor.slice_and_scale(fmt).with_context(|| name.clone())?
        };
        converted += q.len();
        out.insert(name, q);
    }
    out.save(&PathBuf::from(output))?;
    println!(
        "slice-and-scale {} -> {}: {} elements in {:.1} ms ({} KB -> {} KB)",
        input,
        output,
        converted,
        t.elapsed().as_secs_f64() * 1e3,
        ck.storage_bytes() / 1024,
        out.storage_bytes() / 1024,
    );
    Ok(())
}

fn inspect(args: &Args) -> Result<()> {
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    println!("meta:");
    for (k, v) in &ck.meta {
        println!("  {k} = {}", v.to_string());
    }
    println!("mx tensors ({}):", ck.tensors.len());
    for (name, t) in &ck.tensors {
        println!(
            "  {name:<14} {:?} {} ({} bytes packed)",
            t.shape,
            t.format,
            t.storage_bytes()
        );
    }
    println!("raw tensors ({}):", ck.raw.len());
    for (name, t) in &ck.raw {
        println!("  {name:<14} {:?} f32 ({} bytes)", t.shape, t.len() * 4);
    }
    println!("total storage: {} KB", ck.storage_bytes() / 1024);
    Ok(())
}

/// Base weights for the serving demo: a pretrained checkpoint when one is
/// available (training it first under `pjrt` if artifacts exist), else a
/// random init — the serving path itself is identical either way.
fn base_params(args: &Args, manifest: &Manifest) -> Result<ParamSet> {
    let root = repo_root(args);
    let pre = root
        .join("runs")
        .join(&manifest.config_name)
        .join("pretrained.mfq");
    if pre.exists() {
        let ck = Checkpoint::load(&pre)?;
        return ParamSet::from_checkpoint(manifest, &ck, None);
    }
    #[cfg(feature = "pjrt")]
    if root
        .join("artifacts")
        .join(&manifest.config_name)
        .join("manifest.json")
        .exists()
    {
        let ctx = open_ctx(args)?;
        return ctx.ensure_pretrained();
    }
    log::warn!("no pretrained base found — serving random-init weights");
    Ok(ParamSet::init(manifest, args.u64("seed", 20260710)?))
}

/// Build (or reuse) the demo anchor checkpoint.
fn default_anchor_checkpoint(args: &Args, dims: &ModelDims) -> Result<PathBuf> {
    let runs_dir = repo_root(args).join("runs").join(&dims.name);
    let path = runs_dir.join("anchor_serve_int8.mfq");
    if path.exists() {
        return Ok(path);
    }
    let manifest = dims.to_manifest();
    let params = base_params(args, &manifest)?;
    std::fs::create_dir_all(&runs_dir)?;
    params
        .to_anchor_checkpoint(&manifest, ElementFormat::int(8))?
        .save(&path)?;
    Ok(path)
}

#[cfg(feature = "pjrt")]
fn pjrt_engine(root: &Path, config: &str, ck: &Path, cache_bytes: usize) -> Result<ElasticEngine> {
    ElasticEngine::open(&root.join("artifacts").join(config), ck, cache_bytes)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_engine(
    _root: &Path,
    _config: &str,
    _ck: &Path,
    _cache_bytes: usize,
) -> Result<ElasticEngine> {
    anyhow::bail!("this build has no PJRT backend — rebuild with `--features pjrt`")
}

/// Serving demo: fire a bursty synthetic workload — scoring plus optional
/// batched-generation requests — at the elastic server pool and report the
/// precision mix + latency profile.
fn serve(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "native").to_string();
    let policy = Policy::parse(args.get_or("policy", "ladder"))?;
    let n_requests = args.usize("requests", 256)?;
    let burst = args.usize("burst", 32)?;
    let workers = args.usize("workers", 1)?;
    let gen_requests = args.usize("gen-requests", 0)?;
    let gen_tokens = args.usize("gen-tokens", 16)?;
    let batching = GenBatching::parse(args.get_or("batching", "continuous"))?;
    let decode_slots = args.usize("slots", 0)?;
    let queue_cap = args.usize("queue-cap", 0)?;
    let shutdown_grace = std::time::Duration::from_millis(args.u64("shutdown-grace-ms", 5000)?);
    let kv_page = kv_page_cfg(args)?;
    let spec = args
        .get("spec")
        .map(mfqat::eval::generate::SpecCfg::parse)
        .transpose()?;
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let act = ActMode::parse(args.get_or("act", "f32"))?;
    if backend == "pjrt" {
        reject_act_for_pjrt(args)?;
    }
    let cache_bytes = args.usize("cache-mb", 256)? << 20;
    let dims = resolve_dims(args)?;
    let width = dims.seq_len + 1;

    let ck_path = match args.get("checkpoint") {
        Some(p) => PathBuf::from(p),
        None => default_anchor_checkpoint(args, &dims)?,
    };

    let root = repo_root(args);
    let config = args.get_or("config", "tiny").to_string();
    let dims_worker = dims.clone();
    let (server, client) = Server::start(
        width,
        move || match backend.as_str() {
            "native" => {
                ElasticEngine::open_native_with_act(dims_worker, &ck_path, cache_bytes, act)
            }
            "pjrt" => pjrt_engine(&root, &config, &ck_path, cache_bytes),
            other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
        },
        ServerConfig {
            policy,
            gather_window: std::time::Duration::from_millis(2),
            workers,
            batching,
            decode_slots,
            kv_page,
            trace_out: trace_out.clone(),
            metrics_out: metrics_out.clone(),
            queue_cap,
            shutdown_grace,
            spec,
            ..ServerConfig::default()
        },
    )?;

    let corpus = Corpus::generate(CorpusConfig {
        seed: 42,
        width,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: n_requests.div_ceil(64).max(1) * 64,
    });
    println!(
        "firing {n_requests} score requests in bursts of {burst} \
         (+{gen_requests} generate) across {workers} worker(s)…"
    );
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut pending_gen = Vec::new();
    let mut sent = 0usize;
    let mut gen_sent = 0usize;
    let gen_cfg = sample_cfg(args)?;
    let gen_prompts = ["the color of kova is", "kovaq", "blue sky", "q"];
    // Generation traffic rides along in slices per score burst; a pure
    // generation workload (--requests 0) still drains through the loop.
    let bursts = n_requests.div_ceil(burst.max(1)).max(1);
    let gen_share = gen_requests.div_ceil(bursts).max(1);
    // Per-request failures (a worker died mid-batch, a deadline passed, the
    // bounded queue shed the request) are counted instead of aborting the
    // demo — the same loop doubles as the fault-injection smoke workload.
    let mut rejected = 0usize;
    let mut failed = 0usize;
    while sent < n_requests || gen_sent < gen_requests {
        for _ in 0..burst.max(1).min(n_requests - sent) {
            let row = &corpus.val[sent % corpus.val.len()];
            match client.submit(row, None) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    log::warn!("score submit shed: {e:#}");
                    rejected += 1;
                }
            }
            sent += 1;
        }
        for _ in 0..gen_share.min(gen_requests - gen_sent) {
            let prompt = gen_prompts[gen_sent % gen_prompts.len()];
            match client.submit_generate(prompt, gen_tokens, None, gen_cfg.clone()) {
                Ok(rx) => pending_gen.push(rx),
                Err(e) => {
                    log::warn!("generate submit shed: {e:#}");
                    rejected += 1;
                }
            }
            gen_sent += 1;
        }
        // Drain this burst.
        for rx in pending.drain(..) {
            match rx.recv() {
                Ok(Ok(resp)) => log::debug!(
                    "nll {:.3} fmt {} batch {} depth {}",
                    resp.nll,
                    resp.format,
                    resp.batch_size,
                    resp.queue_depth
                ),
                Ok(Err(e)) => {
                    log::warn!("score request failed: {e}");
                    failed += 1;
                }
                Err(_) => {
                    log::warn!("score request dropped by server");
                    failed += 1;
                }
            }
        }
        for rx in pending_gen.drain(..) {
            match rx.recv() {
                Ok(Ok(resp)) => log::debug!(
                    "gen {:?} fmt {} batch {}",
                    resp.text,
                    resp.format,
                    resp.batch_size
                ),
                Ok(Err(e)) => {
                    log::warn!("generate request failed: {e}");
                    failed += 1;
                }
                Err(_) => {
                    log::warn!("generate request dropped by server");
                    failed += 1;
                }
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let metrics = server.metrics();
    println!(
        "done: {} requests in {:.2}s ({:.1} req/s)",
        metrics.requests,
        elapsed,
        metrics.requests as f64 / elapsed
    );
    println!("  {}", metrics.summary());
    println!("  format conversions performed: {}", metrics.conversions());
    if rejected + failed > 0 {
        println!("  degraded service: {rejected} shed at submit, {failed} failed in flight");
    }
    drop(client);
    server.shutdown();
    if let Some(p) = &trace_out {
        println!("  trace written to {} (load in Perfetto / chrome://tracing)", p.display());
    }
    if let Some(p) = &metrics_out {
        println!(
            "  metrics snapshot written to {} (+ {})",
            p.display(),
            p.with_extension("prom").display()
        );
    }
    Ok(())
}

fn experiment_cmd(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| {
            anyhow!("usage: mfqat experiment <fig1|fig2|fig3|fig4|tab1|tab2|tab3|fig19|fig20|all>")
        })?
        .clone();
    // Tensor-level SS fidelity sweeps need no model runtime at all.
    if id == "fig19" || id == "fig20" {
        let results = repo_root(args)
            .join("results")
            .join(args.get_or("config", "tiny"));
        std::fs::create_dir_all(&results)?;
        let family = if id == "fig19" { "int" } else { "fp" };
        return mfqat::experiments::ss_eval::fig19_or_20(family, &results.join(&id));
    }
    experiment_pjrt(args, &id)
}

#[cfg(feature = "pjrt")]
fn experiment_pjrt(args: &Args, id: &str) -> Result<()> {
    let ctx = open_ctx(args)?;
    mfqat::experiments::run(&ctx, id)
}

#[cfg(not(feature = "pjrt"))]
fn experiment_pjrt(_args: &Args, id: &str) -> Result<()> {
    anyhow::bail!("experiment '{id}' trains/evaluates through AOT graphs — rebuild with `--features pjrt`")
}
