//! LRU cache of per-format serving weight sets.
//!
//! Elastic serving switches formats with load; re-deriving weights on every
//! batch would waste the SS + dequant work, while caching every format at
//! full f32 costs memory. The cache bounds total bytes and evicts the least
//! recently used format.

use crate::eval::ParamLiterals;
use crate::formats::ElementFormat;
use std::collections::HashMap;
use std::sync::Arc;

/// Byte-bounded LRU over derived weight sets.
pub struct FormatCache {
    budget: usize,
    used: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    entries: HashMap<ElementFormat, Entry>,
}

struct Entry {
    weights: Arc<ParamLiterals>,
    bytes: usize,
    last_used: u64,
}

impl FormatCache {
    pub fn new(budget_bytes: usize) -> FormatCache {
        FormatCache {
            budget: budget_bytes,
            used: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            entries: HashMap::new(),
        }
    }

    pub fn get(&mut self, fmt: ElementFormat) -> Option<Arc<ParamLiterals>> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(&fmt) {
            Some(e) => {
                e.last_used = clock;
                self.hits += 1;
                Some(e.weights.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, fmt: ElementFormat, weights: Arc<ParamLiterals>, bytes: usize) {
        self.clock += 1;
        if let Some(old) = self.entries.remove(&fmt) {
            self.used -= old.bytes;
        }
        // Evict LRU entries until the new set fits (but always admit it —
        // an over-budget single entry is still better than re-deriving
        // every batch).
        while self.used + bytes > self.budget && !self.entries.is_empty() {
            let lru = *self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
                .unwrap();
            let e = self.entries.remove(&lru).unwrap();
            self.used -= e.bytes;
            log::debug!("format cache: evicted {lru} ({} bytes)", e.bytes);
        }
        self.used += bytes;
        self.entries.insert(
            fmt,
            Entry {
                weights,
                bytes,
                last_used: self.clock,
            },
        );
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> Arc<ParamLiterals> {
        Arc::new(ParamLiterals { literals: vec![] })
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = FormatCache::new(1000);
        assert!(c.get(ElementFormat::int(4)).is_none());
        c.put(ElementFormat::int(4), dummy(), 100);
        assert!(c.get(ElementFormat::int(4)).is_some());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.used_bytes(), 100);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = FormatCache::new(250);
        c.put(ElementFormat::int(2), dummy(), 100);
        c.put(ElementFormat::int(4), dummy(), 100);
        // Touch int2 so int4 becomes LRU.
        c.get(ElementFormat::int(2));
        c.put(ElementFormat::int(6), dummy(), 100);
        assert!(c.get(ElementFormat::int(2)).is_some());
        assert!(c.get(ElementFormat::int(4)).is_none(), "int4 evicted");
        assert!(c.get(ElementFormat::int(6)).is_some());
        assert!(c.used_bytes() <= 250);
    }

    #[test]
    fn oversized_entry_still_admitted() {
        let mut c = FormatCache::new(50);
        c.put(ElementFormat::int(8), dummy(), 500);
        assert_eq!(c.len(), 1);
        assert!(c.get(ElementFormat::int(8)).is_some());
    }

    #[test]
    fn replace_same_format_updates_bytes() {
        let mut c = FormatCache::new(1000);
        c.put(ElementFormat::int(4), dummy(), 100);
        c.put(ElementFormat::int(4), dummy(), 300);
        assert_eq!(c.used_bytes(), 300);
        assert_eq!(c.len(), 1);
    }
}
