"""Microscaling format descriptors shared by the L1 kernels and L2 model.

Mirrors ``rust/src/formats`` exactly:

* MXINT(b), b in 2..8:  emax = b - 2, elements in [-(2^(b-1)), 2^(b-1)-1].
* MXFP(b):  4->E2M1, 5->E2M2, 6->E3M2, 7->E3M3, 8->E4M3;  emax = 2^(eta-1);
  E4M3 follows OCP (max normal 448).

The numeric behaviour (shared-exponent extraction, RNE, saturation) lives in
``kernels/ref.py``; this module is only the format algebra.
"""

from dataclasses import dataclass

# Paper's MXFP bitwidth -> (exponent bits, mantissa bits).
MXFP_BITS = {4: (2, 1), 5: (2, 2), 6: (3, 2), 7: (3, 3), 8: (4, 3)}

# Scale exponent storage range (E8M0-like, matches rust SCALE_EXP_MIN/MAX).
# The lower bound is -126 (not -127): XLA CPU flushes subnormal f32 to zero,
# so a 2^-127 scale would decode differently between the jnp oracle (FTZ)
# and the bit-exact rust path. Clamping to the normal range keeps the two
# implementations bit-identical; blocks this small are zero-for-all-purposes.
SCALE_EXP_MIN = -126
SCALE_EXP_MAX = 127


@dataclass(frozen=True)
class ElementFormat:
    """An MX element format: ``kind`` is 'int' or 'fp'."""

    kind: str
    bits: int  # total bits including sign

    def __post_init__(self):
        if self.kind == "int":
            assert 2 <= self.bits <= 8, self.bits
        elif self.kind == "fp":
            assert self.bits in MXFP_BITS, self.bits
        else:
            raise ValueError(f"bad kind {self.kind}")

    # ------------------------------------------------------------ properties
    @property
    def exp_bits(self) -> int:
        assert self.kind == "fp"
        return MXFP_BITS[self.bits][0]

    @property
    def man_bits(self) -> int:
        assert self.kind == "fp"
        return MXFP_BITS[self.bits][1]

    @property
    def emax(self) -> int:
        """Exponent of the largest normal number (paper e_max(f))."""
        if self.kind == "int":
            return self.bits - 2
        return 1 << (self.exp_bits - 1)

    @property
    def bias(self) -> int:
        assert self.kind == "fp"
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emin(self) -> int:
        """Smallest normal exponent."""
        assert self.kind == "fp"
        return 1 - self.bias

    @property
    def is_e4m3(self) -> bool:
        return self.kind == "fp" and MXFP_BITS[self.bits] == (4, 3)

    @property
    def max_value(self) -> float:
        """Largest representable element magnitude."""
        if self.kind == "int":
            return float((1 << (self.bits - 1)) - 1)
        m = self.man_bits
        if self.is_e4m3:
            # OCP E4M3: top mantissa code at top exponent is NaN -> 448.
            return (2.0 - 2.0 ** (-m) * 2.0) * 2.0 ** self.emax
        return (2.0 - 2.0 ** (-m)) * 2.0 ** self.emax

    @property
    def int_range(self):
        assert self.kind == "int"
        half = 1 << (self.bits - 1)
        return (-half, half - 1)

    @property
    def name(self) -> str:
        return f"{self.kind}{self.bits}"

    @property
    def long_name(self) -> str:
        if self.kind == "int":
            return f"MXINT{self.bits}"
        e, m = MXFP_BITS[self.bits]
        return f"MXFP{self.bits}(E{e}M{m})"


def mxint(bits: int) -> ElementFormat:
    return ElementFormat("int", bits)


def mxfp(bits: int) -> ElementFormat:
    return ElementFormat("fp", bits)


def parse(name: str) -> ElementFormat:
    n = name.strip().lower()
    for prefix in ("mxint", "int"):
        if n.startswith(prefix) and n[len(prefix):].isdigit():
            bits = int(n[len(prefix):])
            if not 2 <= bits <= 8:
                raise ValueError(f"MXINT bits must be 2..8, got {bits}")
            return mxint(bits)
    for prefix in ("mxfp", "fp"):
        if n.startswith(prefix) and n[len(prefix):].isdigit():
            bits = int(n[len(prefix):])
            if bits not in MXFP_BITS:
                raise ValueError(f"MXFP bits must be 4..8, got {bits}")
            return mxfp(bits)
    raise ValueError(f"unknown format {name!r}")


ALL_INT = [mxint(b) for b in range(2, 9)]
ALL_FP = [mxfp(b) for b in range(4, 9)]
# Formats seen during multi-format QAT (paper section 3.2).
TRAIN_INT = [mxint(b) for b in (2, 4, 6, 8)]
TRAIN_FP = [mxfp(b) for b in (4, 6, 8)]
