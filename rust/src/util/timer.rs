//! Wall-clock timing helpers for the bench harness and perf logging.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a stopwatch.
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Measure a closure repeatedly; returns per-iteration stats in seconds.
///
/// Does a warmup pass, then runs at least `min_iters` iterations and at least
/// `min_time_s` seconds, whichever is longer. Used by `rust/benches/*` (the
/// offline crate set has no criterion).
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time_s: f64, mut f: F) -> BenchResult {
    // Warmup.
    f();
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    loop {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() >= min_iters && start.elapsed().as_secs_f64() >= min_time_s {
            break;
        }
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    BenchResult::from_samples(name, samples)
}

/// Summary statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Iterations measured.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Slowest iteration, seconds.
    pub max_s: f64,
}

impl BenchResult {
    /// Build stats from raw per-iteration samples (sorted internally).
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> BenchResult {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            p50_s: samples[n / 2],
            p95_s: samples[(n as f64 * 0.95) as usize..][0],
            min_s: samples[0],
            max_s: samples[n - 1],
        }
    }

    /// Throughput line given `units` processed per iteration.
    pub fn report(&self, units_per_iter: f64, unit: &str) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  {:>14}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            format!("{}/{}", fmt_rate(units_per_iter / self.mean_s), unit),
        )
    }
}

/// Human-readable time.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-readable rate (e.g. elements/s).
pub fn fmt_rate(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1} ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let r = bench("noop", 16, 0.0, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 16);
        assert!(r.min_s <= r.p50_s && r.p50_s <= r.max_s);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(2.0).contains('s'));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_rate(2e9).starts_with("2.00 G"));
    }
}
