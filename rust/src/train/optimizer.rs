//! AdamW optimizer state (the update itself runs inside the train-step HLO;
//! the host only carries the moment tensors between steps).

use crate::model::ParamSet;
use crate::tensor::Tensor;

/// First/second moment tensors for the trainable subset.
#[derive(Debug, Clone)]
pub struct OptState {
    /// Indices (into the manifest param order) this state covers.
    pub idx: Vec<usize>,
    /// First-moment (momentum) accumulator.
    pub m: Vec<Tensor>,
    /// Second-moment accumulator.
    pub v: Vec<Tensor>,
}

impl OptState {
    /// Fresh zero state for the given trainable indices.
    pub fn zeros(params: &ParamSet, idx: &[usize]) -> OptState {
        let m = idx
            .iter()
            .map(|&i| Tensor::zeros(&params.tensors[i].shape))
            .collect::<Vec<_>>();
        OptState {
            idx: idx.to_vec(),
            m: m.clone(),
            v: m,
        }
    }

    /// Total state elements (for memory accounting).
    pub fn numel(&self) -> usize {
        self.m.iter().map(|t| t.len()).sum::<usize>() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;
    use crate::tensor::Tensor;

    #[test]
    fn zeros_match_param_shapes() {
        let params = ParamSet {
            tensors: vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[4]), Tensor::zeros(&[5, 5])],
        };
        let s = OptState::zeros(&params, &[0, 2]);
        assert_eq!(s.m.len(), 2);
        assert_eq!(s.m[0].shape, vec![2, 3]);
        assert_eq!(s.v[1].shape, vec![5, 5]);
        assert_eq!(s.numel(), (6 + 25) * 2);
    }
}
