"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes, dtypes-of-content (magnitude regimes), bitwidths
and block sizes; every comparison demands exact equality (interpret-mode
Pallas must be bit-identical to the oracle since both run the same jax ops).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref
from compile.kernels.mx_quant import fake_quantize_pallas, _pick_tile
from compile.kernels.mx_matmul import mx_matmul_pallas
from compile.kernels.ss_convert import ss_convert_pallas

ALL_FMTS = F.ALL_INT + F.ALL_FP


def wild(rng, shape, scale_pow):
    """Values spanning many binades, with zeros and sign mix."""
    v = rng.normal(size=shape) * (10.0 ** scale_pow)
    mask = rng.random(size=shape) < 0.05
    v = np.where(mask, 0.0, v)
    return v.astype(np.float32)


@pytest.mark.parametrize("fmt", ALL_FMTS, ids=lambda f: f.name)
def test_fq_kernel_matches_oracle_exactly(fmt):
    rng = np.random.default_rng(1)
    v = wild(rng, (24, 96), 0)
    got = np.asarray(fake_quantize_pallas(v, fmt, 32))
    want = np.asarray(ref.fake_quantize(v, fmt, 32))
    assert np.array_equal(got, want), fmt


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    rows=st.integers(1, 40),
    nblocks=st.integers(1, 6),
    bs=st.sampled_from([8, 16, 32]),
    scale_pow=st.integers(-25, 25),
    fmt_i=st.integers(0, len(ALL_FMTS) - 1),
)
def test_hypothesis_fq_kernel_equals_oracle(seed, rows, nblocks, bs, scale_pow, fmt_i):
    fmt = ALL_FMTS[fmt_i]
    rng = np.random.default_rng(seed)
    v = wild(rng, (rows, nblocks * bs), scale_pow)
    got = np.asarray(fake_quantize_pallas(v, fmt, bs))
    want = np.asarray(ref.fake_quantize(v, fmt, bs))
    assert np.array_equal(got, want), (fmt, rows, nblocks, bs, scale_pow)


def test_fq_kernel_3d_input():
    rng = np.random.default_rng(2)
    v = wild(rng, (3, 4, 64), 0)
    got = np.asarray(fake_quantize_pallas(v, F.mxint(5), 32))
    want = np.asarray(ref.fake_quantize(v, F.mxint(5), 32))
    assert got.shape == (3, 4, 64)
    assert np.array_equal(got, want)


def test_pick_tile_divides():
    assert _pick_tile(128, 64) == 64
    assert _pick_tile(96, 64) == 48
    assert _pick_tile(7, 64) == 7
    assert _pick_tile(13, 4) == 1


@pytest.mark.parametrize(
    "anchor,targets",
    [(F.mxint(8), F.ALL_INT[:-1]), (F.mxfp(8), F.ALL_FP[:-1])],
    ids=["int", "fp"],
)
def test_ss_kernel_matches_oracle(anchor, targets):
    rng = np.random.default_rng(3)
    v = wild(rng, (16, 128), 0)
    se, p = ref.quantize_blocks(v, anchor, 32)
    for t in targets:
        se_k, p_k = ss_convert_pallas(se, p, anchor, t)
        se_r, p_r = ref.ss_convert(se, p, anchor, t)
        assert np.array_equal(np.asarray(se_k), np.asarray(se_r)), t
        assert np.array_equal(np.asarray(p_k), np.asarray(p_r)), t


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    rows=st.integers(1, 24),
    tbits=st.integers(2, 8),
)
def test_hypothesis_ss_kernel_int(seed, rows, tbits):
    rng = np.random.default_rng(seed)
    v = wild(rng, (rows, 64), 0)
    se, p = ref.quantize_blocks(v, F.mxint(8), 32)
    se_k, p_k = ss_convert_pallas(se, p, F.mxint(8), F.mxint(tbits))
    se_r, p_r = ref.ss_convert(se, p, F.mxint(8), F.mxint(tbits))
    assert np.array_equal(np.asarray(se_k), np.asarray(se_r))
    assert np.array_equal(np.asarray(p_k), np.asarray(p_r))


def test_mx_matmul_kernel_matches_ref():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(8, 128)).astype(np.float32)
    w = rng.normal(size=(64, 128)).astype(np.float32)
    se, p = ref.quantize_blocks(w, F.mxint(6), 32)
    got = np.asarray(mx_matmul_pallas(x, se, p))
    want = np.asarray(ref.mx_matmul_ref(x, se, p, 64, 32))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    b=st.integers(1, 8),
    n=st.sampled_from([16, 32, 64]),
    k_blocks=st.integers(1, 4),
)
def test_hypothesis_mx_matmul(seed, b, n, k_blocks):
    rng = np.random.default_rng(seed)
    k = 32 * k_blocks
    x = rng.normal(size=(b, k)).astype(np.float32)
    w = rng.normal(size=(n, k)).astype(np.float32)
    se, p = ref.quantize_blocks(w, F.mxfp(8), 32)
    got = np.asarray(mx_matmul_pallas(x, se, p))
    want = np.asarray(ref.mx_matmul_ref(x, se, p, n, 32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_matmul_with_quantized_weights_bounds_error():
    """Sanity: 8-bit MX weights give a close matmul; 2-bit a worse one."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(4, 256)).astype(np.float32)
    w = rng.normal(size=(32, 256)).astype(np.float32)
    exact = x @ w.T
    errs = {}
    for bits in (8, 2):
        se, p = ref.quantize_blocks(w, F.mxint(bits), 32)
        y = np.asarray(mx_matmul_pallas(x, se, p))
        errs[bits] = float(np.mean((y - exact) ** 2))
    assert errs[8] < errs[2] / 100.0, errs
