//! Elastic serving under a bursty load pattern — the paper's deployment
//! story (§1): one anchor checkpoint, precision chosen *per batch* from the
//! current queue depth.
//!
//! The workload alternates calm phases (trickle of requests) with load
//! spikes; the report shows the precision ladder engaging during spikes and
//! the latency/throughput profile per phase.
//!
//! Serves through the native packed-MX backend: no AOT artifacts and no
//! XLA install required.
//!
//! Run: `cargo run --release --example elastic_serving`

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::server::{Policy, Server, ServerConfig};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    mfqat::util::logging::init();
    let dims = ModelDims::by_name("tiny").unwrap();
    let width = dims.seq_len + 1;

    // Aggressive ladder so the tiny demo visibly degrades under bursts.
    let ladder = Policy::Ladder(vec![
        (2, ElementFormat::int(8)),
        (12, ElementFormat::int(6)),
        (usize::MAX, ElementFormat::int(4)),
    ]);
    let (server, client) = Server::start(
        width,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, 7);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 128 << 20)
        },
        ServerConfig {
            policy: ladder,
            gather_window: Duration::from_millis(2),
            workers: 2,
            ..Default::default()
        },
    )?;

    let corpus = Corpus::generate(CorpusConfig {
        width,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 64,
        ..Default::default()
    });

    // Phased workload: calm → spike → calm → bigger spike.
    let phases: &[(&str, usize, Duration)] = &[
        ("calm", 8, Duration::from_millis(30)),
        ("spike", 48, Duration::from_millis(0)),
        ("calm", 8, Duration::from_millis(30)),
        ("surge", 96, Duration::from_millis(0)),
    ];
    println!("{:<8} {:>6} {:>9} {:>9} {:>16}", "phase", "reqs", "p50 lat", "p95 lat", "precision mix");
    for (name, n, pacing) in phases {
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for i in 0..*n {
            rxs.push(client.submit(&corpus.val[i % corpus.val.len()], None)?);
            if !pacing.is_zero() {
                std::thread::sleep(*pacing);
            }
        }
        let mut lats: Vec<f64> = Vec::new();
        let mut mix = std::collections::BTreeMap::<String, usize>::new();
        for rx in rxs {
            let resp = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
            lats.push(resp.latency.as_secs_f64());
            *mix.entry(resp.format.name()).or_insert(0) += 1;
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = lats[lats.len() / 2];
        let p95 = lats[(lats.len() as f64 * 0.95) as usize];
        let mix_s: Vec<String> = mix.iter().map(|(f, c)| format!("{f}:{c}")).collect();
        println!(
            "{:<8} {:>6} {:>7.1}ms {:>7.1}ms {:>16}   ({:.1} req/s)",
            name,
            n,
            p50 * 1e3,
            p95 * 1e3,
            mix_s.join(" "),
            *n as f64 / t0.elapsed().as_secs_f64(),
        );
    }

    let metrics = server.metrics();
    println!("\nserver totals: {}", metrics.summary());
    println!("anchor→target conversions: {} (cache does the rest)", metrics.conversions());
    drop(client);
    server.shutdown();
    Ok(())
}
