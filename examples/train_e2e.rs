//! End-to-end training driver — the full-system validation run.
//!
//! All three layers compose here, with Python nowhere at runtime:
//!   1. **Pretrain** a transformer LM on the synthetic corpus via the
//!      AOT-compiled `train_pretrain` HLO (L2 graph + L1 Pallas kernels),
//!      logging the loss curve.
//!   2. **Multi-format QAT** (paper §3.2): one epoch per MXINT format in
//!      increasing bit order over the 128-example finetune split.
//!   3. **Anchor storage** (paper §3.5): save ONE MXINT8 checkpoint.
//!   4. **Elastic evaluation**: derive every MXINT format 2–8 from the
//!      anchor via Slice-and-Scale and report validation perplexity.
//!
//! Run: `cargo run --release --example train_e2e`
//!      (`MFQAT_E2E_STEPS=64 MFQAT_E2E_CONFIG=tiny` to resize)

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::eval::{perplexity, ParamLiterals};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use mfqat::train::{TrainPlan, Trainer};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    mfqat::util::logging::init();
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let config = std::env::var("MFQAT_E2E_CONFIG").unwrap_or_else(|_| "tiny".into());
    let pretrain_steps: usize = std::env::var("MFQAT_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);

    let rt = Runtime::cpu()?;
    let arts = ArtifactSet::open(&root.join("artifacts").join(&config))?;
    let m = arts.manifest.clone();
    println!(
        "=== e2e: {} ({:.2}M params), {} pretrain steps ===",
        m.config_name,
        m.n_params as f64 / 1e6,
        pretrain_steps
    );

    let corpus = Corpus::generate(CorpusConfig {
        width: m.seq_len + 1,
        ..Default::default()
    });

    // ---- 1. pretraining, loss curve logged every epoch-chunk ----
    let params = ParamSet::init(&m, 20260710);
    let mut trainer = Trainer::new(&rt, &arts, params);
    let chunk = 16usize; // batches per log line
    let mut done = 0usize;
    while done < pretrain_steps {
        let n = chunk.min(pretrain_steps - done);
        let rows: Vec<Vec<i32>> = (0..n * m.train_batch)
            .map(|i| corpus.pretrain[(done * m.train_batch + i) % corpus.pretrain.len()].clone())
            .collect();
        let stats = trainer.train_epoch("pretrain", &rows, 1e-3)?;
        done += n;
        println!(
            "pretrain step {:>4}/{}  loss {:.4} -> {:.4}",
            done, pretrain_steps, stats.first_loss, stats.last_loss
        );
    }
    let base_lits = ParamLiterals::build(&trainer.params)?;
    let base_ppl = perplexity(&rt, &arts, &base_lits, &corpus.val)?;
    println!("pretrained val ppl: {base_ppl:.3}");

    // ---- 2. multi-format QAT (2 -> 4 -> 6 -> 8) ----
    trainer.reset_opt();
    let plan = TrainPlan::multi_int();
    println!("\n=== multi-format QAT: {:?} ===", plan.phases.iter().map(|p| &p.variant).collect::<Vec<_>>());
    for phase in &plan.phases {
        let stats = trainer.train_epoch(&phase.variant, &corpus.qat, 1e-4)?;
        println!(
            "qat epoch [{}] loss {:.4} -> {:.4}",
            phase.variant, stats.first_loss, stats.last_loss
        );
    }

    // ---- 3. anchor checkpoint (the ONLY stored serving artifact) ----
    let ck = trainer.params.to_anchor_checkpoint(&m, ElementFormat::int(8))?;
    let ck_path = std::env::temp_dir().join("mfqat_e2e_anchor.mfq");
    ck.save(&ck_path)?;
    println!(
        "\nanchor checkpoint: {} ({:.2} MB vs {:.2} MB fp32)",
        ck_path.display(),
        ck.storage_bytes() as f64 / 1e6,
        trainer.params.n_params() as f64 * 4.0 / 1e6
    );

    // ---- 4. elastic precision sweep via Slice-and-Scale ----
    println!("\n=== elastic sweep: anchor -> SSMXINT -> val perplexity ===");
    println!("{:<10} {:>10} {:>12}", "format", "val ppl", "vs direct");
    let master = trainer.params.clone();
    for bits in (2..=8).rev() {
        let fmt = ElementFormat::int(bits);
        // Serving path: anchor + SS.
        let served = ParamSet::from_checkpoint(&m, &ck, Some(fmt))?;
        let ppl = perplexity(&rt, &arts, &ParamLiterals::build(&served)?, &corpus.val)?;
        // Reference path: direct PTQ from the fp32 master.
        let direct = master.ptq(&m, fmt)?;
        let dppl = perplexity(&rt, &arts, &ParamLiterals::build(&direct)?, &corpus.val)?;
        println!("{:<10} {:>10.3} {:>11.3}", fmt.long_name(), ppl, dppl);
    }
    println!("\n(SS column ≈ direct column: the paper's Fig. 2/4 claim, end to end)");

    // Engine smoke: the serving stack consumes the same checkpoint.
    let engine = ElasticEngine::open(&root.join("artifacts").join(&config), &ck_path, 128 << 20)?;
    let mut batch = Vec::new();
    for r in 0..m.train_batch {
        batch.extend_from_slice(&corpus.val[r]);
    }
    let nll = engine.score_batch(&batch, ElementFormat::int(4))?;
    println!("engine MXINT4 batch NLL: {:?}", &nll[..3.min(nll.len())]);
    Ok(())
}
