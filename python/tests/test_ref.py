"""Oracle (ref.py) numerics tests: exactness of the bit-level helpers and the
quantization semantics, including hypothesis sweeps."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import formats as F
from compile.kernels import ref


# --------------------------------------------------------------------------
# exact helpers
# --------------------------------------------------------------------------

def test_floor_log2_exact_on_normals():
    xs = np.array([1.0, 0.9999999, 2.0, 3.999, 4.0, 0.5, 1e-30, 2.0**-126],
                  np.float32)
    got = np.asarray(ref.floor_log2(xs))
    want = np.array([math.floor(math.log2(abs(float(x)))) for x in xs])
    assert (got == want).all(), (got, want)


def test_floor_log2_subnormals_clamp():
    tiny = np.float32(1e-45)  # subnormal
    assert int(np.asarray(ref.floor_log2(tiny))) == -127


def test_exp2i_exact():
    # Scales live in [-126, 127]: the f32 normal range (SCALE_EXP_MIN docs).
    es = np.arange(F.SCALE_EXP_MIN, 128, dtype=np.int32)
    got = np.asarray(ref.exp2i(es), np.float64)
    want = np.array([2.0**int(e) for e in es])
    assert (got == want).all()
    # 2^-127 is subnormal; XLA CPU may flush it — either value is acceptable
    # because the scale clamp keeps it out of the quantization path.
    low = float(np.asarray(ref.exp2i(np.int32(-127))))
    assert low in (0.0, 2.0**-127)


# --------------------------------------------------------------------------
# element quantizers
# --------------------------------------------------------------------------

def test_int_elem_rne_ties():
    u = np.array([0.5, 1.5, 2.5, -0.5, -1.5, 100.0, -100.0], np.float32)
    got = np.asarray(ref.quantize_int_elem(u, 4))
    assert got.tolist() == [0.0, 2.0, 2.0, -0.0, -2.0, 7.0, -8.0]


def fp_magnitudes(fmt):
    """All representable non-negative magnitudes of a minifloat format."""
    m = fmt.man_bits
    vals = [k * 2.0 ** (fmt.emin - m) for k in range(2 ** m)]  # subnormals
    top_m = 2 ** m
    for E in range(fmt.emin, fmt.emax + 1):
        for k in range(top_m):
            v = (1 + k / top_m) * 2.0 ** E
            if v <= fmt.max_value:
                vals.append(v)
    return sorted(set(vals))


@pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
def test_fp_elem_is_nearest(bits):
    fmt = F.mxfp(bits)
    grid = np.array(fp_magnitudes(fmt))
    xs = np.linspace(-1.4 * fmt.max_value, 1.4 * fmt.max_value, 1001).astype(
        np.float32)
    got = np.asarray(ref.quantize_fp_elem(xs, fmt))
    for x, q in zip(xs, got):
        a = min(abs(float(x)), fmt.max_value)
        best = grid[np.argmin(np.abs(grid - a))]
        # Nearest (ties may legitimately differ; check distance optimality).
        assert abs(abs(q) - a) <= abs(best - a) + 1e-6, (x, q, best)
        assert (q <= 0) == (x <= 0) or q == 0


@pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
def test_fp_elem_fixed_points(bits):
    fmt = F.mxfp(bits)
    grid = np.array(fp_magnitudes(fmt), np.float32)
    got = np.asarray(ref.quantize_fp_elem(grid, fmt))
    assert (got == grid).all()
    gotn = np.asarray(ref.quantize_fp_elem(-grid, fmt))
    assert (gotn == -grid).all()


def test_fp_elem_e2m1_matches_known_table():
    fmt = F.mxfp(4)
    # OCP FP4: 0, .5, 1, 1.5, 2, 3, 4, 6
    assert fp_magnitudes(fmt) == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    # RNE ties to even code: 1.25 -> 1.0, 1.75 -> 2.0, 2.5 -> 2.0.
    u = np.array([1.25, 1.75, 2.5, 0.25], np.float32)
    got = np.asarray(ref.quantize_fp_elem(u, fmt))
    assert got.tolist() == [1.0, 2.0, 2.0, 0.0]


# --------------------------------------------------------------------------
# block quantization
# --------------------------------------------------------------------------

def test_shared_exponent_basics():
    fmt = F.mxint(8)
    vb = np.array([[[0.5, -1.0, 0.25, 0.1]]], np.float32)
    se = np.asarray(ref.shared_exponent(jnp.asarray(vb), fmt))
    assert se.reshape(-1)[0] == -6  # floor(log2 1.0) - 6
    zero = np.zeros((1, 1, 4), np.float32)
    assert np.asarray(ref.shared_exponent(jnp.asarray(zero), fmt)).reshape(-1)[0] == F.SCALE_EXP_MIN


def test_fake_quantize_error_bound_int():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(4, 64)).astype(np.float32)
    for bits in range(2, 9):
        fq = np.asarray(ref.fake_quantize(v, F.mxint(bits), 32))
        # Per-block bound: |err| <= X (bin radius X/2 + positive clip).
        vb = v.reshape(4, 2, 32)
        se = np.asarray(ref.shared_exponent(jnp.asarray(vb), F.mxint(bits)))
        X = 2.0 ** se.astype(np.float64)
        err = np.abs(fq - v).reshape(4, 2, 32)
        assert (err <= X[..., None] + 1e-12).all(), bits


def test_fake_quantize_idempotent():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(2, 64)).astype(np.float32)
    for fmt in [F.mxint(4), F.mxint(8), F.mxfp(4), F.mxfp(8)]:
        once = np.asarray(ref.fake_quantize(v, fmt, 32))
        twice = np.asarray(ref.fake_quantize(once, fmt, 32))
        assert np.array_equal(once, twice), fmt


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    bits=st.integers(2, 8),
    bs=st.sampled_from([8, 16, 32, 64]),
)
def test_hypothesis_int_fq_bound(seed, bits, bs):
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.integers(-20, 20)
    v = (rng.normal(size=(2, 2 * bs)) * scale).astype(np.float32)
    fq = np.asarray(ref.fake_quantize(v, F.mxint(bits), bs))
    assert np.isfinite(fq).all()
    vb = v.reshape(2, 2, bs)
    amax = np.abs(vb).max(axis=-1, keepdims=True)
    # Quantized magnitude can exceed per-element value but never the block
    # max scaled beyond one bin.
    assert (np.abs(fq.reshape(2, 2, bs)) <= amax * 1.5 + 1e-30).all()


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    bits=st.sampled_from([4, 5, 6, 7, 8]),
    bs=st.sampled_from([8, 16, 32, 64]),
)
def test_hypothesis_fp_fq_relative_error(seed, bits, bs):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(1, 4 * bs)).astype(np.float32)
    fmt = F.mxfp(bits)
    fq = np.asarray(ref.fake_quantize(v, fmt, bs))
    vb = v.reshape(1, 4, bs)
    se = np.asarray(ref.shared_exponent(jnp.asarray(vb), fmt))
    X = 2.0 ** se.astype(np.float64)
    # err <= max(relative 2^-(m+1), clip bound, subnormal step X*2^(emin-m)).
    m = fmt.man_bits
    err = np.abs(fq - v).reshape(1, 4, bs)
    bound = np.maximum(
        np.abs(v).reshape(1, 4, bs) * 2.0 ** (-m - 1),
        X[..., None] * max(2.0 ** (fmt.emax - m + 1), 2.0 ** (fmt.emin - m)),
    )
    assert (err <= bound + 1e-30).all()


# --------------------------------------------------------------------------
# slice-and-scale
# --------------------------------------------------------------------------

def test_ss_scale_matches_direct():
    rng = np.random.default_rng(2)
    v = rng.normal(size=(1, 128)).astype(np.float32)
    for anchor, targets in ((F.mxint(8), F.ALL_INT[:-1]), (F.mxfp(8), F.ALL_FP[:-1])):
        va = ref.fake_quantize(v, anchor, 32)
        vb = np.asarray(va).reshape(1, 4, 32)
        se_h = ref.shared_exponent(jnp.asarray(vb), anchor)
        p_h = jnp.asarray(vb) * ref.exp2i(-se_h)[..., None]
        for t in targets:
            se_l, _ = ref.ss_convert(se_h, p_h, anchor, t)
            se_direct = ref.shared_exponent(jnp.asarray(v.reshape(1, 4, 32)), t)
            assert np.array_equal(np.asarray(se_l), np.asarray(se_direct)), t


def test_ss_equals_fake_quant_on_anchor_values():
    """The SS theorem: value-level SS == direct fake-quant of anchor values."""
    rng = np.random.default_rng(3)
    v = rng.normal(size=(4, 96)).astype(np.float32)
    for anchor, targets in ((F.mxint(8), F.ALL_INT[:-1]), (F.mxfp(8), F.ALL_FP[:-1])):
        va = np.asarray(ref.fake_quantize(v, anchor, 32))
        for t in targets:
            ss = np.asarray(ref.ss_fake_quantize(va, anchor, t, 32))
            direct = np.asarray(ref.fake_quantize(va, t, 32))
            assert np.array_equal(ss, direct), (anchor, t)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), tbits=st.integers(2, 7))
def test_hypothesis_ssint_close_to_direct(seed, tbits):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(1, 1024)).astype(np.float32)
    t = F.mxint(tbits)
    va = np.asarray(ref.fake_quantize(v, F.mxint(8), 64))
    ss = np.asarray(ref.ss_fake_quantize(va, F.mxint(8), t, 64))
    direct = np.asarray(ref.fake_quantize(v, t, 64))
    mse_ss = float(np.mean((ss - v) ** 2))
    mse_direct = float(np.mean((direct - v) ** 2))
    # At n=1024 the statistical gap is small (paper App. C) except near the
    # anchor bitwidth, where the direct error is tiny and the double-rounding
    # term dominates the *ratio* (absolute gap stays negligible).
    bound = 2.5 if tbits >= 7 else 1.6
    assert mse_ss <= mse_direct * bound + 1e-10, (tbits, mse_ss, mse_direct)
