//! Precision sweep: storage / accuracy / conversion-cost trade-off table
//! for a single anchor checkpoint — the capacity-planning view an operator
//! of an elastic fleet would want.
//!
//! For every MXINT and MXFP target derivable from the corresponding 8-bit
//! anchor, reports: packed weight bytes, bits/element, SS conversion time,
//! dequant time, and validation perplexity.
//!
//! Run: `cargo run --release --example precision_sweep`

use mfqat::data::{Corpus, CorpusConfig};
use mfqat::eval::{perplexity, ParamLiterals};
use mfqat::formats::{ElementFormat, MxFormat};
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use mfqat::tensor::MxTensor;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    mfqat::util::logging::init();
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rt = Runtime::cpu()?;
    let arts = ArtifactSet::open(&root.join("artifacts/tiny"))?;
    let m = arts.manifest.clone();
    let corpus = Corpus::generate(CorpusConfig {
        width: m.seq_len + 1,
        ..Default::default()
    });
    let params = ParamSet::init(&m, 99);

    println!(
        "{:<14} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "format", "weights(KB)", "bits/elem", "SS convert", "dequant", "val ppl"
    );
    for (anchor, targets) in [
        (ElementFormat::int(8), ElementFormat::all_int()),
        (ElementFormat::fp_from_bits(8), ElementFormat::all_fp()),
    ] {
        // Quantize the decoder linears once into the anchor format.
        let quant_idx = m.quant_indices();
        let anchored: Vec<MxTensor> = quant_idx
            .iter()
            .map(|&i| {
                let t = &params.tensors[i];
                MxTensor::quantize(&t.data, &t.shape, MxFormat::new(anchor, m.block_size))
            })
            .collect::<anyhow::Result<_>>()?;

        for target in targets.iter().rev() {
            // SS conversion cost (anchor -> target, all decoder weights).
            let t_conv = std::time::Instant::now();
            let converted: Vec<MxTensor> = anchored
                .iter()
                .map(|a| {
                    if *target == anchor {
                        Ok(a.clone())
                    } else {
                        a.slice_and_scale(*target)
                    }
                })
                .collect::<anyhow::Result<_>>()?;
            let conv_ms = t_conv.elapsed().as_secs_f64() * 1e3;

            // Dequant cost + serving params.
            let t_deq = std::time::Instant::now();
            let mut served = params.clone();
            for (&i, q) in quant_idx.iter().zip(&converted) {
                served.tensors[i] =
                    mfqat::tensor::Tensor::new(&q.shape.clone(), q.dequantize())?;
            }
            let deq_ms = t_deq.elapsed().as_secs_f64() * 1e3;

            let bytes: usize = converted.iter().map(|q| q.storage_bytes()).sum();
            let elems: usize = converted.iter().map(|q| q.len()).sum();
            let ppl = perplexity(&rt, &arts, &ParamLiterals::build(&served)?, &corpus.val)?;
            println!(
                "{:<14} {:>12} {:>10.2} {:>9.1}ms {:>9.1}ms {:>10.3}",
                target.long_name(),
                bytes / 1024,
                bytes as f64 * 8.0 / elems as f64,
                conv_ms,
                deq_ms,
                ppl
            );
        }
        println!();
    }
    println!("(one {}-anchor on disk serves every row above it)", ElementFormat::int(8));
    Ok(())
}
