//! End-to-end serving benchmarks over the real PJRT engine — regenerates
//! the elastic-inference trade-off the paper motivates (§1): throughput and
//! latency per serving precision, cost of a format switch, and fixed-format
//! vs elastic-ladder behaviour under a burst.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use mfqat::util::timer::{bench, fmt_time};
use std::path::PathBuf;

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let arts_dir = root.join("artifacts/tiny");
    if !arts_dir.join("manifest.json").exists() {
        println!("serving bench skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&arts_dir).unwrap();
    let m = arts.manifest.clone();
    let params = ParamSet::init(&m, 3);
    let ck = params
        .to_anchor_checkpoint(&m, ElementFormat::int(8))
        .unwrap();
    let engine = ElasticEngine::from_parts(rt, arts, ck.clone(), ElementFormat::int(8), 256 << 20);

    let corpus = Corpus::generate(CorpusConfig {
        width: m.seq_len + 1,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 16,
        ..Default::default()
    });
    let mut batch = Vec::new();
    for r in 0..m.train_batch {
        batch.extend_from_slice(&corpus.val[r]);
    }
    let tokens_per_batch = (m.train_batch * m.seq_len) as f64;

    println!("== steady-state batch scoring per format (batch = {}) ==", m.train_batch);
    for bits in [8u8, 6, 4, 2] {
        let fmt = ElementFormat::int(bits);
        engine.score_batch(&batch, fmt).unwrap(); // warm the format cache
        let r = bench(&format!("score_batch/int{bits}"), 6, 0.8, || {
            std::hint::black_box(engine.score_batch(&batch, fmt).unwrap());
        });
        println!("{}", r.report(tokens_per_batch, "tok"));
    }

    println!("\n== format-switch cost (anchor -> target derivation, uncached) ==");
    for bits in [6u8, 4, 3, 2] {
        let fmt = ElementFormat::int(bits);
        // Fresh engine state per measurement: use a cache-busting format
        // cycle (derive, then measure re-derivation after eviction is not
        // possible with a large cache, so measure the cold path directly).
        let t = std::time::Instant::now();
        let w = {
            let p = ParamSet::from_checkpoint(&m, &ck, Some(fmt)).unwrap();
            mfqat::eval::ParamLiterals::build(&p).unwrap()
        };
        std::hint::black_box(&w);
        println!(
            "derive/int{bits}: {} ({} params)",
            fmt_time(t.elapsed().as_secs_f64()),
            m.n_params
        );
    }

    println!("\n== batched vs single-row execution (batching win) ==");
    let r8 = bench("forward/batch8", 6, 0.8, || {
        std::hint::black_box(engine.score_batch(&batch, ElementFormat::int(8)).unwrap());
    });
    println!("{}", r8.report(m.train_batch as f64, "seq"));
    // One row padded to a full batch: per-sequence cost without batching.
    let mut one = batch.clone();
    for r in 1..m.train_batch {
        let w = m.seq_len + 1;
        let src = batch[..w].to_vec();
        one[r * w..(r + 1) * w].copy_from_slice(&src);
    }
    let r1 = bench("forward/batch1(padded)", 6, 0.8, || {
        std::hint::black_box(engine.score_batch(&one, ElementFormat::int(8)).unwrap());
    });
    println!("{}", r1.report(1.0, "seq"));
    println!(
        "batching speedup: {:.2}x per sequence",
        r1.mean_s / (r8.mean_s / m.train_batch as f64)
    );
}
