//! Experiment harness: regenerates every table and figure of the paper.
//!
//! | id      | paper artifact                                  | module      |
//! |---------|--------------------------------------------------|-------------|
//! | `fig1`  | Fig. 1 + App. A.1 (MF-QAT vs single-format PPL)  | [`quality`] |
//! | `fig2`  | Fig. 2 (SSMXINT vs direct, PPL sweeps)           | [`ss_eval`] |
//! | `fig3`  | Fig. 3 (SSMXFP vs direct, PPL sweeps)            | [`ss_eval`] |
//! | `fig4`  | Fig. 4 + App. A.2 (MF-QAT **with** SS)           | [`quality`] |
//! | `tab1`  | Table 1 (+App. B Tables 4–6): MXINT accuracy grid| [`quality`] |
//! | `tab2`  | Table 2 (+App. B Table 7): MXFP accuracy grid    | [`quality`] |
//! | `tab3`  | Table 3: chart-QA grid (VL stand-in)             | [`quality`] |
//! | `fig19` | App. C Fig. 19 (SSMXINT tensor MSE)              | [`ss_eval`] |
//! | `fig20` | App. C Fig. 20 (SSMXFP tensor MSE)               | [`ss_eval`] |
//!
//! Trained variants are cached as checkpoints under `runs/<config>/`, so
//! `tab1` reuses the models trained for `fig1`, etc. Results land in
//! `results/<config>/`.

#[cfg(feature = "pjrt")]
pub mod ablations;
#[cfg(feature = "pjrt")]
pub mod quality;
pub mod report;
pub mod ss_eval;

#[cfg(feature = "pjrt")]
use crate::data::{Corpus, CorpusConfig};
#[cfg(feature = "pjrt")]
use crate::eval::ParamLiterals;
#[cfg(feature = "pjrt")]
use crate::model::ParamSet;
#[cfg(feature = "pjrt")]
use crate::runtime::{ArtifactSet, Runtime};
#[cfg(feature = "pjrt")]
use crate::train::{TrainPlan, Trainer};
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// Shared state for experiment runs.
#[cfg(feature = "pjrt")]
pub struct Ctx {
    /// PJRT runtime.
    pub rt: Runtime,
    /// Loaded AOT artifacts.
    pub arts: ArtifactSet,
    /// Generated corpus shared by every experiment.
    pub corpus: Corpus,
    /// Directory for training runs and checkpoints.
    pub runs_dir: PathBuf,
    /// Directory for result tables and figures.
    pub results_dir: PathBuf,
    /// Base seed.
    pub seed: u64,
    /// Learning rates swept per variant (paper §3.2 sweeps 3; default here
    /// is a 2-point sweep sized for the 1-core budget — override with
    /// `--lrs`).
    pub lrs: Vec<f32>,
    /// Pretraining budget in epochs over the pretrain split.
    pub pretrain_epochs: usize,
    /// Items per downstream task.
    pub task_items: usize,
}

#[cfg(feature = "pjrt")]
impl Ctx {
    /// Open an experiment context for `config` under the repo root.
    pub fn open(repo_root: &Path, config: &str, seed: u64) -> Result<Ctx> {
        let arts_dir = repo_root.join("artifacts").join(config);
        if !arts_dir.join("manifest.json").exists() {
            bail!(
                "no artifacts for config '{config}' at {} — run `make artifacts`",
                arts_dir.display()
            );
        }
        let rt = Runtime::cpu()?;
        let arts = ArtifactSet::open(&arts_dir)?;
        let corpus = Corpus::generate(CorpusConfig {
            seed,
            width: arts.manifest.seq_len + 1,
            ..Default::default()
        });
        Ok(Ctx {
            rt,
            arts,
            corpus,
            runs_dir: repo_root.join("runs").join(config),
            results_dir: repo_root.join("results").join(config),
            seed,
            lrs: vec![3e-4, 1e-4],
            pretrain_epochs: 2,
            task_items: 48,
        })
    }

    /// Mean NLL on the validation split.
    pub fn val_nll(&self, params: &ParamSet) -> Result<f64> {
        let lits = ParamLiterals::build(params)?;
        crate::eval::mean_nll(&self.rt, &self.arts, &lits, &self.corpus.val)
    }

    /// Validation perplexity of a param set after host-side PTQ.
    pub fn val_ppl(&self, params: &ParamSet) -> Result<f64> {
        Ok(self.val_nll(params)?.exp())
    }

    // ------------------------------------------------------------- caching

    fn pretrained_path(&self) -> PathBuf {
        self.runs_dir.join("pretrained.mfq")
    }

    /// Train (or load) the pretrained base model — the substrate standing in
    /// for the paper's pretrained LLMs.
    pub fn ensure_pretrained(&self) -> Result<ParamSet> {
        let path = self.pretrained_path();
        if path.exists() {
            let ck = crate::checkpoint::Checkpoint::load(&path)?;
            log::info!("loaded pretrained base from {}", path.display());
            return ParamSet::from_checkpoint(&self.arts.manifest, &ck, None);
        }
        log::info!(
            "pretraining base model ({} epochs x {} sequences)…",
            self.pretrain_epochs,
            self.corpus.pretrain.len()
        );
        let params = ParamSet::init(&self.arts.manifest, self.seed);
        let mut trainer = Trainer::new(&self.rt, &self.arts, params);
        for e in 0..self.pretrain_epochs {
            let stats = trainer.train_epoch("pretrain", &self.corpus.pretrain, 1e-3)?;
            let ppl = self.val_ppl(&trainer.params)?;
            log::info!("pretrain epoch {e}: loss {:.4}, val ppl {:.2}", stats.mean_loss, ppl);
        }
        std::fs::create_dir_all(&self.runs_dir)?;
        trainer
            .params
            .to_master_checkpoint(&self.arts.manifest)?
            .save(&path)?;
        Ok(trainer.params)
    }

    fn variant_path(&self, plan: &str, lr: f32) -> PathBuf {
        self.runs_dir.join(format!("var_{plan}_lr{lr:e}.mfq"))
    }

    /// Train (or load) one QAT/FT variant from the pretrained base at one
    /// learning rate. Returns the FP32 master weights after finetuning.
    pub fn ensure_variant(&self, plan_name: &str, lr: f32) -> Result<ParamSet> {
        let path = self.variant_path(plan_name, lr);
        if path.exists() {
            let ck = crate::checkpoint::Checkpoint::load(&path)?;
            return ParamSet::from_checkpoint(&self.arts.manifest, &ck, None);
        }
        let base = self.ensure_pretrained()?;
        let plan = TrainPlan::by_name(plan_name)?;
        log::info!("training variant {plan_name} (lr {lr:e}, {} epochs)", plan.total_epochs());
        let mut trainer = Trainer::new(&self.rt, &self.arts, base);
        trainer
            .run_plan(&plan, &self.corpus.qat, lr)
            .with_context(|| format!("training {plan_name}"))?;
        std::fs::create_dir_all(&self.runs_dir)?;
        trainer
            .params
            .to_master_checkpoint(&self.arts.manifest)?
            .save(&path)?;
        Ok(trainer.params)
    }

    /// LR sweep: train at each configured LR, return the params with the
    /// lowest validation NLL (the paper's "best-performing learning rate").
    pub fn ensure_variant_best(&self, plan_name: &str) -> Result<ParamSet> {
        let mut best: Option<(f64, ParamSet)> = None;
        for &lr in &self.lrs {
            let params = self.ensure_variant(plan_name, lr)?;
            let nll = self.val_nll(&params)?;
            log::info!("variant {plan_name} lr {lr:e}: val nll {nll:.4}");
            if best.as_ref().map(|(b, _)| nll < *b).unwrap_or(true) {
                best = Some((nll, params));
            }
        }
        Ok(best.expect("at least one lr").1)
    }

    /// Path for a result file under the results directory.
    pub fn result_path(&self, name: &str) -> PathBuf {
        self.results_dir.join(name)
    }
}

/// Run an experiment by id ("all" runs everything).
#[cfg(feature = "pjrt")]
pub fn run(ctx: &Ctx, id: &str) -> Result<()> {
    match id {
        "pretrain" => {
            ctx.ensure_pretrained()?;
        }
        "fig1" => quality::fig1(ctx)?,
        "fig2" => ss_eval::fig2_or_3(ctx, "int")?,
        "fig3" => ss_eval::fig2_or_3(ctx, "fp")?,
        "fig4" => quality::fig4(ctx)?,
        "tab1" => quality::table_grid(ctx, "int", "tab1")?,
        "tab2" => quality::table_grid(ctx, "fp", "tab2")?,
        "tab3" => quality::tab3(ctx)?,
        "fig19" => ss_eval::fig19_or_20("int", &ctx.result_path("fig19"))?,
        "fig20" => ss_eval::fig19_or_20("fp", &ctx.result_path("fig20"))?,
        "abl_order" => ablations::abl_order(ctx)?,
        "abl_round" => ablations::abl_round(ctx)?,
        "all" => {
            for id in [
                "fig19", "fig20", "fig2", "fig3", "fig1", "fig4", "tab1", "tab2", "tab3",
            ] {
                log::info!("=== experiment {id} ===");
                run(ctx, id)?;
            }
        }
        "ablations" => {
            for id in ["abl_round", "abl_order"] {
                log::info!("=== experiment {id} ===");
                run(ctx, id)?;
            }
        }
        _ => bail!("unknown experiment '{id}'"),
    }
    Ok(())
}
