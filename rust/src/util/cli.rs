//! Minimal CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch lives in `main.rs`.
//!
//! # Runtime configuration surface (canonical reference)
//!
//! The knobs below steer *how* the engine executes, independent of what a
//! subcommand computes. This table is the one place they are documented —
//! kernel and backend module docs link here.
//!
//! **Flags** (every `mfqat` subcommand that runs inference):
//!
//! | flag | values | effect |
//! |------|--------|--------|
//! | `--backend` | `native` (default) \| `pjrt` | `native` executes packed MX codes directly (no XLA, no AOT artifacts); `pjrt` runs the AOT HLO path and needs `--features pjrt` plus exported artifacts. |
//! | `--act` | `f32` (default) \| `int8` | Activation pipeline for packed linears on the native backend: `f32` keeps dequantize-oracle parity; `int8` quantizes activations per MX block and runs the integer-MAC GEMM. Rejected for `--backend pjrt` (that graph is f32-only). |
//! | `--batching` | `continuous` (default) \| `gather` | Generate-lane batching for `serve`: continuous batching admits prompts into the in-flight decode every step with per-row formats; `gather` restores the legacy grouped batched decode. |
//! | `--slots` | integer (default `0` = model `train_batch`) | Sequence rows in each serve worker's continuous decode session. |
//! | `--kv-page` | integer ≥ 1 (default: `MFQAT_KV_PAGE`, else 64) | Positions per KV page for `serve`/`generate` decode caches (also pins `MFQAT_KV_PAGE` for the process). Resident KV memory tracks live context in pages of this size; tiny values (e.g. 8) force page boundaries mid-prompt/mid-decode, which CI uses to stress the paged walk. |
//! | `--kv-format` | `f32` (default) \| `mxint8` \| `mxfp8` \| `mxint4` (also pins `MFQAT_KV_FORMAT`) | Storage format for `serve`/`generate` KV pages. `f32` keeps the dense arenas and stays bit-identical to pre-quantization behavior; the MX formats store packed codes plus one E8M0 scale per 32 channels (~3.9x / ~3.9x / ~7.3x smaller resident pages), dequantized through SIMD-dispatched kernels at the attention gather. Decode output then differs from f32-KV within the per-format parity tolerance (`rust/tests/kv_quant.rs`); page size stays bit-invisible at any fixed format. |
//! | `--prefix-share` | bare flag (default off) | Content-addressed KV prefix sharing for `serve`/`generate` decode caches (pins `MFQAT_PREFIX_SHARE=1`): a row admitted with a prompt whose full-page prefix is already cached maps those pages read-only (refcounted) and skips their prefill; divergence copies-on-write. Sharing is bit-invisible — decoded tokens are identical with it on or off. |
//! | `--kv-retain` | integer (default `0` = uncapped; pins `MFQAT_KV_RETAIN`) | Cap on pages the prefix index may retain for retired rows. Above the cap (or under pool pressure) the least-recently-used unshared entry is evicted; a later request for that prefix recomputes via prefill. Only meaningful with `--prefix-share`. |
//! | `--kv-budget` | integer (default `0` = uncapped, `serve` only) | Worst-case KV page claims each worker may hold below its dense-equivalent pool. With several continuous workers the server pools `workers × budget` into one cross-worker page ledger: admission claims from the shared balance, so a worker under skewed load can fund rows from pages its idle peers are not using. |
//! | `--trace-out` | file path (`serve` only) | Collect per-request lifecycle spans (queue-wait, prefill, each decode step, completion) and write them as Chrome-trace-event JSON at shutdown — loadable in Perfetto / `chrome://tracing`, one track per worker with one lane per decode row. Tracing is off (and costs one `Option` check) without this flag. |
//! | `--metrics-out` | file path (`serve` only) | Write a machine-readable metrics snapshot periodically and at shutdown: JSON (counters, latency/TTFT/inter-token percentiles per format, KV/cache/queue time series) at the path, Prometheus text exposition next to it with a `.prom` extension. |
//! | `--queue-cap` | integer (default `0` = unbounded, `serve` only) | Bound on queued-but-unstarted requests. When full, new submissions are rejected at the client with a typed `Rejected { retry_after }` error instead of growing the backlog — the last rung of the shed ladder (downshift → defer → reject). |
//! | `--spec` | `k=4,draft=mxint4[,policy=greedy\|stochastic]` (`serve` only) | Self-speculative decoding for the continuous generate lane: each row drafts up to `k` tokens autoregressively at the cheap `draft` format (same anchor parameters — the draft model is free) and verifies them in one multi-position pass at its own serving format, rolling its paged KV back past rejected drafts. `policy=greedy` (default) keeps outputs token-identical to plain decode; `policy=stochastic` is distribution-preserving rejection sampling. Off without this flag. |
//! | `--shutdown-grace-ms` | integer (default `5000`, `serve` only) | Drain grace period for `Server::shutdown`: workers stop taking new work immediately, finish in-flight decode rows until the grace deadline, then fail whatever remains. Workers are joined (even if panicked) and the metrics sampler always stops. |
//!
//! **Environment variables** (read at each cache/engine construction):
//!
//! | variable | values | effect |
//! |----------|--------|--------|
//! | `MFQAT_LOG` | `off` \| `error` \| `warn` \| `info` (default) \| `debug` \| `trace` | Stderr log level ([`crate::util::logging`]). Unrecognized values fall back to `info` with a one-time warning. Read once at logger install. |
//! | `MFQAT_THREADS` | integer ≥ 1 | Pins the kernel worker-thread count (default: detected cores). Benches pin to 1 so pool scaling is not confounded by kernel fan-out. Read once per process. |
//! | `MFQAT_SIMD` | `off`/`0`/`false`/`portable`/`none` | Forces the integer-MAC tile kernels onto the portable scalar loop (the differential-test oracle); any other value, or unset, keeps the runtime-detected AVX2/NEON dispatch. Read once per process. |
//! | `MFQAT_KV_PAGE` | integer ≥ 1 (default 64) | Positions per KV-cache page wherever a sizing is not passed explicitly (`KvPageCfg::from_env`). Paging is bit-invisible to decode output — only residency granularity changes. CI runs a `MFQAT_KV_PAGE=8` test leg so page boundaries land mid-prompt and mid-decode. |
//! | `MFQAT_KV_FORMAT` | `f32` (default) \| `mxint8` \| `mxfp8` \| `mxint4` | KV page storage format wherever a `KvPageCfg` is built from the environment (`KvPageCfg::from_env`) — same semantics as `--kv-format`. Unparsable values warn once and fall back to `f32`. |
//! | `MFQAT_PREFIX_SHARE` | `1`/`true`/`on` (default off) | Turns on content-addressed KV prefix sharing wherever a `KvPageCfg` is built from the environment — same semantics as `--prefix-share`. Off by default: a non-sharing pool frees (and zeroes) every page the instant its row retires. |
//! | `MFQAT_KV_RETAIN` | integer (default 0 = uncapped) | Retained-page cap for the prefix index (`KvPageCfg::from_env`) — same semantics as `--kv-retain`. |
//! | `MFQAT_FAULT` | `;`-separated specs: `panic:worker=W,step=S` \| `stall:worker=W,step=S,ms=M` \| `shrink:worker=W,step=S,pages=P` | Deterministic fault injection for `serve` workers ([`crate::server::FaultPlan`]). Each spec fires at most once, at the first decode step / gather batch `>= S` on worker `W`: `panic` kills the worker body (the supervisor respawns it), `stall` sleeps the worker for `M` ms, `shrink` quarantines up to `P` free KV pages. Unset ⇒ no faults; parse errors are reported at server start. |

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value for `--name`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value for `--name`, with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer option with a default.
    pub fn usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `u64` option with a default.
    pub fn u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Float option with a default.
    pub fn f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --steps 100 --lr=1e-4 tiny --verbose");
        assert_eq!(a.positional, vec!["train", "tiny"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("lr"), Some("1e-4"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 5 --x 2.5");
        assert_eq!(a.usize("n", 0).unwrap(), 5);
        assert_eq!(a.f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!(parse("--n abc").usize("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --quiet");
        assert!(a.flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn list_option() {
        let a = parse("--formats int2,int4, int8");
        // note: whitespace split puts "int8" as positional; emulate real argv
        let a2 = Args::parse(vec!["--formats".into(), "int2, int4,int8".into()]);
        assert_eq!(a2.list("formats").unwrap(), vec!["int2", "int4", "int8"]);
        assert!(a.list("missing").is_none());
    }
}
