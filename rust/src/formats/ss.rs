//! Slice-and-Scale conversions — the paper's §3.3 (SSMXINT, Eq. 4) and
//! §3.4 (SSMXFP, Eq. 6).
//!
//! These convert a *higher*-precision MX block `(X_h, P_h)` into a
//! lower-precision one `(X_ℓ, P_ℓ)` **without access to the original FP32
//! values**:
//!
//! * **SSMXINT** — `Δe = b_h − b_ℓ`; elements are arithmetically
//!   shifted right by `Δe` with round-to-nearest on the dropped bits and
//!   clipped to the target range; the scale exponent increases by `Δe`
//!   (`X_ℓ = X_h·2^Δe`), preserving the represented real range.
//! * **SSMXFP** — `Δe = e_max(η_h) − e_max(η_ℓ)`; elements are decoded,
//!   multiplied by the exact power of two `2^−Δe`, and requantized to the
//!   target minifloat (through an FP32 intermediate, as the paper permits);
//!   the scale exponent increases by `Δe`.
//!
//! Because `V` is fixed, the scale for any MXINT precision differs from the
//! high-precision scale only through `e_max` (paper §3.3), so the SS scale
//! equals the direct-quantization scale exactly; the residual element error
//! comes from the double rounding of the low-precision cast.

use super::int::{int_range, shift_round};
use super::mxblock::{MxBlock, RoundMode, SCALE_EXP_MAX};
use super::{exp2i, ElementFormat};
use anyhow::{bail, Result};

/// Convert a block to a lower-precision format via Slice-and-Scale.
///
/// Errors if the source/target element families differ (MXINT→MXINT and
/// MXFP→MXFP only, as in the paper) or if the target is not lower-or-equal
/// precision.
pub fn slice_and_scale(block: &MxBlock, target: ElementFormat, mode: RoundMode) -> Result<MxBlock> {
    match (block.format, target) {
        (ElementFormat::Int { bits: bh }, ElementFormat::Int { bits: bl }) => {
            if bl > bh {
                bail!("SSMXINT requires b_l <= b_h (got {bh} -> {bl})");
            }
            Ok(ss_int(block, bh, bl, mode))
        }
        (ElementFormat::Fp { .. }, ElementFormat::Fp { .. }) => {
            let sh = block.format.fp_spec().unwrap();
            let sl = target.fp_spec().unwrap();
            if sl.emax() > sh.emax() || (sl.emax() == sh.emax() && sl.m > sh.m) {
                bail!(
                    "SSMXFP requires a lower-precision target (got {} -> {})",
                    block.format,
                    target
                );
            }
            Ok(ss_fp(block, target))
        }
        _ => bail!(
            "slice-and-scale cannot cross element families ({} -> {})",
            block.format,
            target
        ),
    }
}

/// SSMXINT (Eq. 4): integer right-shift with rounding + scale bump.
fn ss_int(block: &MxBlock, bh: u8, bl: u8, mode: RoundMode) -> MxBlock {
    let de = (bh - bl) as u32; // Δe = b_h − b_ℓ (emax(b) = b−2)
    let (lo, hi) = int_range(bl);
    let codes = block
        .codes
        .iter()
        .map(|&c| shift_round(c as i32, de, mode).clamp(lo, hi) as i8)
        .collect();
    MxBlock {
        format: ElementFormat::int(bl),
        scale_exp: ((block.scale_exp as i32 + de as i32).min(SCALE_EXP_MAX)) as i8,
        codes,
    }
}

/// SSMXFP (Eq. 6): decode → scale by exact 2^−Δe → requantize + scale bump.
fn ss_fp(block: &MxBlock, target: ElementFormat) -> MxBlock {
    let sh = block.format.fp_spec().unwrap();
    let sl = target.fp_spec().unwrap();
    let de = sh.emax() - sl.emax();
    let down = exp2i(-de); // exact power of two
    let codes = block
        .codes
        .iter()
        .map(|&c| sl.quantize_code(sh.decode(c as u8) * down) as i8)
        .collect();
    MxBlock {
        format: target,
        scale_exp: ((block.scale_exp as i32 + de).min(SCALE_EXP_MAX)) as i8,
        codes,
    }
}

/// Slice-and-scale an entire plane of blocks (convenience for tensors).
pub fn slice_and_scale_all(
    blocks: &[MxBlock],
    target: ElementFormat,
    mode: RoundMode,
) -> Result<Vec<MxBlock>> {
    blocks
        .iter()
        .map(|b| slice_and_scale(b, target, mode))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::mxblock::{decode_block, encode_block};
    use crate::util::props::{run_cases, Gen};
    use crate::util::stats::mse;

    fn enc(vals: &[f32], f: ElementFormat) -> MxBlock {
        encode_block(vals, f, RoundMode::HalfEven)
    }

    #[test]
    fn ss_int_scale_matches_direct_quantization_scale() {
        // Paper §3.3: the SS scale equals the direct-quantization scale,
        // because only e_max differs between precisions.
        let vals = [0.9f32, -0.3, 0.05, 0.61];
        for bl in 2..=8u8 {
            let anchor = enc(&vals, ElementFormat::int(8));
            let ss = slice_and_scale(&anchor, ElementFormat::int(bl), RoundMode::HalfEven).unwrap();
            let direct = enc(&vals, ElementFormat::int(bl));
            assert_eq!(ss.scale_exp, direct.scale_exp, "bl={bl}");
        }
    }

    #[test]
    fn ss_fp_scale_matches_direct() {
        let vals = [0.9f32, -0.3, 0.05, 0.61];
        let anchor = enc(&vals, ElementFormat::fp(4, 3));
        for bl in 4..=8u8 {
            let tgt = ElementFormat::fp_from_bits(bl);
            let ss = slice_and_scale(&anchor, tgt, RoundMode::HalfEven).unwrap();
            let direct = enc(&vals, tgt);
            assert_eq!(ss.scale_exp, direct.scale_exp, "bl={bl}");
        }
    }

    #[test]
    fn ss_identity_when_same_format() {
        let vals = [0.4f32, -0.7, 0.1];
        let b = enc(&vals, ElementFormat::int(8));
        let ss = slice_and_scale(&b, ElementFormat::int(8), RoundMode::HalfEven).unwrap();
        assert_eq!(b, ss);
        let bf = enc(&vals, ElementFormat::fp(4, 3));
        let ssf = slice_and_scale(&bf, ElementFormat::fp(4, 3), RoundMode::HalfEven).unwrap();
        assert_eq!(bf, ssf);
    }

    #[test]
    fn cross_family_rejected() {
        let b = enc(&[1.0], ElementFormat::int(8));
        assert!(slice_and_scale(&b, ElementFormat::fp(2, 1), RoundMode::HalfEven).is_err());
        let bf = enc(&[1.0], ElementFormat::fp(4, 3));
        assert!(slice_and_scale(&bf, ElementFormat::int(4), RoundMode::HalfEven).is_err());
    }

    #[test]
    fn up_conversion_rejected() {
        let b = enc(&[1.0], ElementFormat::int(4));
        assert!(slice_and_scale(&b, ElementFormat::int(8), RoundMode::HalfEven).is_err());
        let bf = enc(&[1.0], ElementFormat::fp(2, 1));
        assert!(slice_and_scale(&bf, ElementFormat::fp(4, 3), RoundMode::HalfEven).is_err());
    }

    #[test]
    fn ss_int_equals_shift_semantics() {
        // Eq. 4: reconstruction X_l·P_l ≈ X_h·P_h.
        let vals: Vec<f32> = (0..32).map(|i| ((i * 37 % 64) as f32 - 32.0) / 19.0).collect();
        let anchor = enc(&vals, ElementFormat::int(8));
        let anchor_dec = decode_block(&anchor);
        let ss4 = slice_and_scale(&anchor, ElementFormat::int(4), RoundMode::HalfEven).unwrap();
        let ss_dec = decode_block(&ss4);
        let xl = exp2i(ss4.scale_exp as i32);
        for (h, l) in anchor_dec.iter().zip(&ss_dec) {
            // Residual bounded by the low-precision rounding bin (X_l/2),
            // plus the negative-clip corner.
            assert!((h - l).abs() <= xl * 0.5 + 1e-9, "h={h} l={l} xl={xl}");
        }
    }

    #[test]
    fn prop_ss_close_to_direct_quantization() {
        // The headline SS claim (paper §4.3 / App. C): SS from an 8-bit
        // anchor closely matches direct quantization from FP32. The two can
        // differ by one quantization bin (double rounding) but the MSE gap
        // must stay within a small factor.
        run_cases("SS ≈ direct", 48, |g: &mut Gen| {
            let n = g.len(8, 64);
            let vals: Vec<f32> = (0..n).map(|_| g.rng.normal()).collect();
            for (anchor_f, targets) in [
                (
                    ElementFormat::int(8),
                    (2..=7u8).map(ElementFormat::int).collect::<Vec<_>>(),
                ),
                (
                    ElementFormat::fp(4, 3),
                    (4..=7u8).map(ElementFormat::fp_from_bits).collect(),
                ),
            ] {
                let anchor = enc(&vals, anchor_f);
                let anchor_dec = decode_block(&anchor);
                let m_anchor = mse(&vals, &anchor_dec);
                for &t in &targets {
                    let ss = slice_and_scale(&anchor, t, RoundMode::HalfEven).unwrap();
                    let ss_dec = decode_block(&ss);
                    let direct = enc(&vals, t);
                    let direct_dec = decode_block(&direct);
                    let m_ss = mse(&vals, &ss_dec);
                    let m_direct = mse(&vals, &direct_dec);
                    // Sound per-element bound: SS error ≤ direct bin radius +
                    // anchor bin radius (double rounding). In MSE terms that
                    // is ≤ (√direct + √anchor)² per element, relaxed to a
                    // 2.5× multiplicative + anchor-additive bound. The
                    // statistical SS≈direct claim (gap ≈ 0 at scale) is
                    // checked by experiment fig19/fig20 on 100×1024 tensors.
                    let bound = 2.5 * m_direct + 8.0 * m_anchor + 1e-12;
                    if m_ss > bound {
                        return Err(format!(
                            "anchor={anchor_f} target={t}: ss mse {m_ss} vs bound {bound} (direct {m_direct}, anchor {m_anchor})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ss_int_elements_match_shift_round_of_codes() {
        run_cases("SSMXINT = shift+round on codes", 32, |g: &mut Gen| {
            let n = g.len(4, 48);
            let vals = g.f32_vec_wild(n);
            let anchor = enc(&vals, ElementFormat::int(8));
            for bl in [2u8, 3, 5, 7] {
                let ss = slice_and_scale(&anchor, ElementFormat::int(bl), RoundMode::HalfEven)
                    .unwrap();
                let (lo, hi) = int_range(bl);
                for (i, (&ch, &cl)) in anchor.codes.iter().zip(&ss.codes).enumerate() {
                    let want = shift_round(ch as i32, (8 - bl) as u32, RoundMode::HalfEven)
                        .clamp(lo, hi);
                    if cl as i32 != want {
                        return Err(format!("i={i} ch={ch} bl={bl}: got {cl}, want {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chained_ss_matches_one_hop_scale() {
        // 8→6→4 vs 8→4: scales must agree; elements may differ by a bin.
        let vals: Vec<f32> = (0..32).map(|i| ((i as f32) * 0.13).cos()).collect();
        let anchor = enc(&vals, ElementFormat::int(8));
        let hop6 = slice_and_scale(&anchor, ElementFormat::int(6), RoundMode::HalfEven).unwrap();
        let hop64 = slice_and_scale(&hop6, ElementFormat::int(4), RoundMode::HalfEven).unwrap();
        let direct4 = slice_and_scale(&anchor, ElementFormat::int(4), RoundMode::HalfEven).unwrap();
        assert_eq!(hop64.scale_exp, direct4.scale_exp);
        for (a, b) in hop64.codes.iter().zip(&direct4.codes) {
            assert!((a - b).abs() <= 1);
        }
    }

    #[test]
    fn ss_fp_e4m3_to_e2m1_delta_e() {
        // Δe = emax(E4)−emax(E2) = 8−2 = 6.
        let vals = [1.0f32, -0.5, 0.25];
        let anchor = enc(&vals, ElementFormat::fp(4, 3));
        let ss = slice_and_scale(&anchor, ElementFormat::fp(2, 1), RoundMode::HalfEven).unwrap();
        assert_eq!(ss.scale_exp as i32, anchor.scale_exp as i32 + 6);
    }

    #[test]
    fn scale_exp_saturates_at_max() {
        // A block whose anchor scale is already at the max must not overflow.
        let anchor = MxBlock {
            format: ElementFormat::int(8),
            scale_exp: 125,
            codes: vec![100, -100],
        };
        let ss = slice_and_scale(&anchor, ElementFormat::int(2), RoundMode::HalfEven).unwrap();
        assert_eq!(ss.scale_exp as i32, SCALE_EXP_MAX);
    }
}
