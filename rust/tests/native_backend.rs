//! Native backend integration: packed-GEMM forward parity against the
//! dequantize-then-f32-matmul oracle, end-to-end serving of every
//! MXINT{4,6,8}/MXFP{4,6,8} format from one anchor checkpoint, and the
//! engine's conversion/caching behaviour — all with **no** AOT artifacts.

use mfqat::backend::forward::{forward_cached, forward_logits, score_rows, ActMode, KvCache};
use mfqat::backend::NativeWeights;
use mfqat::checkpoint::Checkpoint;
use mfqat::coordinator::ElasticEngine;
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};

/// Small deterministic model: 2 layers, d_model 32, vocab 64, seq 16.
fn test_dims() -> ModelDims {
    let mut dims = ModelDims::new("parity", 64, 32, 2, 2, 16);
    dims.train_batch = 4;
    dims
}

fn anchor_ck(dims: &ModelDims, seed: u64, anchor: ElementFormat) -> Checkpoint {
    let manifest = dims.to_manifest();
    ParamSet::init(&manifest, seed)
        .to_anchor_checkpoint(&manifest, anchor)
        .unwrap()
}

fn token_rows(dims: &ModelDims, rows: usize, width: usize, seed: u64) -> Vec<i32> {
    (0..rows * width)
        .map(|i| (((i as u64 * 13 + seed * 17) % dims.vocab as u64) as i32))
        .collect()
}

#[test]
fn native_forward_matches_dequantize_oracle_all_formats() {
    let dims = test_dims();
    for (anchor, targets) in [
        (
            ElementFormat::int(8),
            vec![
                ElementFormat::int(8),
                ElementFormat::int(6),
                ElementFormat::int(4),
            ],
        ),
        (
            ElementFormat::fp_from_bits(8),
            vec![
                ElementFormat::fp_from_bits(8),
                ElementFormat::fp_from_bits(6),
                ElementFormat::fp_from_bits(4),
            ],
        ),
    ] {
        let ck = anchor_ck(&dims, 21, anchor);
        let tokens = token_rows(&dims, 4, dims.seq_len, 1);
        for fmt in targets {
            let packed = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
            let oracle = NativeWeights::dense_from_checkpoint(&dims, &ck, Some(fmt)).unwrap();
            // Logit-level parity.
            let lp = forward_logits(&packed, &tokens, 4).unwrap();
            let lo = forward_logits(&oracle, &tokens, 4).unwrap();
            assert_eq!(lp.len(), 4 * dims.seq_len * dims.vocab);
            for (i, (a, b)) in lp.iter().zip(&lo).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{} logit[{i}]: packed {a} vs oracle {b}",
                    fmt.long_name()
                );
            }
            // NLL-level parity (the acceptance criterion's 1e-4 bound).
            let windows = token_rows(&dims, 4, dims.seq_len + 1, 2);
            let np = score_rows(&packed, &windows, 4).unwrap();
            let no = score_rows(&oracle, &windows, 4).unwrap();
            for (a, b) in np.iter().zip(&no) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{} nll: packed {a} vs oracle {b}",
                    fmt.long_name()
                );
            }
        }
    }
}

#[test]
fn engine_serves_every_paper_format_from_one_anchor() {
    let dims = test_dims();
    // MXINT family from the MXINT8 anchor.
    let engine = ElasticEngine::native(
        dims.clone(),
        anchor_ck(&dims, 22, ElementFormat::int(8)),
        256 << 20,
    )
    .unwrap();
    assert_eq!(engine.backend_name(), "native");
    let batch = token_rows(&dims, 4, dims.seq_len + 1, 3);
    let uniform = (dims.vocab as f32).ln();
    for bits in [4u8, 6, 8] {
        let nll = engine.score_batch(&batch, ElementFormat::int(bits)).unwrap();
        assert_eq!(nll.len(), dims.train_batch);
        for v in &nll {
            assert!(v.is_finite() && *v > 0.0, "int{bits}: nll={v}");
            // Untrained model stays near uniform at every precision.
            assert!((v - uniform).abs() < 2.0, "int{bits}: {v} vs uniform {uniform}");
        }
    }
    // One conversion per distinct format; repeats hit the cache.
    assert_eq!(engine.conversions(), 3);
    engine.score_batch(&batch, ElementFormat::int(6)).unwrap();
    assert_eq!(engine.conversions(), 3, "repeat is a cache hit");
    assert_eq!(engine.cached_formats(), 3);

    // MXFP family from the MXFP8 anchor.
    let engine_fp = ElasticEngine::native(
        dims.clone(),
        anchor_ck(&dims, 23, ElementFormat::fp_from_bits(8)),
        256 << 20,
    )
    .unwrap();
    for bits in [4u8, 6, 8] {
        let fmt = ElementFormat::fp_from_bits(bits);
        let nll = engine_fp.score_batch(&batch, fmt).unwrap();
        assert!(nll.iter().all(|v| v.is_finite() && *v > 0.0), "fp{bits}");
    }
    assert_eq!(engine_fp.conversions(), 3);
}

#[test]
fn lower_precision_costs_fewer_cache_bytes() {
    // The native cache holds *packed* weight sets and Arc-shares the
    // unquantized f32 params: an entry is charged only its packed planes,
    // and MXINT4 planes are roughly half the MXINT8 bytes.
    let dims = test_dims();
    let ck = anchor_ck(&dims, 24, ElementFormat::int(8));
    let w8 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let w4 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(4)).unwrap();
    let quant8: usize = w8.packed_bytes();
    let quant4: usize = w4.packed_bytes();
    assert!(
        quant4 < quant8,
        "packed int4 planes ({quant4} B) must be smaller than int8 ({quant8} B)"
    );
    // Half the code bits ⇒ roughly half the plane bytes (scales identical).
    assert!(quant4 * 2 < quant8 + quant8 / 4, "int4 ~ half of int8: {quant4} vs {quant8}");

    let engine = ElasticEngine::native(dims, ck, 256 << 20).unwrap();
    engine
        .score_batch(
            &token_rows(&test_dims(), 4, test_dims().seq_len + 1, 4),
            ElementFormat::int(4),
        )
        .unwrap();
    let stats = engine.cache_stats();
    assert_eq!(
        stats.used_bytes, quant4,
        "cache charges packed planes only (shared f32 params ride the Arc)"
    );
}

#[test]
fn kv_incremental_decode_matches_full_window_all_formats() {
    // Prefill + one-token decode steps must reproduce the full-window
    // forward logits exactly at every position, for every ElementFormat
    // the paper evaluates, in both activation modes (the decode path is
    // deterministic per position — same op order as the batch forward).
    let dims = test_dims();
    let vocab = dims.vocab;
    for (anchor, fmts) in [
        (ElementFormat::int(8), ElementFormat::all_int()),
        (ElementFormat::fp_from_bits(8), ElementFormat::all_fp()),
    ] {
        let ck = anchor_ck(&dims, 31, anchor);
        let tokens = token_rows(&dims, 1, dims.seq_len, 7);
        for fmt in fmts {
            for act in [ActMode::F32, ActMode::Int8] {
                let mut w =
                    NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
                w.act = act;
                let full = forward_logits(&w, &tokens, 1).unwrap();
                let p0 = dims.seq_len / 2;
                let mut cache = KvCache::new(&dims);
                let prefill = forward_cached(&w, &mut cache, &tokens[..p0]).unwrap();
                assert_eq!(cache.len(), p0);
                assert_eq!(
                    prefill,
                    full[..p0 * vocab].to_vec(),
                    "{} act={}: prefill logits",
                    fmt.long_name(),
                    act.name()
                );
                for i in p0..dims.seq_len {
                    let step = forward_cached(&w, &mut cache, &tokens[i..i + 1]).unwrap();
                    assert_eq!(
                        step,
                        full[i * vocab..(i + 1) * vocab].to_vec(),
                        "{} act={}: decode step at position {i}",
                        fmt.long_name(),
                        act.name()
                    );
                }
                assert_eq!(cache.len(), dims.seq_len);
            }
        }
    }
}

#[test]
fn int_mac_scoring_tracks_f32_activations() {
    // End-to-end: the integer-MAC pipeline (i8 activations) must score
    // within activation-quantization error of the exact f32-activation
    // path, at every MXINT precision.
    let dims = test_dims();
    let ck = anchor_ck(&dims, 32, ElementFormat::int(8));
    let windows = token_rows(&dims, 4, dims.seq_len + 1, 8);
    for bits in [2u8, 4, 6, 8] {
        let fmt = ElementFormat::int(bits);
        let exact = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
        let mut intmac = exact.clone();
        intmac.act = ActMode::Int8;
        let a = score_rows(&exact, &windows, 4).unwrap();
        let b = score_rows(&intmac, &windows, 4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(y.is_finite(), "int{bits}: nll must be finite");
            assert!(
                (x - y).abs() < 1e-2,
                "int{bits}: act-quantization drift {x} vs {y}"
            );
        }
    }
}

#[test]
fn forward_logits_shape_through_engine() {
    let dims = test_dims();
    let engine =
        ElasticEngine::native(dims.clone(), anchor_ck(&dims, 25, ElementFormat::int(8)), 1 << 20)
            .unwrap();
    let tokens = token_rows(&dims, dims.train_batch, dims.seq_len, 5);
    let logits = engine
        .forward_logits(&tokens, ElementFormat::int(8))
        .unwrap();
    assert_eq!(logits.len(), dims.train_batch * dims.seq_len * dims.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
    // Wrong shapes are rejected, not mis-scored.
    assert!(engine.forward_logits(&tokens[1..], ElementFormat::int(8)).is_err());
}

#[test]
fn more_bits_track_the_oracle_more_closely() {
    // Quantization error of the packed forward (vs the fp32 dense forward)
    // must shrink as precision grows — the elastic accuracy knob.
    let dims = test_dims();
    let ck = anchor_ck(&dims, 26, ElementFormat::int(8));
    let fp32 = NativeWeights::dense_from_checkpoint(&dims, &ck, None).unwrap();
    let tokens = token_rows(&dims, 4, dims.seq_len + 1, 6);
    let base = score_rows(&fp32, &tokens, 4).unwrap();
    let mut errs = Vec::new();
    for bits in [2u8, 4, 8] {
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(bits))
            .unwrap();
        let nll = score_rows(&w, &tokens, 4).unwrap();
        let err: f64 = nll
            .iter()
            .zip(&base)
            .map(|(a, b)| ((a - b) as f64).abs())
            .sum();
        errs.push(err);
    }
    assert!(
        errs[2] <= errs[0] + 1e-9,
        "int8 must track the anchor at least as well as int2: {errs:?}"
    );
}
