//! Batched KV-cached decode: `generate_batch` with ragged prompt lengths
//! must be **token-identical** to N independent single-sequence `generate`
//! calls — for every `ElementFormat` the paper evaluates, in both
//! activation modes. Exactness assertions, not tolerances: every per-row
//! computation in the batched forward is row-independent, so the outputs
//! must agree bit for bit.
//!
//! The continuous-batching sections extend the same oracle to **per-row
//! elastic formats** and **mid-flight membership changes**: rows in
//! MXINT8/MXINT4/MXFP8 decode in one step-synchronized pass, prompts join
//! and retire between any two steps, freed slots are reused — and every
//! row's text must still equal a solo decode at that row's format.

use mfqat::backend::forward::{forward_cached, forward_cached_batch, KvCache};
use mfqat::backend::{ActMode, DecodeSession as _, NativeWeights, SharedParams};
use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::{ContinuousBatch, generate_native, generate_native_batch, SampleCfg};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use std::collections::HashMap;
use std::sync::Arc;

/// Byte-level prompts need the full 256-token vocab; keep everything else
/// tiny so the full format × act-mode matrix stays fast.
fn gen_dims() -> ModelDims {
    let mut dims = ModelDims::new("batchgen", 256, 32, 1, 2, 10);
    dims.train_batch = 4;
    dims
}

fn anchor(dims: &ModelDims, seed: u64, fmt: ElementFormat) -> mfqat::checkpoint::Checkpoint {
    let m = dims.to_manifest();
    ParamSet::init(&m, seed).to_anchor_checkpoint(&m, fmt).unwrap()
}

#[test]
fn generate_batch_token_identical_all_formats_and_act_modes() {
    let dims = gen_dims();
    // Ragged prompts: shorter than, equal to, and longer than the window,
    // plus empty (PAD-seeded) — rows hit the re-prefill path at different
    // steps, so decode batches go ragged mid-run.
    let prompts = ["k", "kova query", "the color of kova is violet", ""];
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 33,
    };
    let n_tokens = 2 * dims.seq_len; // well past the window: forced overflow
    for (anchor_fmt, targets) in [
        (ElementFormat::int(8), ElementFormat::all_int()),
        (ElementFormat::fp_from_bits(8), ElementFormat::all_fp()),
    ] {
        let ck = anchor(&dims, 41, anchor_fmt);
        for fmt in targets {
            for act in [ActMode::F32, ActMode::Int8] {
                let mut w =
                    NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
                w.act = act;
                let batch = generate_native_batch(&w, &prompts, n_tokens, &cfg).unwrap();
                assert_eq!(batch.len(), prompts.len());
                for (r, p) in prompts.iter().enumerate() {
                    let solo = generate_native(&w, p, n_tokens, &cfg).unwrap();
                    assert_eq!(solo.chars().count(), n_tokens, "one char per token");
                    assert_eq!(
                        batch[r],
                        solo,
                        "{} act={} row {r} (prompt {p:?}): batched decode diverged",
                        fmt.long_name(),
                        act.name()
                    );
                }
            }
        }
    }
}

#[test]
fn engine_generate_batch_matches_engine_generate() {
    // The Backend/engine surface routes through the same batched decode.
    let dims = gen_dims();
    let ck = anchor(&dims, 42, ElementFormat::int(8));
    let engine = ElasticEngine::native(dims.clone(), ck, 64 << 20).unwrap();
    let cfg = SampleCfg {
        temperature: 0.6,
        top_k: 4,
        seed: 7,
    };
    let prompts = ["ab", "kova", "q"];
    let batch = engine
        .generate_batch(&prompts, ElementFormat::int(4), 12, &cfg)
        .unwrap();
    for (r, p) in prompts.iter().enumerate() {
        let solo = engine.generate(p, ElementFormat::int(4), 12, &cfg).unwrap();
        assert_eq!(batch[r], solo, "row {r}");
    }
    // Batched generation at a new format is one cache derivation.
    assert_eq!(engine.cached_formats(), 1);
}

/// Build one weight set per format, all sharing a single `Arc`'d f32
/// parameter set (the precondition for mixing rows in one batch).
fn shared_weight_sets(
    dims: &ModelDims,
    ck: &mfqat::checkpoint::Checkpoint,
    formats: &[ElementFormat],
    act: ActMode,
) -> Vec<NativeWeights> {
    let shared = Arc::new(SharedParams::from_checkpoint(dims, ck).unwrap());
    formats
        .iter()
        .map(|&fmt| {
            NativeWeights::packed_with_shared(dims, ck, fmt, shared.clone(), act).unwrap()
        })
        .collect()
}

#[test]
fn mixed_format_rows_with_midflight_joins_match_solo() {
    // The acceptance scenario: rows in MXINT8, MXINT4 and MXFP8 decode in
    // ONE step-synchronized batch; a third prompt joins mid-flight; the
    // first finished row's slot is immediately reused by a fourth prompt
    // with yet another budget — and every row's continuation is exactly the
    // tokens of a solo `generate_native` with that row's weight set.
    let dims = gen_dims();
    let ck = anchor(&dims, 44, ElementFormat::int(8));
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 19,
    };
    for act in [ActMode::F32, ActMode::Int8] {
        let ws = shared_weight_sets(
            &dims,
            &ck,
            &[
                ElementFormat::int(8),
                ElementFormat::int(4),
                ElementFormat::fp_from_bits(8),
            ],
            act,
        );
        let (w8, w4, wf8) = (&ws[0], &ws[1], &ws[2]);
        let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::new(&dims, 3);
        let mut expect: HashMap<usize, (&NativeWeights, &str, usize)> = HashMap::new();
        // n_tokens > seq_len on one row so a re-prefill lands mid-batch.
        let s = cb.join(w8, "kova", 10, &cfg).unwrap();
        expect.insert(s, (w8, "kova", 10));
        let s = cb.join(w4, "the color of kova is violet", dims.seq_len + 6, &cfg).unwrap();
        expect.insert(s, (w4, "the color of kova is violet", dims.seq_len + 6));

        let mut steps = 0usize;
        let mut joined_fp8 = false;
        let mut reused_slot = false;
        let mut finished_rows = 0usize;
        while cb.active() > 0 {
            for f in cb.step().unwrap() {
                let (w, p, n) = expect.remove(&f.slot).expect("unexpected slot finished");
                let solo = generate_native(w, p, n, &cfg).unwrap();
                assert_eq!(
                    f.text, solo,
                    "act={} slot {} (prompt {p:?}, fmt {:?}): continuous decode diverged",
                    act.name(),
                    f.slot,
                    w.fmt
                );
                finished_rows += 1;
                if !reused_slot {
                    // Immediately reuse the freed slot while the other
                    // rows keep decoding — in a different format again.
                    let s = cb.join(w4, "q", 8, &cfg).unwrap();
                    assert_eq!(s, f.slot, "lowest free slot is the one just retired");
                    expect.insert(s, (w4, "q", 8));
                    reused_slot = true;
                }
            }
            steps += 1;
            if steps == 2 {
                // Mid-flight join in a third format: prefill-on-join rides
                // the next step while neighbours decode single tokens.
                let s = cb.join(wf8, "blue", 12, &cfg).unwrap();
                expect.insert(s, (wf8, "blue", 12));
                joined_fp8 = true;
            }
            assert!(steps < 500, "continuous decode did not converge");
        }
        assert!(joined_fp8 && reused_slot);
        assert_eq!(finished_rows, 4, "all four sequences completed");
        assert!(expect.is_empty());
    }
}

#[test]
fn engine_decode_session_serves_mixed_formats_with_joins() {
    // The Backend surface the server drives: per-row formats resolve
    // through the engine's FormatCache, mid-flight joins and cancels work,
    // and every row matches the engine's own solo `generate`.
    let dims = gen_dims();
    let ck = anchor(&dims, 45, ElementFormat::int(8));
    let engine = ElasticEngine::native(dims.clone(), ck, 64 << 20).unwrap();
    let cfg = SampleCfg {
        temperature: 0.6,
        top_k: 4,
        seed: 5,
    };
    let mut session = engine.decode_session(3).unwrap();
    assert_eq!(session.capacity(), 3);
    let mut expect: HashMap<usize, (&str, ElementFormat, usize)> = HashMap::new();
    for (p, fmt, n) in [
        ("kova", ElementFormat::int(8), 9usize),
        ("ab", ElementFormat::int(4), 13),
    ] {
        let s = session.join(p, fmt, n, &cfg).unwrap();
        expect.insert(s, (p, fmt, n));
    }
    let mut steps = 0usize;
    let mut joined_late = false;
    let mut finished_rows = 0usize;
    while session.active() > 0 {
        for f in session.step().unwrap() {
            let (p, fmt, n) = expect.remove(&f.slot).expect("unexpected slot finished");
            let solo = engine.generate(p, fmt, n, &cfg).unwrap();
            assert_eq!(f.text, solo, "slot {} ({p:?} at {fmt}) diverged", f.slot);
            finished_rows += 1;
        }
        steps += 1;
        if steps == 3 && !joined_late {
            let s = session
                .join("blue", ElementFormat::fp_from_bits(6), 7, &cfg)
                .unwrap();
            expect.insert(s, ("blue", ElementFormat::fp_from_bits(6), 7));
            joined_late = true;
        }
        assert!(steps < 300, "session did not converge");
    }
    assert_eq!(finished_rows, 3);
    assert!(expect.is_empty());
    // Cancel frees the slot without emitting a result.
    let s = session.join("qq", ElementFormat::int(6), 50, &cfg).unwrap();
    session.step().unwrap();
    session.cancel(s).unwrap();
    assert_eq!(session.active(), 0);
    assert!(session.cancel(s).is_err(), "double-cancel is an error");
}

#[test]
fn prop_join_retire_order_never_changes_surviving_rows() {
    // Property: retiring a random row mid-decode and joining a new prompt
    // into the freed slot never perturbs the surviving rows — each still
    // emits exactly its solo tokens, whatever the membership churn.
    let dims = gen_dims();
    let ck = anchor(&dims, 46, ElementFormat::int(8));
    let formats = [
        ElementFormat::int(8),
        ElementFormat::int(6),
        ElementFormat::int(4),
        ElementFormat::fp_from_bits(8),
    ];
    let weights = shared_weight_sets(&dims, &ck, &formats, ActMode::F32);
    let prompts = ["k", "kova blue", "the color of kova", "", "qq"];
    let cfg = SampleCfg {
        temperature: 0.9,
        top_k: 5,
        seed: 27,
    };
    mfqat::util::props::run_cases("join_retire_survivors", 10, |g| {
        let rows = 3usize;
        let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::new(&dims, rows);
        let mut expect: HashMap<usize, (&NativeWeights, &str, usize)> = HashMap::new();
        let max_n = 4 + g.len(2, 2 * dims.seq_len);
        for _ in 0..rows {
            let w = &weights[g.rng.below(weights.len())];
            let p = prompts[g.rng.below(prompts.len())];
            let n = g.rng.range(4, max_n + 1);
            let s = cb.join(w, p, n, &cfg).unwrap();
            expect.insert(s, (w, p, n));
        }
        // A few steps in (before anything can finish: n ≥ 4), retire a
        // random live row and join a fresh prompt into the freed slot.
        let retire_after = g.rng.range(1, 4);
        for _ in 0..retire_after {
            if !cb.step().map_err(|e| e.to_string())?.is_empty() {
                return Err("a row finished before its budget".into());
            }
        }
        let victims: Vec<usize> = expect.keys().copied().collect();
        let victim = victims[g.rng.below(victims.len())];
        cb.retire(victim).map_err(|e| e.to_string())?;
        expect.remove(&victim);
        let w = &weights[g.rng.below(weights.len())];
        let p = prompts[g.rng.below(prompts.len())];
        let n = g.rng.range(4, max_n + 1);
        let s = cb.join(w, p, n, &cfg).map_err(|e| e.to_string())?;
        if s != victim {
            return Err(format!("expected freed slot {victim}, joined into {s}"));
        }
        expect.insert(s, (w, p, n));
        // Run to completion: every surviving (and newly joined) row must
        // match its solo decode exactly.
        let mut steps = 0usize;
        while cb.active() > 0 {
            for f in cb.step().map_err(|e| e.to_string())? {
                let (w, p, n) = expect
                    .remove(&f.slot)
                    .ok_or_else(|| format!("unexpected slot {} finished", f.slot))?;
                let solo = generate_native(w, p, n, &cfg).map_err(|e| e.to_string())?;
                if f.text != solo {
                    return Err(format!(
                        "slot {} (prompt {p:?}, fmt {:?}, n={n}) diverged after churn: \
                         batch {:?} vs solo {:?}",
                        f.slot, w.fmt, f.text, solo
                    ));
                }
            }
            steps += 1;
            if steps > 4 * max_n + 50 {
                return Err("decode did not converge".into());
            }
        }
        if !expect.is_empty() {
            return Err("not every joined row finished".into());
        }
        Ok(())
    });
}

#[test]
fn batched_prefill_logits_match_single_sequence_prefill() {
    // Scoring-shaped check on the batched cache itself: a ragged batched
    // prefill reproduces each row's single-sequence prefill logits exactly
    // (the decode exactness above builds on this).
    let dims = gen_dims();
    let ck = anchor(&dims, 43, ElementFormat::int(8));
    let vocab = dims.vocab;
    let rows_tok: Vec<Vec<i32>> = vec![
        (0..3).map(|i| (i * 31 + 5) as i32 % 256).collect(),
        (0..9).map(|i| (i * 17 + 2) as i32 % 256).collect(),
        (0..6).map(|i| (i * 7 + 11) as i32 % 256).collect(),
    ];
    for fmt in [ElementFormat::int(8), ElementFormat::fp_from_bits(6)] {
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
        let mut cache = KvCache::with_rows(&dims, rows_tok.len());
        let step: Vec<&[i32]> = rows_tok.iter().map(|t| t.as_slice()).collect();
        let batched = forward_cached_batch(&w, &mut cache, &step).unwrap();
        let mut off = 0usize;
        for (r, row) in rows_tok.iter().enumerate() {
            let mut solo_cache = KvCache::new(&dims);
            let solo = forward_cached(&w, &mut solo_cache, row).unwrap();
            assert_eq!(
                &batched[off * vocab..(off + row.len()) * vocab],
                solo.as_slice(),
                "{}: row {r}",
                fmt.long_name()
            );
            off += row.len();
        }
    }
}
