//! Tiny stderr logger wired to the `log` facade.
//!
//! Level is controlled by `MFQAT_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("MFQAT_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::new(StderrLogger {
        start: Instant::now(),
    });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
