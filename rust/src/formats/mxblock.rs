//! Block-level MX encode/decode — paper Eq. 1–3.
//!
//! For a block of scalars `V = {V_i}` the MX conversion computes
//!
//! ```text
//! shared_exp = floor(log2 max_i |V_i|) − e_max(f)        (Eq. 1/3/5)
//! X          = 2^shared_exp
//! P_i        = quantize_f(V_i / X)                       (Eq. 2)
//! ```
//!
//! and reconstructs `V̂_i = X · P_i`. The shared exponent is stored as an
//! `i8` (E8M0-like scale datatype), clamped to `[−127, 127]`; an all-zero
//! block stores the minimum exponent and all-zero elements.

use super::int::quantize_int;
use super::{exp2i, floor_log2, ElementFormat};

/// Rounding mode for integer element quantization and SSMXINT shifts.
///
/// `HalfEven` (default) matches the jnp oracle / OCP conversions; `HalfAway`
/// is the "round using the most-significant dropped bit" variant mentioned in
/// paper §3.3, kept for the ablation benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RoundMode {
    /// Round half to even (the default; matches the jnp oracle and OCP conversions).
    #[default]
    HalfEven,
    /// Round half away from zero (paper §3.3 ablation variant).
    HalfAway,
}

/// Minimum/maximum stored shared exponent (E8M0-like scale range).
///
/// The lower bound is −126, not −127: XLA CPU flushes subnormal f32 results
/// to zero, so a 2^−127 scale would decode differently between the jnp
/// oracle and this bit-exact path. Clamping the scale to the f32 *normal*
/// range keeps rust ↔ python golden parity; blocks that small quantize to
/// zero anyway.
pub const SCALE_EXP_MIN: i32 = -126;
/// Maximum stored shared exponent (see [`SCALE_EXP_MIN`] for the range rationale).
pub const SCALE_EXP_MAX: i32 = 127;

/// One encoded MX block: a shared scale exponent plus element codes.
///
/// Element codes are stored uniformly as `i8`:
/// * `Int` formats: the two's-complement element value itself.
/// * `Fp` formats: the sign-magnitude minifloat code reinterpreted as `i8`
///   (only the low `bits()` bits are significant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MxBlock {
    /// Element format of the codes.
    pub format: ElementFormat,
    /// Shared E8M0-style scale exponent.
    pub scale_exp: i8,
    /// Element codes (`block_size` of them).
    pub codes: Vec<i8>,
}

/// Compute the shared exponent for a block (Eq. 1), clamped to the scale
/// datatype range. Returns `SCALE_EXP_MIN` for an all-zero (or all-nonfinite)
/// block.
pub fn shared_exponent(values: &[f32], format: ElementFormat) -> i32 {
    let mut max_abs = 0.0f32;
    for &v in values {
        let a = v.abs();
        // NaNs are ignored for the max (quantize maps them to 0); infinities
        // saturate the scale.
        if a.is_finite() && a > max_abs {
            max_abs = a;
        } else if a.is_infinite() {
            return SCALE_EXP_MAX;
        }
    }
    if max_abs == 0.0 {
        return SCALE_EXP_MIN;
    }
    (floor_log2(max_abs) - format.emax()).clamp(SCALE_EXP_MIN, SCALE_EXP_MAX)
}

/// Encode one block of values (Eq. 1–3). `values.len()` is the block size
/// (ragged final blocks are allowed).
pub fn encode_block(values: &[f32], format: ElementFormat, mode: RoundMode) -> MxBlock {
    let scale_exp = shared_exponent(values, format);
    let inv_scale = exp2i(-scale_exp); // exact power of two
    let codes = match format {
        ElementFormat::Int { bits } => values
            .iter()
            .map(|&v| quantize_int(v * inv_scale, bits, mode))
            .collect(),
        ElementFormat::Fp { .. } => {
            let spec = format.fp_spec().unwrap();
            values
                .iter()
                .map(|&v| spec.quantize_code(v * inv_scale) as i8)
                .collect()
        }
    };
    MxBlock {
        format,
        scale_exp: scale_exp as i8,
        codes,
    }
}

/// Decode a block back to f32 values (`V̂_i = X · P_i`).
pub fn decode_block(block: &MxBlock) -> Vec<f32> {
    let mut out = vec![0.0f32; block.codes.len()];
    decode_block_into(block, &mut out);
    out
}

/// Decode into a caller-provided buffer (hot path).
pub fn decode_block_into(block: &MxBlock, out: &mut [f32]) {
    assert_eq!(out.len(), block.codes.len());
    let scale = exp2i(block.scale_exp as i32);
    match block.format {
        ElementFormat::Int { .. } => {
            for (o, &c) in out.iter_mut().zip(&block.codes) {
                *o = c as f32 * scale;
            }
        }
        ElementFormat::Fp { .. } => {
            let spec = block.format.fp_spec().unwrap();
            for (o, &c) in out.iter_mut().zip(&block.codes) {
                *o = spec.decode(c as u8) * scale;
            }
        }
    }
}

/// Fake-quantize a whole slice blockwise: encode + decode (PTQ simulation).
pub fn fake_quantize(values: &[f32], format: ElementFormat, block_size: usize, mode: RoundMode) -> Vec<f32> {
    let mut out = vec![0.0f32; values.len()];
    for (chunk, ochunk) in values.chunks(block_size).zip(out.chunks_mut(block_size)) {
        let block = encode_block(chunk, format, mode);
        decode_block_into(&block, ochunk);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::props::{run_cases, Gen};

    #[test]
    fn shared_exp_matches_paper_examples() {
        // max|V| = 1.0 → floor(log2)=0; MXINT8 emax=6 → shared_exp=-6, X=2^-6.
        let f = ElementFormat::int(8);
        assert_eq!(shared_exponent(&[0.5, -1.0, 0.25], f), -6);
        // MXFP8 (E4M3) emax=8 → shared_exp=-8.
        let f8 = ElementFormat::fp(4, 3);
        assert_eq!(shared_exponent(&[1.0], f8), -8);
        // All-zero block.
        assert_eq!(shared_exponent(&[0.0, 0.0], f), SCALE_EXP_MIN);
    }

    #[test]
    fn max_element_never_clips_much() {
        // For the max-magnitude element, |code| must land in
        // [2^emax, 2^(emax+1)) before clipping — i.e. quantization uses the
        // top binade of the element format.
        let f = ElementFormat::int(8);
        for max in [1.0f32, 1.5, 1.99, 2.0, 3.7, 100.0, 1e-3] {
            let b = encode_block(&[max], f, RoundMode::HalfEven);
            let code = b.codes[0] as i32;
            assert!(code.abs() >= 64, "max={max} code={code}"); // 2^6
            assert!(code.abs() <= 127, "max={max} code={code}");
        }
    }

    #[test]
    fn roundtrip_error_bound_int() {
        // |x − decode(encode(x))| ≤ X/2 for in-range elements (RNE bin radius).
        let f = ElementFormat::int(4);
        let vals = [0.3f32, -0.95, 0.02, 1.0, -0.5, 0.77, -0.11, 0.0];
        let b = encode_block(&vals, f, RoundMode::HalfEven);
        let dec = decode_block(&b);
        let x = exp2i(b.scale_exp as i32);
        for (v, d) in vals.iter().zip(&dec) {
            // The most-negative code −8 is never needed here; bound holds.
            assert!((v - d).abs() <= x / 2.0 + 1e-9, "v={v} d={d} X={x}");
        }
    }

    #[test]
    fn all_zero_block_decodes_to_zero() {
        for f in [ElementFormat::int(4), ElementFormat::fp(2, 1)] {
            let b = encode_block(&[0.0; 16], f, RoundMode::HalfEven);
            assert!(decode_block(&b).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn nan_elements_become_zero() {
        let f = ElementFormat::int(8);
        let b = encode_block(&[f32::NAN, 1.0], f, RoundMode::HalfEven);
        let dec = decode_block(&b);
        assert_eq!(dec[0], 0.0);
        assert!((dec[1] - 1.0).abs() < 0.02);
    }

    #[test]
    fn subnormal_inputs_are_safe() {
        let f = ElementFormat::int(8);
        let tiny = f32::from_bits(1); // 2^-149
        let b = encode_block(&[tiny, -tiny], f, RoundMode::HalfEven);
        // Scale clamps at SCALE_EXP_MIN; elements quantize to ~0.
        assert_eq!(b.scale_exp as i32, SCALE_EXP_MIN);
        let dec = decode_block(&b);
        assert!(dec.iter().all(|x| x.abs() <= exp2i(-120)));
    }

    #[test]
    fn huge_inputs_saturate() {
        let f = ElementFormat::int(8);
        let b = encode_block(&[f32::MAX, 1.0], f, RoundMode::HalfEven);
        let dec = decode_block(&b);
        assert!(dec[0].is_finite());
        assert!(dec[0] > 1e37);
    }

    #[test]
    fn fp_block_roundtrip_fixed_points() {
        // Values already on the MXFP grid survive encode/decode exactly.
        let f = ElementFormat::fp(3, 2);
        let spec = f.fp_spec().unwrap();
        // Pick grid values scaled by a power of two.
        let vals: Vec<f32> = spec.magnitudes().iter().map(|m| m * 0.25).collect();
        let b = encode_block(&vals, f, RoundMode::HalfEven);
        let dec = decode_block(&b);
        for (v, d) in vals.iter().zip(&dec) {
            assert_eq!(v, d, "vals={vals:?} dec={dec:?}");
        }
    }

    #[test]
    fn prop_roundtrip_error_bounded() {
        run_cases("mx roundtrip error bound", 64, |g: &mut Gen| {
            let n = g.len(1, 64);
            let vals = g.f32_vec_wild(n);
            for f in [
                ElementFormat::int(2),
                ElementFormat::int(5),
                ElementFormat::int(8),
                ElementFormat::fp(2, 1),
                ElementFormat::fp(3, 2),
                ElementFormat::fp(4, 3),
            ] {
                let b = encode_block(&vals, f, RoundMode::HalfEven);
                let dec = decode_block(&b);
                let x = exp2i(b.scale_exp as i32);
                let max_abs = vals
                    .iter()
                    .filter(|v| v.is_finite())
                    .fold(0.0f32, |m, v| m.max(v.abs()));
                if !max_abs.is_finite() || max_abs == 0.0 || b.scale_exp as i32 == SCALE_EXP_MAX {
                    continue; // saturated/degenerate scales checked elsewhere
                }
                for (&v, &d) in vals.iter().zip(&dec) {
                    if !v.is_finite() {
                        continue;
                    }
                    // Worst-case absolute error: int → X (the RNE bin radius
                    // is X/2, but the positive clip at 2^(b−1)−1 can cost up
                    // to one extra step for the block max, e.g. MXINT2's
                    // range [−2, 1]); fp → relative 2^−(m+1) in range plus
                    // the top-of-binade clip, ≤ X·2^(emax−m+1) (factor 2
                    // covers E4M3's NaN-slot clip to 448).
                    let bound = match f {
                        ElementFormat::Int { .. } => x + 1e-30,
                        ElementFormat::Fp { man, .. } => {
                            let rel = exp2i(-(man as i32) - 1);
                            let clip = x * exp2i(f.emax() - man as i32 + 1);
                            (v.abs() * rel).max(clip) + 1e-30
                        }
                    };
                    let err = (v - d).abs();
                    if err > bound {
                        return Err(format!(
                            "fmt={f} v={v} d={d} err={err} bound={bound} X={x}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_scale_is_power_of_two_and_stable() {
        run_cases("scale power-of-two", 64, |g: &mut Gen| {
            let n = g.len(1, 96);
            let vals = g.f32_vec_wild(n);
            let f = ElementFormat::int(6);
            let b1 = encode_block(&vals, f, RoundMode::HalfEven);
            let b2 = encode_block(&vals, f, RoundMode::HalfEven);
            if b1 != b2 {
                return Err("encode must be deterministic".into());
            }
            let x = exp2i(b1.scale_exp as i32);
            if x <= 0.0 || x.log2().fract().abs() > 1e-6 {
                return Err(format!("scale {x} not a positive power of two"));
            }
            Ok(())
        });
    }

    #[test]
    fn fake_quantize_blocks_independent() {
        // Changing values in one block must not affect another block.
        let f = ElementFormat::int(4);
        let mut a = vec![0.1f32; 64];
        let fq1 = fake_quantize(&a, f, 32, RoundMode::HalfEven);
        a[40] = 100.0; // second block only
        let fq2 = fake_quantize(&a, f, 32, RoundMode::HalfEven);
        assert_eq!(&fq1[..32], &fq2[..32]);
        assert_ne!(&fq1[32..], &fq2[32..]);
    }

    #[test]
    fn ragged_final_block() {
        let f = ElementFormat::int(8);
        let vals: Vec<f32> = (0..50).map(|i| (i as f32 * 0.7).sin()).collect();
        let fq = fake_quantize(&vals, f, 32, RoundMode::HalfEven);
        assert_eq!(fq.len(), 50);
        // Final ragged block of 18 must be scaled on its own max.
        let tail_block = encode_block(&vals[32..], f, RoundMode::HalfEven);
        let tail_dec = decode_block(&tail_block);
        assert_eq!(&fq[32..], &tail_dec[..]);
    }
}
