//! Block-major repacked weights — the serving layout of the native GEMM.
//!
//! An [`MxTensor`] stores codes row-major (`[in_f, out_f]`, scaling blocks
//! along `out`) — the wire and checkpoint layout. The GEMM kernels instead
//! want to stream one *output block* at a time: all `in_f` code rows of a
//! single `block_size`-wide column group, contiguous, with that block's
//! scale column alongside. [`RepackedMx`] is exactly that layout, built once
//! per weight at `FormatCache` insert time:
//!
//! ```text
//! codes : [jb][k][n_in_block]   one plane per out-block jb; each (jb, k)
//!                               row is `block_size` codes (tail block
//!                               zero-padded) packed at the element width
//!                               and padded to whole bytes, so tile decode
//!                               is a straight byte-aligned streaming loop.
//! scales: [jb][k]               the transposed scale matrix — the GEMM
//!                               reads one contiguous scale column per
//!                               out-block instead of striding by
//!                               blocks-per-row (this is where the old
//!                               per-row-tile `exp2i` re-expansion went).
//! ```
//!
//! The transform is pure data movement: codes and scales are bit-identical
//! to the source tensor (round-trip enforced by tests), so numerics are
//! decided entirely by the kernel that consumes the layout.

use crate::formats::{pack, ElementFormat};
use crate::tensor::MxTensor;

/// A 2-D packed MX weight `[in_f, out_f]` in block-major serving layout.
#[derive(Debug, Clone)]
pub struct RepackedMx {
    /// Element format of the packed codes.
    pub elem: ElementFormat,
    /// MX scaling block size (codes per shared scale).
    pub block_size: usize,
    /// Input features (the reduction dimension).
    pub in_f: usize,
    /// Output features.
    pub out_f: usize,
    /// Block-major code planes (see module docs).
    codes: Vec<u8>,
    /// Block-major scales: `scales[jb * in_f + k]`.
    scales: Vec<i8>,
}

impl RepackedMx {
    /// Repack a row-major packed tensor into block-major serving form.
    pub fn from_mx(t: &MxTensor) -> RepackedMx {
        assert_eq!(t.shape.len(), 2, "repack wants a 2-D weight");
        let in_f = t.shape[0];
        let out_f = t.shape[1];
        let bs = t.format.block_size;
        let bpr = out_f.div_ceil(bs);
        let flat = t.unpack_codes();
        let mut tile_codes = vec![0i8; bpr * in_f * bs];
        let mut scales = vec![0i8; bpr * in_f];
        for jb in 0..bpr {
            let n0 = jb * bs;
            let nl = (out_f - n0).min(bs);
            for k in 0..in_f {
                tile_codes[(jb * in_f + k) * bs..][..nl]
                    .copy_from_slice(&flat[k * out_f + n0..][..nl]);
                scales[jb * in_f + k] = t.scales[k * bpr + jb];
            }
        }
        let codes = if in_f == 0 || out_f == 0 {
            Vec::new()
        } else {
            pack::pack_rows(&tile_codes, t.format.elem.bits(), bs)
        };
        RepackedMx {
            elem: t.format.elem,
            block_size: bs,
            in_f,
            out_f,
            codes,
            scales,
        }
    }

    /// Output blocks per row (`ceil(out_f / block_size)`).
    pub fn blocks(&self) -> usize {
        self.out_f.div_ceil(self.block_size)
    }

    /// Packed bytes of one `(jb, k)` code row.
    pub fn row_bytes(&self) -> usize {
        pack::packed_len(self.block_size, self.elem.bits())
    }

    /// Contiguous scale column of out-block `jb` (one `i8` exponent per `k`).
    pub fn scale_col(&self, jb: usize) -> &[i8] {
        &self.scales[jb * self.in_f..(jb + 1) * self.in_f]
    }

    /// Decode rows `k0..k0+kl` of out-block `jb` into `out` (sign-extended
    /// integer codes), `block_size` codes per row. `out.len()` must be
    /// `kl * block_size`.
    pub fn decode_tile_signed(&self, jb: usize, k0: usize, kl: usize, out: &mut [i8]) {
        let bs = self.block_size;
        assert_eq!(out.len(), kl * bs);
        let rb = self.row_bytes();
        let w = self.elem.bits();
        let base = (jb * self.in_f + k0) * rb;
        for k in 0..kl {
            pack::unpack_signed_into(&self.codes[base + k * rb..], w, &mut out[k * bs..][..bs]);
        }
    }

    /// Raw-code variant of [`Self::decode_tile_signed`] (minifloat planes).
    pub fn decode_tile_unsigned(&self, jb: usize, k0: usize, kl: usize, out: &mut [u8]) {
        let bs = self.block_size;
        assert_eq!(out.len(), kl * bs);
        let rb = self.row_bytes();
        let w = self.elem.bits();
        let base = (jb * self.in_f + k0) * rb;
        for k in 0..kl {
            pack::unpack_unsigned_into(&self.codes[base + k * rb..], w, &mut out[k * bs..][..bs]);
        }
    }

    /// Resident bytes (packed codes + scales) — cache accounting.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }

    /// Reconstruct the row-major code plane (tests / round-trip checks).
    pub fn to_row_major_codes(&self) -> Vec<i8> {
        let bs = self.block_size;
        let mut flat = vec![0i8; self.in_f * self.out_f];
        let mut row = vec![0i8; bs];
        for jb in 0..self.blocks() {
            let n0 = jb * bs;
            let nl = (self.out_f - n0).min(bs);
            for k in 0..self.in_f {
                self.decode_tile_signed(jb, k, 1, &mut row);
                flat[k * self.out_f + n0..][..nl].copy_from_slice(&row[..nl]);
            }
        }
        flat
    }

    /// Reconstruct the row-major scale matrix `[k][jb]` (tests).
    pub fn to_row_major_scales(&self) -> Vec<i8> {
        let bpr = self.blocks();
        let mut out = vec![0i8; self.in_f * bpr];
        for jb in 0..bpr {
            for k in 0..self.in_f {
                out[k * bpr + jb] = self.scales[jb * self.in_f + k];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{ElementFormat, MxFormat};
    use crate::util::props::{run_cases, Gen};

    #[test]
    fn prop_repack_round_trips_codes_and_scales() {
        // Block-major repack is pure data movement: codes and scales must
        // reconstruct bit-identically for every element format, including
        // ragged final blocks and non-multiple row counts.
        run_cases("repack roundtrip", 24, |g: &mut Gen| {
            let in_f = g.len(1, 70);
            let out_f = g.len(1, 90);
            let bs = [8usize, 16, 32][g.rng.range(0, 3)];
            let data: Vec<f32> = (0..in_f * out_f).map(|_| g.rng.normal()).collect();
            for fmt in [
                ElementFormat::int(2),
                ElementFormat::int(4),
                ElementFormat::int(8),
                ElementFormat::fp_from_bits(4),
                ElementFormat::fp_from_bits(8),
            ] {
                let t =
                    MxTensor::quantize(&data, &[in_f, out_f], MxFormat::new(fmt, bs)).unwrap();
                let r = RepackedMx::from_mx(&t);
                if r.to_row_major_codes() != t.unpack_codes() {
                    return Err(format!("{fmt}: codes differ ({in_f}x{out_f}@{bs})"));
                }
                if r.to_row_major_scales() != t.scales {
                    return Err(format!("{fmt}: scales differ ({in_f}x{out_f}@{bs})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tile_decode_matches_dequantize_layout() {
        // Decoding a (jb, k0, kl) tile must yield exactly the codes of
        // columns [jb*bs, jb*bs+bs) of rows [k0, k0+kl).
        let (in_f, out_f, bs) = (48usize, 40usize, 32usize);
        let data: Vec<f32> = (0..in_f * out_f).map(|i| ((i * 37) % 19) as f32 - 9.0).collect();
        let t = MxTensor::quantize(&data, &[in_f, out_f], MxFormat::mxint(4, bs)).unwrap();
        let flat = t.unpack_codes();
        let r = RepackedMx::from_mx(&t);
        let mut tile = vec![0i8; 16 * bs];
        r.decode_tile_signed(1, 8, 16, &mut tile);
        let nl = out_f - bs; // ragged tail block: 8 columns
        for k in 0..16 {
            let want = &flat[(8 + k) * out_f + bs..][..nl];
            assert_eq!(&tile[k * bs..][..nl], want, "k={k}");
            assert!(tile[k * bs + nl..(k + 1) * bs].iter().all(|&c| c == 0), "pad");
        }
    }

    #[test]
    fn storage_is_close_to_source_tensor() {
        // Padding waste is bounded by one block per (jb, k) row.
        let t = MxTensor::quantize(
            &vec![0.1f32; 128 * 96],
            &[128, 96],
            MxFormat::mxint(4, 32),
        )
        .unwrap();
        let r = RepackedMx::from_mx(&t);
        assert_eq!(r.storage_bytes(), t.storage_bytes());
    }
}
