//! Elastic server integration over the native backend: batching,
//! policy-driven format selection, pinned formats (including mixed pins in
//! one gather window), metrics/cache counters, and graceful shutdown.
//!
//! Runs everywhere — the native backend needs no AOT artifacts and no XLA.

use mfqat::coordinator::ElasticEngine;
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::server::{Policy, Server, ServerConfig};
use std::time::Duration;

/// Small dims so the whole suite stays fast on one core.
fn test_dims() -> ModelDims {
    let mut dims = ModelDims::new("srv", 64, 32, 2, 2, 16);
    dims.train_batch = 4;
    dims
}

fn test_corpus(width: usize, seed: u64, vocab: usize) -> Vec<Vec<i32>> {
    // Deterministic token rows within the test vocab.
    (0..64u64)
        .map(|r| {
            (0..width)
                .map(|i| (((r * 31 + seed * 7 + i as u64 * 13) % vocab as u64) as i32))
                .collect()
        })
        .collect()
}

fn start_server(policy: Policy, seed: u64) -> (Server, mfqat::server::Client, usize) {
    let dims = test_dims();
    let width = dims.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, seed);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 64 << 20)
        },
        ServerConfig {
            policy,
            gather_window: Duration::from_millis(1),
        },
    )
    .unwrap();
    (server, client, width)
}

#[test]
fn requests_are_scored_and_batched() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 11);
    let rows = test_corpus(width, 9, 64);

    // Fire a burst; all must come back finite with the fixed format.
    let rxs: Vec<_> = (0..16)
        .map(|i| client.submit(&rows[i % rows.len()], None).unwrap())
        .collect();
    let mut max_batch = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.nll.is_finite() && resp.nll > 0.0);
        assert_eq!(resp.format, ElementFormat::int(8));
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "burst must be batched (got {max_batch})");
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.requests, 16);
    assert!(m.cache.misses >= 1, "int8 derivation is a cache miss");
    assert_eq!(m.cache.entries, 1, "one format resident after a fixed-format run");
    drop(client);
    server.shutdown();
}

#[test]
fn pinned_format_wins_over_policy() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 12);
    let rows = test_corpus(width, 10, 64);
    let resp = client
        .score(&rows[0], Some(ElementFormat::int(3)))
        .unwrap();
    assert_eq!(resp.format, ElementFormat::int(3), "pin honoured");
    drop(client);
    server.shutdown();
}

#[test]
fn mixed_pins_in_one_window_each_get_their_format() {
    // Regression for the mixed-pin batching bug: when requests pinned to
    // *different* formats land in the same gather window, each must be
    // served at its own pin (the old code let the first pin win for all).
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 13);
    let rows = test_corpus(width, 11, 64);
    let pins = [
        Some(ElementFormat::int(4)),
        Some(ElementFormat::int(6)),
        Some(ElementFormat::int(4)),
        None, // policy pick
        Some(ElementFormat::int(2)),
        Some(ElementFormat::int(6)),
    ];
    // Submit the whole burst back-to-back so several pins share a window.
    let rxs: Vec<_> = pins
        .iter()
        .enumerate()
        .map(|(i, pin)| client.submit(&rows[i % rows.len()], *pin).unwrap())
        .collect();
    for (rx, pin) in rxs.into_iter().zip(pins) {
        let resp = rx.recv().unwrap().unwrap();
        let want = pin.unwrap_or(ElementFormat::int(8));
        assert_eq!(resp.format, want, "response served at the wrong precision");
        assert!(resp.nll.is_finite());
    }
    drop(client);
    server.shutdown();
}

#[test]
fn ladder_policy_degrades_under_load() {
    // Aggressive ladder so a modest burst crosses thresholds.
    let ladder = Policy::Ladder(vec![
        (2, ElementFormat::int(8)),
        (10, ElementFormat::int(6)),
        (usize::MAX, ElementFormat::int(4)),
    ]);
    let (server, client, width) = start_server(ladder, 14);
    let rows = test_corpus(width, 12, 64);

    // Single request under no load → highest precision.
    let solo = client.score(&rows[0], None).unwrap();
    assert_eq!(solo.format, ElementFormat::int(8));

    // Big burst → later batches must see depth > 10 and degrade.
    let rxs: Vec<_> = (0..48)
        .map(|i| client.submit(&rows[i % rows.len()], None).unwrap())
        .collect();
    let mut formats = std::collections::BTreeSet::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        formats.insert(resp.format.bits());
    }
    assert!(
        formats.iter().any(|&b| b < 8),
        "burst must trigger lower precisions, saw {formats:?}"
    );
    let metrics = server.metrics.lock().unwrap().clone();
    assert!(metrics.conversions() >= formats.len() as u64);
    let s = metrics.summary();
    assert!(s.contains("cache["), "summary surfaces cache counters: {s}");
    drop(client);
    server.shutdown();
}

#[test]
fn shutdown_rejects_new_requests() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 15);
    let tokens = vec![33i32; width];
    client.score(&tokens, None).unwrap();
    server.shutdown();
    assert!(client.score(&tokens, None).is_err(), "post-shutdown submit fails");
}
