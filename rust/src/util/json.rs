//! Minimal JSON value, parser and writer.
//!
//! Used for the AOT `manifest.json`, golden-vector files, experiment result
//! metadata and the server wire protocol. Supports the full JSON grammar
//! except for exotic number forms beyond f64; numbers are stored as `f64`
//! (all values we exchange — dims, seeds, floats — fit losslessly or are
//! floats anyway).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------- constructors
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Object from key/value pairs.
    pub fn from_pairs<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    // --------------------------------------------------------------- setters
    /// Insert into an object (panics if not an object — construction-time use).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // --------------------------------------------------------------- getters
    /// Object field by key (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as `usize`, if a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Numeric value as `i64`, if a number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// String value, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `get` chained with a typed accessor, with a descriptive error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Required string field (error when missing or mistyped).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a string"))
    }

    /// Required `usize` field.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a number"))
    }

    /// Required `f64` field.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a number"))
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not an array"))
    }

    /// Array of numbers → `Vec<f32>`.
    pub fn f32_vec(&self) -> anyhow::Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|v| v as f32)
                    .ok_or_else(|| anyhow::anyhow!("non-numeric array element"))
            })
            .collect()
    }

    /// Array of numbers → `Vec<usize>`.
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|x| {
                x.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("non-numeric array element"))
            })
            .collect()
    }

    // --------------------------------------------------------------- parsing
    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Parse a file.
    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else if x.is_finite() {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                } else {
                    // JSON has no inf/nan; encode as null (callers avoid these).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(items: Vec<T>) -> Json {
        Json::Arr(items.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not produced by our writers;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null, "x\ny"], "c": {"d": "e"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(-0.25).to_string(), "-0.25");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 4, "s": "hi", "a": [1,2,3]}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert_eq!(v.req("a").unwrap().usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(v.req("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let esc = Json::parse(r#""☃""#).unwrap();
        assert_eq!(esc.as_str().unwrap(), "☃");
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::from(vec![1usize, 2, 3]))
            .set("name", Json::from("mfqat"));
        let p = o.pretty();
        assert_eq!(Json::parse(&p).unwrap(), o);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
