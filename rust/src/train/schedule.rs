//! Format schedules — the paper's training recipes (§3.2, §3.5).
//!
//! * Single-format QAT: one format for all epochs.
//! * Multi-format QAT: one epoch per format in **increasing bit order**
//!   (2→4→6→8): "lower-precision weights typically require larger updates to
//!   jump out of the quantization bin; training in the opposite direction
//!   can destabilize the higher-precision settings learned earlier".
//! * Anchor-SS multi-format QAT (§3.5): targets are reached through the
//!   8-bit anchor (`W_t = Q_{A→t}(Q_A(W))`); the anchor-format epoch itself
//!   is plain QAT at the anchor (fake-quant is idempotent there).

use anyhow::{bail, Result};

/// One schedule phase: a train-step variant run for `epochs` epochs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Phase {
    /// Train-step variant this phase runs.
    pub variant: String,
    /// Epochs to run the variant for.
    pub epochs: usize,
}

/// A named training plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrainPlan {
    /// Plan name.
    pub name: String,
    /// Ordered phases.
    pub phases: Vec<Phase>,
}

impl TrainPlan {
    fn of(name: &str, phases: Vec<(&str, usize)>) -> TrainPlan {
        TrainPlan {
            name: name.to_string(),
            phases: phases
                .into_iter()
                .map(|(v, epochs)| Phase {
                    variant: v.to_string(),
                    epochs,
                })
                .collect(),
        }
    }

    /// Total epochs across all phases.
    pub fn total_epochs(&self) -> usize {
        self.phases.iter().map(|p| p.epochs).sum()
    }

    /// Full-precision finetune baseline, `epochs` epochs.
    pub fn ft_fp(epochs: usize) -> TrainPlan {
        TrainPlan::of("ft_fp", vec![("ft_fp", epochs)])
    }

    /// Single-format QAT at `fmt` (e.g. "int4"), `epochs` epochs.
    pub fn single(fmt: &str, epochs: usize) -> TrainPlan {
        TrainPlan::of(
            &format!("qat_{fmt}"),
            vec![(Box::leak(format!("qat_{fmt}").into_boxed_str()), epochs)],
        )
    }

    /// Multi-format MXINT QAT: 2→4→6→8, one epoch each (4 total).
    pub fn multi_int() -> TrainPlan {
        TrainPlan::of(
            "mf_int",
            vec![("qat_int2", 1), ("qat_int4", 1), ("qat_int6", 1), ("qat_int8", 1)],
        )
    }

    /// Multi-format MXFP QAT: 4→6→8, one epoch each (3 total).
    pub fn multi_fp() -> TrainPlan {
        TrainPlan::of("mf_fp", vec![("qat_fp4", 1), ("qat_fp6", 1), ("qat_fp8", 1)])
    }

    /// ABLATION: multi-format MXINT in **decreasing** bit order (8→6→4→2).
    /// The paper (§3.2) claims this direction "can destabilize the
    /// higher-precision quantization settings learned earlier"; experiment
    /// `abl_order` tests it.
    pub fn multi_int_desc() -> TrainPlan {
        TrainPlan::of(
            "mf_int_desc",
            vec![("qat_int8", 1), ("qat_int6", 1), ("qat_int4", 1), ("qat_int2", 1)],
        )
    }

    /// ABLATION: decreasing-bit MXFP (8→6→4).
    pub fn multi_fp_desc() -> TrainPlan {
        TrainPlan::of(
            "mf_fp_desc",
            vec![("qat_fp8", 1), ("qat_fp6", 1), ("qat_fp4", 1)],
        )
    }

    /// Anchor-SS multi-format MXINT QAT (§3.5), anchor = MXINT8.
    pub fn multi_ss_int() -> TrainPlan {
        TrainPlan::of(
            "mf_ss_int",
            vec![
                ("qat_ss_int2", 1),
                ("qat_ss_int4", 1),
                ("qat_ss_int6", 1),
                ("qat_int8", 1), // anchor epoch: Q_A∘Q_A = Q_A
            ],
        )
    }

    /// Anchor-SS multi-format MXFP QAT (§3.5), anchor = MXFP8.
    pub fn multi_ss_fp() -> TrainPlan {
        TrainPlan::of(
            "mf_ss_fp",
            vec![("qat_ss_fp4", 1), ("qat_ss_fp6", 1), ("qat_fp8", 1)],
        )
    }

    /// Look up a plan by name. Single-format plans take the total epoch
    /// budget of the matching multi-format plan for fair comparison
    /// (paper: "the same number of epochs as the multi-format QAT runs").
    pub fn by_name(name: &str) -> Result<TrainPlan> {
        Ok(match name {
            "ft_fp_int" => TrainPlan::ft_fp(4),
            "ft_fp_fp" | "ft_fp" => TrainPlan::ft_fp(3),
            "mf_int" => TrainPlan::multi_int(),
            "mf_fp" => TrainPlan::multi_fp(),
            "mf_int_desc" => TrainPlan::multi_int_desc(),
            "mf_fp_desc" => TrainPlan::multi_fp_desc(),
            "mf_ss_int" => TrainPlan::multi_ss_int(),
            "mf_ss_fp" => TrainPlan::multi_ss_fp(),
            _ if name.starts_with("qat_int") => TrainPlan::single(&name[4..], 4),
            _ if name.starts_with("qat_fp") => TrainPlan::single(&name[4..], 3),
            _ => bail!("unknown train plan '{name}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_int_is_increasing_bit_order() {
        let p = TrainPlan::multi_int();
        let bits: Vec<u32> = p
            .phases
            .iter()
            .map(|ph| ph.variant.trim_start_matches("qat_int").parse().unwrap())
            .collect();
        assert_eq!(bits, vec![2, 4, 6, 8]);
        assert_eq!(p.total_epochs(), 4);
    }

    #[test]
    fn fair_epoch_budgets() {
        // Single-format gets the same total epochs as multi-format.
        assert_eq!(
            TrainPlan::by_name("qat_int4").unwrap().total_epochs(),
            TrainPlan::multi_int().total_epochs()
        );
        assert_eq!(
            TrainPlan::by_name("qat_fp6").unwrap().total_epochs(),
            TrainPlan::multi_fp().total_epochs()
        );
        assert_eq!(TrainPlan::by_name("ft_fp_int").unwrap().total_epochs(), 4);
    }

    #[test]
    fn ss_plans_route_through_anchor() {
        let p = TrainPlan::multi_ss_int();
        assert!(p.phases[0].variant.starts_with("qat_ss_"));
        // The anchor epoch uses the plain anchor-format step.
        assert_eq!(p.phases.last().unwrap().variant, "qat_int8");
    }

    #[test]
    fn unknown_plan_errors() {
        assert!(TrainPlan::by_name("nope").is_err());
    }
}
