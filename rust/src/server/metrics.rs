//! Serving metrics: request counts per format and lane (scoring vs
//! generation), latency distributions, batch-size and execution-time
//! statistics, generated-token throughput, and weight-cache counters.
//! One instance aggregates the whole worker pool (shared behind a mutex;
//! each worker takes the lock once per executed sub-batch).

use crate::backend::KvMemory;
use crate::coordinator::CacheStats;
use crate::formats::ElementFormat;
use crate::util::stats::{LatencyHist, Running};
use std::collections::BTreeMap;

/// Aggregated server metrics (guarded by a mutex in the server; workers
/// take that lock once per executed batch).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Requests served (both lanes).
    pub requests: u64,
    per_format: BTreeMap<String, u64>,
    /// End-to-end request latency distribution.
    pub latency: LatencyHist,
    /// Executed batch-size statistics.
    pub batch_size: Running,
    /// Batch execution-time statistics (scoring lane).
    pub exec_time: Running,
    /// Generation-lane request count (also counted in `requests`).
    pub gen_requests: u64,
    /// Generation-lane end-to-end latency distribution.
    pub gen_latency: LatencyHist,
    /// Tokens emitted by the generation lane.
    pub gen_tokens: u64,
    /// Wall-clock seconds spent inside batched decodes (per request row —
    /// `gen_tokens / gen_exec_time` understates shared-batch throughput;
    /// divide by the mean batch size for per-pass numbers).
    pub gen_exec_time: Running,
    /// Worker threads serving this instance (set at server start).
    pub workers: usize,
    /// Weight-cache counter snapshot (hits/misses/evictions/bytes).
    pub cache: CacheStats,
    /// Latest paged-KV accounting snapshot from a worker's decode session
    /// (updated once per decode step; per-session numbers — the
    /// resident-over-dense ratio is the pool-independent signal).
    pub kv: KvMemory,
    /// Highest resident paged-KV bytes observed — sourced from the cache's
    /// own allocation-time high-water mark
    /// ([`KvMemory::resident_peak_bytes`], which registers rows that map
    /// and retire within a single step) plus every snapshot's current
    /// residency. The number to hold against
    /// [`KvMemory::dense_equivalent_bytes`] (dense would sit at that
    /// ceiling the whole time).
    pub kv_resident_peak_bytes: usize,
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Metrics {
        Metrics {
            latency: LatencyHist::new(),
            gen_latency: LatencyHist::new(),
            ..Default::default()
        }
    }

    /// Record one scoring request served in a batch of `batch` at `fmt`.
    pub fn record(&mut self, fmt: ElementFormat, latency_s: f64, batch: usize, exec_s: f64) {
        self.requests += 1;
        *self.per_format.entry(fmt.name()).or_insert(0) += 1;
        self.latency.record(latency_s);
        self.batch_size.push(batch as f64);
        self.exec_time.push(exec_s);
    }

    /// Record one generation-lane request served in a batch of `batch`
    /// prompts that emitted `tokens` tokens for this request. The request
    /// feeds the headline `requests`/`latency`/`batch_size` aggregates
    /// (so the summary line describes one population) *and* the gen-lane
    /// counters for lane-specific views.
    pub fn record_generate(
        &mut self,
        fmt: ElementFormat,
        latency_s: f64,
        batch: usize,
        exec_s: f64,
        tokens: u64,
    ) {
        self.requests += 1;
        self.gen_requests += 1;
        *self.per_format.entry(fmt.name()).or_insert(0) += 1;
        self.latency.record(latency_s);
        self.gen_latency.record(latency_s);
        self.batch_size.push(batch as f64);
        self.gen_exec_time.push(exec_s);
        self.gen_tokens += tokens;
    }

    /// Refresh the weight-cache counter snapshot (once per batch).
    pub fn set_cache(&mut self, stats: CacheStats) {
        self.cache = stats;
    }

    /// Refresh the paged-KV snapshot (once per decode step) and track the
    /// resident peak.
    pub fn set_kv(&mut self, kv: KvMemory) {
        self.kv_resident_peak_bytes = self
            .kv_resident_peak_bytes
            .max(kv.resident_bytes)
            .max(kv.resident_peak_bytes);
        self.kv = kv;
    }

    /// Bytes of KV currently resident (mapped pages) in the last-reported
    /// decode session — `0` until a continuous worker reports.
    pub fn kv_resident_bytes(&self) -> usize {
        self.kv.resident_bytes
    }

    /// Fraction of the last-reported session's KV page pool in use.
    pub fn kv_pool_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    /// Anchor→target weight derivations performed (= format-cache misses).
    pub fn conversions(&self) -> u64 {
        self.cache.misses
    }

    /// Requests served per format name.
    pub fn format_counts(&self) -> &BTreeMap<String, u64> {
        &self.per_format
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mix: Vec<String> = self
            .per_format
            .iter()
            .map(|(f, n)| format!("{f}:{n}"))
            .collect();
        let gen = if self.gen_requests > 0 {
            format!(
                " gen[{} reqs {} tok {}]",
                self.gen_requests,
                self.gen_tokens,
                self.gen_latency.summary()
            )
        } else {
            String::new()
        };
        let kv = if self.kv.total_pages > 0 {
            format!(
                " kv[resident:{}KB peak:{}KB dense:{}KB util:{:.0}% page:{}]",
                self.kv_resident_bytes() / 1024,
                self.kv_resident_peak_bytes / 1024,
                self.kv.dense_equivalent_bytes / 1024,
                self.kv_pool_utilization() * 100.0,
                self.kv.page_positions,
            )
        } else {
            String::new()
        };
        format!(
            "workers={} requests={} latency[{}] mean_batch={:.2}{} mix=[{}] cache[hit:{} miss:{} evict:{} {}KB]{}",
            self.workers.max(1),
            self.requests,
            self.latency.summary(),
            self.batch_size.mean(),
            gen,
            mix.join(" "),
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.used_bytes / 1024,
            kv,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = Metrics::new();
        m.record(ElementFormat::int(8), 0.010, 4, 0.008);
        m.record(ElementFormat::int(8), 0.020, 8, 0.015);
        m.record(ElementFormat::int(4), 0.005, 8, 0.004);
        assert_eq!(m.requests, 3);
        assert_eq!(m.format_counts()["int8"], 2);
        assert_eq!(m.format_counts()["int4"], 1);
        assert!((m.batch_size.mean() - 20.0 / 3.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("int8:2"));
    }

    #[test]
    fn generation_lane_is_tracked() {
        let mut m = Metrics::new();
        m.record(ElementFormat::int(8), 0.010, 4, 0.008);
        m.record_generate(ElementFormat::int(4), 0.200, 2, 0.180, 32);
        m.record_generate(ElementFormat::int(4), 0.210, 2, 0.180, 32);
        assert_eq!(m.requests, 3, "gen requests count toward the total");
        assert_eq!(m.gen_requests, 2);
        assert_eq!(m.gen_tokens, 64);
        assert_eq!(m.format_counts()["int4"], 2);
        let s = m.summary();
        assert!(s.contains("gen[2 reqs 64 tok"), "{s}");
        // Scoring-only metrics skip the gen section.
        let mut m2 = Metrics::new();
        m2.workers = 4;
        m2.record(ElementFormat::int(8), 0.010, 4, 0.008);
        let s2 = m2.summary();
        assert!(!s2.contains("gen["), "{s2}");
        assert!(s2.contains("workers=4"), "{s2}");
    }

    #[test]
    fn kv_residency_flows_into_summary() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("kv["), "no kv section before a report");
        m.set_kv(KvMemory {
            resident_bytes: 8192,
            resident_peak_bytes: 8192,
            dense_equivalent_bytes: 32768,
            pool_bytes: 16384,
            used_pages: 4,
            free_pages: 4,
            total_pages: 8,
            page_positions: 16,
        });
        assert_eq!(m.kv_resident_bytes(), 8192);
        assert!((m.kv_pool_utilization() - 0.5).abs() < 1e-12);
        // Peak survives a lower follow-up snapshot, and honours the cache's
        // own allocation-time high-water mark (rows that mapped and retired
        // within one step).
        m.set_kv(KvMemory {
            resident_bytes: 2048,
            resident_peak_bytes: 10240,
            used_pages: 1,
            free_pages: 7,
            total_pages: 8,
            page_positions: 16,
            dense_equivalent_bytes: 32768,
            pool_bytes: 16384,
        });
        assert_eq!(m.kv_resident_peak_bytes, 10240);
        let s = m.summary();
        assert!(s.contains("kv[resident:2KB"), "{s}");
        assert!(s.contains("peak:10KB"), "{s}");
        assert!(s.contains("dense:32KB"), "{s}");
    }

    #[test]
    fn cache_counters_flow_into_summary() {
        let mut m = Metrics::new();
        m.set_cache(CacheStats {
            hits: 7,
            misses: 3,
            evictions: 2,
            entries: 1,
            used_bytes: 4096,
        });
        assert_eq!(m.conversions(), 3);
        let s = m.summary();
        assert!(s.contains("hit:7"), "{s}");
        assert!(s.contains("miss:3"), "{s}");
        assert!(s.contains("evict:2"), "{s}");
    }
}
