//! Quickstart: the elastic-inference workflow in ~60 lines.
//!
//! 1. Build a model, store it as ONE MXINT8 anchor checkpoint.
//! 2. Derive MXINT{6,4,3,2} *packed* serving weights at runtime via
//!    Slice-and-Scale — no FP32 weights, no retraining — and score a batch
//!    at each precision through the native packed-MX backend.
//!
//! No AOT artifacts and no XLA install required: the native backend
//! computes directly on packed element codes with fused block scales.
//!
//! Run: `cargo run --release --example quickstart`

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};

fn main() -> anyhow::Result<()> {
    mfqat::util::logging::init();
    let dims = ModelDims::by_name("tiny").unwrap();
    let m = dims.to_manifest();
    println!(
        "model '{}': {} params, seq {}, MX block {}",
        m.config_name, m.n_params, m.seq_len, m.block_size
    );

    // A model to serve. (Use `mfqat train --plan mf_int` for a QAT-trained
    // one; random init keeps the quickstart self-contained.)
    let params = ParamSet::init(&m, 42);

    // ONE anchor checkpoint instead of one model per precision.
    let ck = params.to_anchor_checkpoint(&m, ElementFormat::int(8))?;
    let fp32_mb = params.n_params() as f64 * 4.0 / 1e6;
    let anchor_mb = ck.storage_bytes() as f64 / 1e6;
    println!("anchor checkpoint: {anchor_mb:.2} MB (fp32 would be {fp32_mb:.2} MB)");

    let engine = ElasticEngine::native(dims.clone(), ck, 128 << 20)?;

    // A batch of real corpus text to score.
    let corpus = Corpus::generate(CorpusConfig {
        width: dims.seq_len + 1,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 8,
        ..Default::default()
    });
    let mut batch = Vec::new();
    for r in 0..dims.train_batch {
        batch.extend_from_slice(&corpus.val[r]);
    }

    // Elastic precision selection: same checkpoint, any format, on demand.
    println!("\n{:<12} {:>10} {:>14}", "format", "mean NLL", "derive+score");
    for bits in [8u8, 6, 4, 3, 2] {
        let fmt = ElementFormat::int(bits);
        let t = std::time::Instant::now();
        let nll = engine.score_batch(&batch, fmt)?;
        let mean: f32 = nll.iter().sum::<f32>() / nll.len() as f32;
        println!(
            "{:<12} {:>10.4} {:>11.1} ms",
            fmt.long_name(),
            mean,
            t.elapsed().as_secs_f64() * 1e3
        );
    }
    println!(
        "\nconversions performed: {} (then cached: {} packed formats resident, {} KB)",
        engine.conversions(),
        engine.cached_formats(),
        engine.cache_stats().used_bytes / 1024
    );
    Ok(())
}
