//! Quickstart: the elastic-inference workflow in ~60 lines.
//!
//! 1. Load the AOT artifacts (built once by `make artifacts`).
//! 2. Build a model, store it as ONE MXINT8 anchor checkpoint.
//! 3. Derive MXINT{6,4,3,2} serving weights at runtime via Slice-and-Scale —
//!    no FP32 weights, no retraining — and score a batch at each precision.
//!
//! Run: `cargo run --release --example quickstart`

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    mfqat::util::logging::init();
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let rt = Runtime::cpu()?;
    let arts = ArtifactSet::open(&root.join("artifacts/tiny"))?;
    let m = arts.manifest.clone();
    println!(
        "model '{}': {} params, seq {}, MX block {}",
        m.config_name, m.n_params, m.seq_len, m.block_size
    );

    // A model to serve. (Use `mfqat train --plan mf_int` for a QAT-trained
    // one; random init keeps the quickstart self-contained.)
    let params = ParamSet::init(&m, 42);

    // ONE anchor checkpoint instead of one model per precision.
    let ck = params.to_anchor_checkpoint(&m, ElementFormat::int(8))?;
    let fp32_mb = params.n_params() as f64 * 4.0 / 1e6;
    let anchor_mb = ck.storage_bytes() as f64 / 1e6;
    println!("anchor checkpoint: {anchor_mb:.2} MB (fp32 would be {fp32_mb:.2} MB)");

    let engine = ElasticEngine::from_parts(rt, arts, ck, ElementFormat::int(8), 128 << 20);

    // A batch of real corpus text to score.
    let corpus = Corpus::generate(CorpusConfig {
        width: m.seq_len + 1,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 8,
        ..Default::default()
    });
    let mut batch = Vec::new();
    for r in 0..m.train_batch {
        batch.extend_from_slice(&corpus.val[r]);
    }

    // Elastic precision selection: same checkpoint, any format, on demand.
    println!("\n{:<12} {:>10} {:>14}", "format", "mean NLL", "derive+score");
    for bits in [8u8, 6, 4, 3, 2] {
        let fmt = ElementFormat::int(bits);
        let t = std::time::Instant::now();
        let nll = engine.score_b8(&batch, fmt)?;
        let mean: f32 = nll.iter().sum::<f32>() / nll.len() as f32;
        println!(
            "{:<12} {:>10.4} {:>11.1} ms",
            fmt.long_name(),
            mean,
            t.elapsed().as_secs_f64() * 1e3
        );
    }
    println!(
        "\nconversions performed: {} (then cached: {} formats resident)",
        engine.conversions(),
        engine.cached_formats()
    );
    Ok(())
}
