//! Chrome-trace-event recording and export.
//!
//! [`TraceSink`] collects request-lifecycle events from the serving stack
//! and renders them as Chrome trace-event JSON (the `traceEvents` object
//! form), loadable in Perfetto / `chrome://tracing`. The serving runtime
//! maps **`pid` = worker index** and **`tid` = decode-session row slot**,
//! so the viewer shows one track per worker with one lane per row: a
//! `queue_wait` span (enqueue → admit), then `prefill`/`decode`/
//! `reprefill` spans per step, a whole-request `request` span and a
//! `complete` instant at retire. Instant events also mark admission
//! deferrals and policy downshifts.
//!
//! The sink is only constructed when tracing is requested
//! (`serve --trace-out` / [`crate::server::ServerConfig::trace`]); with it
//! absent the hot path pays a single `Option` check. Event storage is an
//! append-only vector behind a mutex with a hard cap — beyond the cap,
//! events are counted as dropped rather than growing without bound.

use crate::util::json::Json;
use crate::util::sync::RobustMutex;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One trace event (Chrome trace-event format).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Event name (`queue_wait`, `prefill`, `decode`, ...).
    pub name: &'static str,
    /// Phase: `'X'` (complete, with duration) or `'i'` (instant).
    pub ph: char,
    /// Start timestamp, microseconds since the sink was created.
    pub ts_us: u64,
    /// Duration in microseconds (`'X'` events only).
    pub dur_us: u64,
    /// Track: worker index.
    pub pid: u64,
    /// Lane within the track: decode-session row slot.
    pub tid: u64,
    /// Extra key/value payload (`format`, `token`, ...).
    pub args: Vec<(&'static str, Json)>,
}

/// Collects trace events; renders Chrome trace-event JSON.
#[derive(Debug)]
pub struct TraceSink {
    start: Instant,
    events: RobustMutex<Vec<TraceEvent>>,
    dropped: AtomicU64,
    cap: usize,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    /// Empty sink; timestamps are relative to this call.
    pub fn new() -> TraceSink {
        TraceSink {
            start: Instant::now(),
            events: RobustMutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            cap: 1 << 20,
        }
    }

    /// Microseconds from sink creation to `t` (0 for instants before it).
    pub fn ts_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.start).as_micros() as u64
    }

    /// Microseconds from sink creation to now.
    pub fn now_us(&self) -> u64 {
        self.ts_us(Instant::now())
    }

    fn push(&self, ev: TraceEvent) {
        let mut events = self.events.lock();
        if events.len() >= self.cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        events.push(ev);
    }

    /// Record a complete (`'X'`) span.
    pub fn complete(
        &self,
        name: &'static str,
        pid: u64,
        tid: u64,
        ts_us: u64,
        dur_us: u64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.push(TraceEvent {
            name,
            ph: 'X',
            ts_us,
            dur_us,
            pid,
            tid,
            args,
        });
    }

    /// Record an instant (`'i'`) event at the current time.
    pub fn instant(&self, name: &'static str, pid: u64, tid: u64, args: Vec<(&'static str, Json)>) {
        self.push(TraceEvent {
            name,
            ph: 'i',
            ts_us: self.now_us(),
            dur_us: 0,
            pid,
            tid,
            args,
        });
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.events.lock().len()
    }

    /// Whether no events have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events rejected by the storage cap.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Render the Chrome trace-event JSON object (`{"traceEvents": [...]}`).
    ///
    /// Events are sorted by timestamp, and `'M'` metadata events name each
    /// worker track (`worker-N`) and row lane (`row-N`) for the viewer.
    pub fn to_json(&self) -> Json {
        let mut events = self.events.lock().clone();
        events.sort_by_key(|e| (e.ts_us, e.pid, e.tid));
        let mut arr: Vec<Json> = Vec::with_capacity(events.len() + 8);
        // Track-naming metadata first.
        let mut tracks: Vec<(u64, u64)> = events.iter().map(|e| (e.pid, e.tid)).collect();
        tracks.sort_unstable();
        tracks.dedup();
        let mut pids: Vec<u64> = tracks.iter().map(|(p, _)| *p).collect();
        pids.dedup();
        for pid in pids {
            let mut m = Json::obj();
            m.set("name", Json::from("process_name"));
            m.set("ph", Json::from("M"));
            m.set("pid", Json::from(pid));
            m.set("tid", Json::from(0u64));
            let mut args = Json::obj();
            args.set("name", Json::from(format!("worker-{pid}")));
            m.set("args", args);
            arr.push(m);
        }
        for (pid, tid) in tracks {
            let mut m = Json::obj();
            m.set("name", Json::from("thread_name"));
            m.set("ph", Json::from("M"));
            m.set("pid", Json::from(pid));
            m.set("tid", Json::from(tid));
            let mut args = Json::obj();
            args.set("name", Json::from(format!("row-{tid}")));
            m.set("args", args);
            arr.push(m);
        }
        for e in events {
            let mut o = Json::obj();
            o.set("name", Json::from(e.name));
            o.set("cat", Json::from("serve"));
            o.set("ph", Json::from(e.ph.to_string()));
            o.set("ts", Json::from(e.ts_us));
            if e.ph == 'X' {
                o.set("dur", Json::from(e.dur_us));
            }
            if e.ph == 'i' {
                o.set("s", Json::from("t")); // thread-scoped instant
            }
            o.set("pid", Json::from(e.pid));
            o.set("tid", Json::from(e.tid));
            if !e.args.is_empty() {
                let mut args = Json::obj();
                for (k, v) in e.args {
                    args.set(k, v);
                }
                o.set("args", args);
            }
            arr.push(o);
        }
        let mut out = Json::obj();
        out.set("traceEvents", Json::Arr(arr));
        out.set("displayTimeUnit", Json::from("ms"));
        if self.dropped() > 0 {
            out.set("droppedEvents", Json::from(self.dropped()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_render_sorted_with_metadata() {
        let sink = TraceSink::new();
        sink.complete("decode", 0, 1, 50, 10, vec![("token", Json::from(2u64))]);
        sink.complete("prefill", 0, 1, 10, 30, Vec::new());
        sink.instant("complete", 1, 0, Vec::new());
        assert_eq!(sink.len(), 3);
        let json = sink.to_json();
        let events = json.get("traceEvents").and_then(|j| j.as_arr()).unwrap();
        // 2 process_name + 3 thread_name metadata events precede the data.
        let data: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) != Some("M"))
            .collect();
        assert_eq!(data.len(), 3);
        let ts: Vec<f64> = data
            .iter()
            .map(|e| e.get("ts").and_then(|t| t.as_f64()).unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "sorted by ts: {ts:?}");
        assert_eq!(data[0].get("name").and_then(|n| n.as_str()), Some("prefill"));
        assert_eq!(data[0].get("dur").and_then(|d| d.as_f64()), Some(30.0));
        // Instants carry a scope and no duration.
        let inst = data
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
            .unwrap();
        assert_eq!(inst.get("s").and_then(|s| s.as_str()), Some("t"));
        assert!(inst.get("dur").is_none());
    }

    #[test]
    fn round_trips_through_parser() {
        let sink = TraceSink::new();
        sink.instant("defer", 0, 0, vec![("reason", Json::from("kv_pages"))]);
        let text = sink.to_json().pretty();
        let parsed = Json::parse(&text).expect("valid JSON");
        assert!(parsed.get("traceEvents").and_then(|j| j.as_arr()).is_some());
    }
}
