//! Observability subsystem behaviour: the lock-free metrics registry under
//! multithreaded hammering (checked against a mutex-protected oracle), and
//! end-to-end request-lifecycle tracing through the continuous serving
//! lane — the exported Chrome trace must be valid trace-event JSON
//! (monotonic timestamps, complete `X` events carrying `dur`) and cover
//! the whole lifecycle: enqueue → admit → prefill → per-step decode →
//! complete. Exporter surfaces (JSON snapshot, Prometheus text) are
//! exercised on live serving data.
//!
//! Runs everywhere — the native backend needs no AOT artifacts and no XLA.

use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::SampleCfg;
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::obs::{AtomicRunning, Counter, Hist, Registry, TraceSink};
use mfqat::server::{GenBatching, Policy, Server, ServerConfig};
use mfqat::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ------------------------------------------------ registry hammer (oracle)

/// Mutex-protected reference accumulator the atomic registry must agree
/// with exactly. All samples are small integers, so the CAS f64
/// accumulation in `Hist`/`AtomicRunning` is exact regardless of thread
/// interleaving and the comparison can be `==`, not approximate.
#[derive(Default)]
struct Oracle {
    count: u64,
    sum: f64,
    hist_n: u64,
    hist_sum: f64,
    run_n: u64,
    run_sum: f64,
    run_min: f64,
    run_max: f64,
}

#[test]
fn hammer_atomic_registry_matches_mutexed_oracle() {
    const THREADS: usize = 8;
    const OPS: usize = 20_000;

    let reg = Arc::new(Registry::new());
    let oracle = Arc::new(Mutex::new(Oracle {
        run_min: f64::INFINITY,
        run_max: f64::NEG_INFINITY,
        ..Default::default()
    }));

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let reg = Arc::clone(&reg);
            let oracle = Arc::clone(&oracle);
            std::thread::spawn(move || {
                // Handles are cached once per thread, the hot-path pattern.
                let counter: Arc<Counter> = reg.counter("hammer_requests");
                let hist: Arc<Hist> = reg.hist("hammer_latency_seconds");
                let running: Arc<AtomicRunning> = reg.running("hammer_batch");
                let gauge = reg.gauge("hammer_peak");
                for i in 0..OPS {
                    let add = (i % 7 + 1) as u64;
                    let secs = (i % 5 + 1) as f64; // integer seconds: exact sums
                    let sample = ((t * 31 + i) % 11) as f64;
                    counter.add(add);
                    hist.record(secs);
                    running.push(sample);
                    gauge.set_max((t * OPS + i) as u64);
                    let mut o = oracle.lock().unwrap();
                    o.count += add;
                    o.sum += add as f64;
                    o.hist_n += 1;
                    o.hist_sum += secs;
                    o.run_n += 1;
                    o.run_sum += sample;
                    o.run_min = o.run_min.min(sample);
                    o.run_max = o.run_max.max(sample);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let o = oracle.lock().unwrap();
    let counter = reg.counter("hammer_requests");
    assert_eq!(counter.get(), o.count, "atomic counter lost updates");

    let hist = reg.hist("hammer_latency_seconds");
    assert_eq!(hist.count(), o.hist_n, "sharded histogram lost samples");
    assert_eq!(hist.sum(), o.hist_sum, "CAS f64 sum must be exact for integer samples");
    let buckets = hist.bucket_counts();
    assert_eq!(buckets.iter().sum::<u64>(), o.hist_n, "bucket counts must sum to the count");

    let running = reg.running("hammer_batch");
    assert_eq!(running.count(), o.run_n);
    assert_eq!(running.sum(), o.run_sum, "CAS f64 sum must be exact for integer samples");
    let snap = running.snapshot();
    assert_eq!(snap.min(), o.run_min);
    assert_eq!(snap.max(), o.run_max);

    let gauge = reg.gauge("hammer_peak");
    assert_eq!(gauge.get(), (THREADS * OPS - 1) as u64, "set_max must keep the global max");
}

#[test]
fn registry_returns_shared_handles_and_distinguishes_labels() {
    let reg = Registry::new();
    let a = reg.counter("shared");
    let b = reg.counter("shared");
    assert!(Arc::ptr_eq(&a, &b), "same name must return the same handle");
    let l1 = reg.counter_with("labelled", &[("format", "int8")]);
    let l2 = reg.counter_with("labelled", &[("format", "int4")]);
    l1.inc();
    assert_eq!(l2.get(), 0, "different label sets must be distinct metrics");
}

// --------------------------------------------------- end-to-end lifecycle

fn test_dims() -> ModelDims {
    let mut dims = ModelDims::new("obs", 256, 32, 2, 2, 16);
    dims.train_batch = 4;
    dims
}

fn start_traced_server() -> (Server, mfqat::server::Client) {
    let dims = test_dims();
    let width = dims.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, 23);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 64 << 20)
        },
        ServerConfig {
            policy: Policy::Fixed(ElementFormat::int(8)),
            gather_window: Duration::from_millis(1),
            workers: 1,
            batching: GenBatching::Continuous,
            trace: true,
            ..Default::default()
        },
    )
    .unwrap();
    (server, client)
}

/// Validate one exported Chrome trace document; returns the set of event
/// names seen (data events only, metadata excluded).
fn validate_trace(doc: &Json) -> Vec<String> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("trace document must carry a traceEvents array");
    assert!(!events.is_empty(), "trace must not be empty");
    let mut names = Vec::new();
    let mut last_ts = f64::NEG_INFINITY;
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("every event has a phase");
        let name = ev.get("name").and_then(|n| n.as_str()).expect("every event has a name");
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some(), "track ids on {name}");
        match ph {
            "M" => continue, // metadata: names tracks, carries no timestamp
            "X" => {
                let dur = ev.get("dur").and_then(|d| d.as_f64());
                assert!(dur.is_some(), "complete event '{name}' must carry dur");
                assert!(dur.unwrap() >= 0.0, "negative duration on '{name}'");
            }
            "i" => {
                assert_eq!(
                    ev.get("s").and_then(|s| s.as_str()),
                    Some("t"),
                    "instant '{name}' must be thread-scoped"
                );
            }
            other => panic!("unexpected phase '{other}' on '{name}'"),
        }
        let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("data events carry ts");
        assert!(ts >= last_ts, "timestamps must be monotonic ('{name}' went backwards)");
        last_ts = ts;
        names.push(name.to_string());
    }
    names
}

#[test]
fn traced_serving_emits_a_valid_request_lifecycle() {
    let (server, client) = start_traced_server();
    let cfg = SampleCfg {
        temperature: 0.7,
        top_k: 6,
        seed: 5,
    };
    // Mixed-format continuous run: pinned int4/int8 rows plus policy rows.
    let pins = [
        Some(ElementFormat::int(4)),
        Some(ElementFormat::int(8)),
        None,
        Some(ElementFormat::int(4)),
    ];
    let rxs: Vec<_> = pins
        .iter()
        .enumerate()
        .map(|(i, pin)| {
            client
                .submit_generate(&format!("prompt-{i}"), 6, *pin, cfg.clone())
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    // One scoring request so the score lane shows up in the trace too.
    client.score(&[1, 2, 3], None).unwrap();

    // Live snapshot through the client, before shutdown.
    let m = client.metrics_snapshot();
    assert_eq!(m.gen_requests, 4);
    assert_eq!(m.requests, 5, "headline counter covers both lanes (4 gen + 1 score)");
    for fmt in ["int4", "int8"] {
        let ttft = m.ttft.get(fmt).unwrap_or_else(|| panic!("missing TTFT hist for {fmt}"));
        assert!(ttft.count() >= 1, "TTFT must be recorded per format ({fmt})");
        let it = m
            .inter_token
            .get(fmt)
            .unwrap_or_else(|| panic!("missing inter-token hist for {fmt}"));
        assert!(it.count() >= 1, "inter-token gaps must be recorded per format ({fmt})");
    }
    assert!(m.queue_wait.count() >= 4, "every admitted row records queue wait");

    let obs = server.obs();
    let sink: Arc<TraceSink> = obs.trace().cloned().expect("trace sink present when trace: true");
    drop(client);
    server.shutdown();

    // The exported trace must round-trip through the JSON parser and pass
    // structural validation.
    let doc = Json::parse(&sink.to_json().pretty()).expect("trace must be parseable JSON");
    let names = validate_trace(&doc);
    for required in ["queue_wait", "admit", "prefill", "decode", "request", "complete"] {
        assert!(
            names.iter().any(|n| n == required),
            "lifecycle event '{required}' missing from trace (saw: {names:?})"
        );
    }
    assert!(names.iter().any(|n| n == "score_batch"), "score lane must be traced");
    // Decode steps outnumber prefills: each row prefills once then decodes.
    let prefills = names.iter().filter(|n| *n == "prefill").count();
    let decodes = names.iter().filter(|n| *n == "decode").count();
    assert!(prefills >= 4, "each admitted row prefills (saw {prefills})");
    assert!(decodes > prefills, "multi-token rows must emit decode steps");
    assert_eq!(sink.dropped(), 0, "small run must not hit the event cap");
}

#[test]
fn exporters_serve_live_data() {
    let (server, client) = start_traced_server();
    let cfg = SampleCfg::default();
    client.generate("kova", 4, Some(ElementFormat::int(8)), cfg).unwrap();

    let obs = server.obs();
    obs.sample(0);
    let json = obs.export_json();
    let parsed = Json::parse(&json.pretty()).expect("metrics JSON must round-trip");
    let summary = parsed.get("summary").expect("snapshot carries a summary object");
    assert_eq!(summary.get("gen_requests").and_then(|v| v.as_f64()), Some(1.0));
    assert!(parsed.get("series").and_then(|s| s.as_arr()).is_some_and(|s| !s.is_empty()));

    let prom = obs.prometheus();
    assert!(prom.contains("mfqat_gen_requests_total 1"), "{prom}");
    assert!(prom.contains("mfqat_ttft_seconds_bucket"), "{prom}");
    assert!(prom.contains("format=\"int8\""), "per-format labels must export\n{prom}");

    drop(client);
    server.shutdown();
}
