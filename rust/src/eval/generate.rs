//! Autoregressive generation.
//!
//! Two execution paths share one sampler ([`sample`] / [`SampleCfg`]):
//!
//! * [`generate_native_batch`] — the serving path: `rows` prompts prefill
//!   their (ragged) trailing windows through one batched KV cache, then
//!   every sequence decodes one token per step-synchronized pass
//!   ([`crate::backend::forward::forward_cached_batch`]); per-step cost is
//!   one `rows`-row pass over the packed weights plus attention over each
//!   row's own cached prefix — no full-window recompute, and the weight
//!   planes stream once per step for the whole batch. When a row's context
//!   outgrows `seq_len` only that row re-prefills from its trailing half
//!   window (amortized O(1) prefills per emitted token); each row carries
//!   its own sampler RNG, so the batch is **token-identical** to `rows`
//!   independent [`generate_native`] calls (which is itself the `rows = 1`
//!   wrapper).
//! * [`generate`] (feature `pjrt`) — the AOT `forward_b1` graph with
//!   full-sequence recompute per emitted token (quality/debug surface for
//!   the compiled path).

use crate::data::{decode, encode, PAD};
use crate::util::Rng;
use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::eval::ParamLiterals;
#[cfg(feature = "pjrt")]
use crate::runtime::{self, ArtifactSet, Runtime};
#[cfg(feature = "pjrt")]
use anyhow::anyhow;

/// Sampling configuration. `PartialEq` lets the server group generation
/// requests that can share one batched decode.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleCfg {
    /// 0.0 ⇒ greedy argmax.
    pub temperature: f32,
    /// 0 ⇒ no top-k truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.8,
            top_k: 8,
            seed: 0,
        }
    }
}

/// Generate `n_tokens` continuation tokens for a text prompt through the
/// native backend's KV-cached incremental decode (single-sequence wrapper
/// around [`generate_native_batch`]).
pub fn generate_native(
    w: &crate::backend::NativeWeights,
    prompt: &str,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Result<String> {
    let mut out = generate_native_batch(w, &[prompt], n_tokens, cfg)?;
    Ok(out.pop().expect("one continuation per prompt"))
}

/// Generate `n_tokens` continuation tokens for each of `prompts.len()`
/// prompts in one step-synchronized batched decode.
///
/// Every row carries its own sampler RNG (seeded `cfg.seed`, exactly as an
/// independent call would be) and its own re-prefill window, and every
/// per-row computation in [`forward_cached_batch`] is row-independent — so
/// the output is **token-identical** to calling [`generate_native`] once
/// per prompt, while the packed weight planes stream once per decode step
/// for the whole batch instead of once per sequence. When one row's window
/// overflows, only that row resets and re-prefills its trailing half
/// window (a ragged step); its neighbours keep decoding single tokens.
pub fn generate_native_batch(
    w: &crate::backend::NativeWeights,
    prompts: &[&str],
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Result<Vec<String>> {
    use crate::backend::forward::{forward_cached_batch, KvCache};
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let seq_len = w.dims.seq_len;
    let vocab = w.dims.vocab;
    let rows = prompts.len();
    let mut rngs: Vec<Rng> = (0..rows).map(|_| Rng::new(cfg.seed)).collect();
    let mut tokens: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let mut t = encode(p);
            if t.is_empty() {
                t.push(PAD as i32);
            }
            t
        })
        .collect();
    let start_lens: Vec<usize> = tokens.iter().map(|t| t.len()).collect();

    let mut cache = KvCache::with_rows(&w.dims, rows);
    // Ragged prefill: each row's trailing prompt window, leaving room to
    // decode, in one batched pass.
    let step: Vec<Vec<i32>> = tokens
        .iter()
        .map(|t| t[t.len().saturating_sub(seq_len)..].to_vec())
        .collect();
    let slices: Vec<&[i32]> = step.iter().map(|t| t.as_slice()).collect();
    let mut logits = forward_cached_batch(w, &mut cache, &slices)?;
    let mut counts: Vec<usize> = step.iter().map(|t| t.len()).collect();
    for emitted in 0..n_tokens {
        // Row r's next token comes from the last logits row of its chunk.
        let mut step: Vec<Vec<i32>> = Vec::with_capacity(rows);
        let mut off = 0usize;
        for r in 0..rows {
            let last = &logits[(off + counts[r] - 1) * vocab..(off + counts[r]) * vocab];
            off += counts[r];
            let next = sample(last, cfg, &mut rngs[r]) as i32;
            tokens[r].push(next);
            if cache.len_of(r) >= seq_len {
                // Row window full: re-prefill this row from its trailing
                // half so subsequent decodes are incremental again (one
                // prefill per seq_len/2 emitted tokens, amortized O(1)).
                let keep = (seq_len / 2).max(1);
                let ctx = tokens[r][tokens[r].len() - keep..].to_vec();
                cache.reset_row(r);
                step.push(ctx);
            } else {
                step.push(vec![next]);
            }
        }
        if emitted + 1 == n_tokens {
            break; // the last sample needs no further forward pass
        }
        let slices: Vec<&[i32]> = step.iter().map(|t| t.as_slice()).collect();
        logits = forward_cached_batch(w, &mut cache, &slices)?;
        counts = step.iter().map(|t| t.len()).collect();
    }
    Ok(tokens
        .iter()
        .zip(&start_lens)
        .map(|(t, &s)| decode(&t[s..]))
        .collect())
}

/// Generate `n_tokens` continuation tokens for a text prompt over the AOT
/// `forward_b1` graph (full-sequence recompute per token).
#[cfg(feature = "pjrt")]
pub fn generate(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    prompt: &str,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Result<String> {
    let m = &arts.manifest;
    let exe = arts.executable(rt, "forward_b1")?;
    let mut rng = Rng::new(cfg.seed);
    let mut tokens = encode(prompt);
    if tokens.is_empty() {
        tokens.push(PAD as i32);
    }
    let start_len = tokens.len();

    for _ in 0..n_tokens {
        // Window: last seq_len tokens, right-padded.
        let ctx_start = tokens.len().saturating_sub(m.seq_len);
        let ctx = &tokens[ctx_start..];
        let pos = ctx.len() - 1; // logits index predicting the next token
        let mut row = ctx.to_vec();
        row.resize(m.seq_len, PAD as i32);

        let lit = runtime::i32_literal(&row, &[1, m.seq_len])?;
        let mut args: Vec<&xla::Literal> = vec![&lit];
        args.extend(params.literals.iter());
        let out = exe.run(&args)?;
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let slice = &logits[pos * m.vocab..(pos + 1) * m.vocab];
        let next = sample(slice, cfg, &mut rng);
        tokens.push(next as i32);
    }
    Ok(decode(&tokens[start_len..]))
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], cfg: &SampleCfg, rng: &mut Rng) -> usize {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k + temperature softmax in f64.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(cfg.top_k);
    }
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max) as f64;
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - max) / cfg.temperature as f64).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1f32, 5.0, -2.0, 4.9];
        let cfg = SampleCfg {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
        };
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0f32, 9.0, -100.0, -100.0];
        let cfg = SampleCfg {
            temperature: 1.0,
            top_k: 2,
            seed: 0,
        };
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = sample(&logits, &cfg, &mut rng);
            assert!(s < 2, "sampled outside top-k: {s}");
        }
    }

    #[test]
    fn temperature_spreads_distribution() {
        let logits = vec![2.0f32, 1.0, 0.0];
        let mut hot = std::collections::HashSet::new();
        let cfg = SampleCfg {
            temperature: 5.0,
            top_k: 0,
            seed: 0,
        };
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            hot.insert(sample(&logits, &cfg, &mut rng));
        }
        assert_eq!(hot.len(), 3, "high temperature should hit all tokens");
    }

    #[test]
    fn batched_generation_matches_independent_calls() {
        use crate::backend::NativeWeights;
        use crate::formats::ElementFormat;
        use crate::model::{ModelDims, ParamSet};
        let mut dims = ModelDims::new("genb", 256, 32, 1, 2, 12);
        dims.train_batch = 2;
        let m = dims.to_manifest();
        let ck = ParamSet::init(&m, 13)
            .to_anchor_checkpoint(&m, ElementFormat::int(8))
            .unwrap();
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(4)).unwrap();
        let cfg = SampleCfg {
            temperature: 0.8,
            top_k: 6,
            seed: 21,
        };
        // Ragged prompts, generation long enough to cross the window and
        // exercise per-row re-prefill at different steps.
        let prompts = ["k", "kovaq blue", "the color of kova is violet", ""];
        let batch =
            generate_native_batch(&w, &prompts, 20, &cfg).unwrap();
        assert_eq!(batch.len(), prompts.len());
        for (r, p) in prompts.iter().enumerate() {
            let solo = generate_native(&w, p, 20, &cfg).unwrap();
            assert_eq!(batch[r], solo, "row {r} (prompt {p:?}) diverged");
        }
        assert!(generate_native_batch(&w, &[], 8, &cfg).unwrap().is_empty());
    }

    #[test]
    fn native_generation_is_deterministic_and_windowed() {
        use crate::backend::NativeWeights;
        use crate::formats::ElementFormat;
        use crate::model::{ModelDims, ParamSet};
        // Byte-level prompts need the full 256-token vocab.
        let mut dims = ModelDims::new("gen", 256, 32, 1, 2, 12);
        dims.train_batch = 2;
        let m = dims.to_manifest();
        let ck = ParamSet::init(&m, 11)
            .to_anchor_checkpoint(&m, ElementFormat::int(8))
            .unwrap();
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
        let cfg = SampleCfg {
            temperature: 0.7,
            top_k: 8,
            seed: 4,
        };
        // Generate past the model window to exercise the re-prefill path.
        let a = generate_native(&w, "kova", 24, &cfg).unwrap();
        let b = generate_native(&w, "kova", 24, &cfg).unwrap();
        assert_eq!(a.chars().count(), 24, "one char per token");
        assert_eq!(a, b, "same seed, same continuation");
    }
}
