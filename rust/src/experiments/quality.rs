//! Quality experiments: Fig. 1/4 perplexity sweeps and Tables 1–3 accuracy
//! grids.
//!
//! Protocol (paper §3.2): every trained variant — full-precision finetune,
//! single-format QAT at each format, and multi-format QAT — is converted to
//! each evaluation format with PTQ and measured in that target format, so
//! all comparisons isolate the training procedure.

use super::report::{ascii_plot, save_text, ResultTable, Series};
use super::Ctx;
use crate::data::tasks;
use crate::eval::{self, ParamLiterals};
use crate::formats::ElementFormat;
use crate::model::{anchor_for, ParamSet};
use anyhow::Result;

/// Evaluation formats per family (paper: MXINT 2–8, MXFP 4–8 incl. unseen).
pub fn eval_formats(family: &str) -> Vec<ElementFormat> {
    match family {
        "int" => ElementFormat::all_int(),
        "fp" => ElementFormat::all_fp(),
        _ => panic!("family must be int|fp"),
    }
}

/// Training variants per family, in the paper's row order.
pub fn variants(family: &str) -> Vec<String> {
    match family {
        "int" => vec![
            "ft_fp_int".into(),
            "qat_int2".into(),
            "qat_int4".into(),
            "qat_int6".into(),
            "qat_int8".into(),
            "mf_int".into(),
        ],
        "fp" => vec![
            "ft_fp_fp".into(),
            "qat_fp4".into(),
            "qat_fp6".into(),
            "qat_fp8".into(),
            "mf_fp".into(),
        ],
        _ => panic!("family must be int|fp"),
    }
}

/// PTQ-grid perplexity for one trained variant.
fn ppl_grid(ctx: &Ctx, params: &ParamSet, family: &str, via_anchor: bool) -> Result<Vec<(u8, f64)>> {
    let mut out = Vec::new();
    for fmt in eval_formats(family) {
        let q = if via_anchor {
            params.ptq_via_anchor(&ctx.arts.manifest, anchor_for(fmt), fmt)?
        } else {
            params.ptq(&ctx.arts.manifest, fmt)?
        };
        let ppl = ctx.val_ppl(&q)?;
        out.push((fmt.bits(), ppl));
        log::info!("  {}: ppl {:.3}{}", fmt, ppl, if via_anchor { " (via anchor)" } else { "" });
    }
    Ok(out)
}

/// Figure 1 (+ Appendix A.1): MF-QAT vs single-format QAT vs FP-FT,
/// perplexity vs evaluation bitwidth, both families.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    for family in ["int", "fp"] {
        let mut table = ResultTable::new(&["variant", "eval_bits", "ppl"]);
        let mut series = Vec::new();

        // Horizontal reference: the unquantized pretrained+FT model.
        let base = ctx.ensure_pretrained()?;
        let base_ppl = ctx.val_ppl(&base)?;
        table.push(vec!["base_fp32".into(), "-".into(), format!("{base_ppl:.4}")]);

        for variant in variants(family) {
            log::info!("[fig1/{family}] variant {variant}");
            let params = ctx.ensure_variant_best(&variant)?;
            let grid = ppl_grid(ctx, &params, family, false)?;
            for &(bits, ppl) in &grid {
                table.push(vec![variant.clone(), bits.to_string(), format!("{ppl:.4}")]);
            }
            series.push(Series {
                name: variant.clone(),
                points: grid.iter().map(|&(b, p)| (b as f64, p)).collect(),
            });
        }

        let stem = format!("fig1_{family}");
        table.save_csv(&ctx.result_path(&format!("{stem}.csv")))?;
        let plot = ascii_plot(
            &format!(
                "Fig.1 ({family}): WikiText-style val PPL vs eval bitwidth [config {}] (base fp32 ppl {base_ppl:.3})",
                ctx.arts.manifest.config_name
            ),
            "eval bitwidth",
            "perplexity",
            &series,
            true,
        );
        save_text(&ctx.result_path(&format!("{stem}.txt")), &format!("{plot}\n{}", table.to_text()))?;
        log::info!("[fig1/{family}] written to {}", ctx.result_path(&stem).display());
    }
    Ok(())
}

/// Figure 4 (+ Appendix A.2): multi-format QAT *with* Slice-and-Scale
/// (anchor-storage training + anchor-path PTQ) vs plain multi-format QAT.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    for family in ["int", "fp"] {
        let mut table = ResultTable::new(&["variant", "eval_bits", "ppl", "path"]);
        let mut series = Vec::new();

        let mf = ctx.ensure_variant_best(&format!("mf_{family}"))?;
        let grid = ppl_grid(ctx, &mf, family, false)?;
        for &(bits, ppl) in &grid {
            table.push(vec![
                format!("mf_{family}"),
                bits.to_string(),
                format!("{ppl:.4}"),
                "direct".into(),
            ]);
        }
        series.push(Series {
            name: format!("mf_{family} (direct PTQ)"),
            points: grid.iter().map(|&(b, p)| (b as f64, p)).collect(),
        });

        let mfss = ctx.ensure_variant_best(&format!("mf_ss_{family}"))?;
        let grid_ss = ppl_grid(ctx, &mfss, family, true)?;
        for &(bits, ppl) in &grid_ss {
            table.push(vec![
                format!("mf_ss_{family}"),
                bits.to_string(),
                format!("{ppl:.4}"),
                "anchor+SS".into(),
            ]);
        }
        series.push(Series {
            name: format!("mf_ss_{family} (anchor + SS)"),
            points: grid_ss.iter().map(|&(b, p)| (b as f64, p)).collect(),
        });

        let stem = format!("fig4_{family}");
        table.save_csv(&ctx.result_path(&format!("{stem}.csv")))?;
        let plot = ascii_plot(
            &format!("Fig.4 ({family}): MF-QAT with Slice-and-Scale vs plain MF-QAT"),
            "eval bitwidth",
            "perplexity",
            &series,
            true,
        );
        save_text(&ctx.result_path(&format!("{stem}.txt")), &format!("{plot}\n{}", table.to_text()))?;
    }
    Ok(())
}

/// Tables 1/2 (+ Appendix B): downstream accuracy grids. `family` selects
/// MXINT (tab1) or MXFP (tab2). Emits both the averaged grid and per-task
/// breakdowns.
pub fn table_grid(ctx: &Ctx, family: &str, stem: &str) -> Result<()> {
    let suite = tasks::standard_suite(&ctx.corpus, ctx.task_items, ctx.seed);
    let fmts = eval_formats(family);
    let mut avg = ResultTable::new(
        &std::iter::once("variant")
            .chain(fmts.iter().map(|f| Box::leak(f.long_name().into_boxed_str()) as &str))
            .collect::<Vec<_>>(),
    );
    let mut per_task = ResultTable::new(&["variant", "format", "task", "accuracy"]);

    for variant in variants(family) {
        log::info!("[{stem}] variant {variant}");
        let params = ctx.ensure_variant_best(&variant)?;
        let mut row = vec![variant.clone()];
        for &fmt in &fmts {
            let q = params.ptq(&ctx.arts.manifest, fmt)?;
            let lits = ParamLiterals::build(&q)?;
            let accs = eval::suite_accuracy(&ctx.rt, &ctx.arts, &lits, &suite)?;
            let mean: f64 = accs.iter().map(|(_, a)| a).sum::<f64>() / accs.len() as f64;
            for (task, acc) in &accs {
                per_task.push(vec![
                    variant.clone(),
                    fmt.long_name(),
                    task.clone(),
                    format!("{:.1}", acc * 100.0),
                ]);
            }
            log::info!("  {}: avg acc {:.1}%", fmt, mean * 100.0);
            row.push(format!("{:.1}", mean * 100.0));
        }
        avg.push(row);
    }

    avg.save_csv(&ctx.result_path(&format!("{stem}.csv")))?;
    per_task.save_csv(&ctx.result_path(&format!("{stem}_per_task.csv")))?;
    let title = format!(
        "Table {} ({}): avg 0-shot accuracy (SynKnow+SynMath+SynCont), rows=training, cols=PTQ format\n",
        if family == "int" { "1" } else { "2" },
        family
    );
    save_text(
        &ctx.result_path(&format!("{stem}.txt")),
        &format!("{title}\n{}", avg.to_text()),
    )?;
    Ok(())
}

/// Table 3: SynChart (ChartQA stand-in) accuracy grid, both families, the
/// paper's reduced variant set (FT, 4/6/8-bit singles, MF).
pub fn tab3(ctx: &Ctx) -> Result<()> {
    let task = tasks::syn_chart(ctx.task_items, ctx.seed);
    let mut table = ResultTable::new(&["family", "variant", "format", "accuracy"]);
    for family in ["int", "fp"] {
        let vars: Vec<String> = match family {
            "int" => vec![
                "ft_fp_int".into(),
                "qat_int4".into(),
                "qat_int6".into(),
                "qat_int8".into(),
                "mf_int".into(),
            ],
            _ => vec![
                "ft_fp_fp".into(),
                "qat_fp4".into(),
                "qat_fp6".into(),
                "qat_fp8".into(),
                "mf_fp".into(),
            ],
        };
        let fmts: Vec<ElementFormat> = eval_formats(family)
            .into_iter()
            .filter(|f| f.bits() >= 4)
            .collect();
        for variant in vars {
            log::info!("[tab3/{family}] variant {variant}");
            let params = ctx.ensure_variant_best(&variant)?;
            for &fmt in &fmts {
                let q = params.ptq(&ctx.arts.manifest, fmt)?;
                let lits = ParamLiterals::build(&q)?;
                let acc = eval::mc_accuracy(&ctx.rt, &ctx.arts, &lits, &task)?;
                log::info!("  {}: {:.1}%", fmt, acc * 100.0);
                table.push(vec![
                    family.into(),
                    variant.clone(),
                    fmt.long_name(),
                    format!("{:.1}", acc * 100.0),
                ]);
            }
        }
    }
    table.save_csv(&ctx.result_path("tab3.csv"))?;
    save_text(
        &ctx.result_path("tab3.txt"),
        &format!(
            "Table 3: SynChart (ChartQA stand-in) accuracy grid\n\n{}",
            table.to_text()
        ),
    )?;
    Ok(())
}
