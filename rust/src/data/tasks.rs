//! Downstream probe tasks — MMLU / MathQA / HellaSwag / ChartQA stand-ins.
//!
//! Every task is 0-shot multiple choice scored by length-normalized
//! continuation log-likelihood (the lm-eval-harness `acc_norm` protocol the
//! paper uses). Items are derived from the same seeded symbol tables as the
//! corpus, so a pretrained model holds the knowledge and quantization noise
//! degrades it measurably:
//!
//! * **SynKnow** (≈MMLU): fact recall — `the color of kova is` → 4 values.
//! * **SynMath** (≈MathQA): `3 plus 4 equals` → 4 candidate sums.
//! * **SynCont** (≈HellaSwag): pick the true continuation of a corpus
//!   prefix among shuffled distractors.
//! * **SynChart** (≈ChartQA): `chart : a 3 , b 8 ... ; max` → series names;
//!   charts are freshly sampled (held out from pretraining text).

use super::corpus::{random_chart, Corpus};
use super::decode;
use crate::util::Rng;

/// One multiple-choice item. `prompt` and `choices` are raw text; choice
/// texts are appended to the prompt for scoring.
#[derive(Debug, Clone)]
pub struct McItem {
    /// Prompt text shared by all choices.
    pub prompt: String,
    /// Choice texts (appended to the prompt for scoring).
    pub choices: Vec<String>,
    /// Index of the correct choice.
    pub answer: usize,
}

/// A named task = a list of items.
#[derive(Debug, Clone)]
pub struct Task {
    /// Task name (reported in tables).
    pub name: String,
    /// The task's items.
    pub items: Vec<McItem>,
}

impl Task {
    /// Item count.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether the task has no items.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Build SynKnow from the corpus fact table.
pub fn syn_know(corpus: &Corpus, n_items: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ 0x5EED_01);
    let mut items = Vec::new();
    for _ in 0..n_items {
        let f = rng.pick(&corpus.facts);
        let (_, values) = corpus
            .attr_values
            .iter()
            .find(|(a, _)| *a == f.attr)
            .expect("attr in table");
        let mut choices: Vec<String> = values.clone();
        rng.shuffle(&mut choices);
        let answer = choices.iter().position(|c| *c == f.value).unwrap();
        items.push(McItem {
            prompt: format!("the {} of {} is", f.attr, f.entity),
            choices: choices.iter().map(|c| format!(" {c}")).collect(),
            answer,
        });
    }
    Task {
        name: "SynKnow".into(),
        items,
    }
}

/// Build SynMath: addition completions with near-miss distractors.
pub fn syn_math(n_items: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ 0x5EED_02);
    let mut items = Vec::new();
    while items.len() < n_items {
        let a = rng.below(10);
        let b = rng.below(10);
        let c = a + b;
        let mut opts = vec![c];
        // Distractors: ±1, ±2, or a random digit-sum — all distinct.
        for delta in [1i64, -1, 2, -2, 3] {
            let d = c as i64 + delta;
            if d >= 0 && !opts.contains(&(d as usize)) {
                opts.push(d as usize);
            }
            if opts.len() == 4 {
                break;
            }
        }
        if opts.len() < 4 {
            continue;
        }
        let correct = opts[0];
        rng.shuffle(&mut opts);
        let answer = opts.iter().position(|&x| x == correct).unwrap();
        items.push(McItem {
            prompt: format!("{a} plus {b} equals"),
            choices: opts.iter().map(|x| format!(" {x}")).collect(),
            answer,
        });
    }
    Task {
        name: "SynMath".into(),
        items,
    }
}

/// Build SynCont: true continuation vs token-shuffled distractors.
pub fn syn_cont(corpus: &Corpus, n_items: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ 0x5EED_03);
    let mut items = Vec::new();
    let prefix_len = 48;
    let cont_len = 16;
    for _ in 0..n_items {
        let row = rng.pick(&corpus.val);
        let start = rng.below(row.len() - prefix_len - cont_len);
        let prompt = decode(&row[start..start + prefix_len]);
        let true_cont = &row[start + prefix_len..start + prefix_len + cont_len];
        let mut choices = vec![decode(true_cont)];
        while choices.len() < 4 {
            // Distractor: same bytes shuffled at word granularity — locally
            // plausible vocabulary, wrong order. Re-shuffle (and finally
            // perturb bytes) until distinct from every existing choice.
            let text = decode(true_cont);
            let mut tokens: Vec<&str> = text.split(' ').collect();
            let mut candidate = String::new();
            for attempt in 0..8 {
                rng.shuffle(&mut tokens);
                candidate = tokens.join(" ");
                if attempt >= 6 {
                    // Degenerate continuation (e.g. one word): mutate a byte.
                    let mut bytes = candidate.into_bytes();
                    let i = rng.below(bytes.len().max(1));
                    bytes[i] = b'a' + (rng.below(26) as u8);
                    candidate = String::from_utf8_lossy(&bytes).to_string();
                }
                if !choices.contains(&candidate) {
                    break;
                }
            }
            if choices.contains(&candidate) {
                continue;
            }
            choices.push(candidate);
        }
        let mut order: Vec<usize> = (0..4).collect();
        rng.shuffle(&mut order);
        let answer = order.iter().position(|&i| i == 0).unwrap();
        let choices = order.into_iter().map(|i| choices[i].clone()).collect();
        items.push(McItem {
            prompt,
            choices,
            answer,
        });
    }
    Task {
        name: "SynCont".into(),
        items,
    }
}

/// Build SynChart: max/min questions over held-out charts.
pub fn syn_chart(n_items: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ 0x5EED_04);
    let mut items = Vec::new();
    for i in 0..n_items {
        let chart = random_chart(&mut rng);
        let ask_max = i % 2 == 0;
        let target = if ask_max { chart.argmax() } else { chart.argmin() };
        let answer = chart.names.iter().position(|&n| n == target).unwrap();
        items.push(McItem {
            prompt: format!(
                "{} ; {}",
                chart.text(),
                if ask_max { "max" } else { "min" }
            ),
            choices: chart.names.iter().map(|n| format!(" {n}")).collect(),
            answer,
        });
    }
    Task {
        name: "SynChart".into(),
        items,
    }
}

/// The standard evaluation suite (≈ the paper's MMLU+MathQA+HellaSwag avg).
pub fn standard_suite(corpus: &Corpus, n_items: usize, seed: u64) -> Vec<Task> {
    vec![
        syn_know(corpus, n_items, seed),
        syn_math(n_items, seed),
        syn_cont(corpus, n_items, seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig {
            pretrain_sequences: 8,
            ..Default::default()
        })
    }

    #[test]
    fn all_tasks_are_well_formed() {
        let c = corpus();
        for task in [
            syn_know(&c, 40, 1),
            syn_math(40, 1),
            syn_cont(&c, 40, 1),
            syn_chart(40, 1),
        ] {
            assert_eq!(task.len(), 40, "{}", task.name);
            for item in &task.items {
                assert!(item.answer < item.choices.len(), "{}", task.name);
                assert!(!item.prompt.is_empty());
                assert!(item.choices.len() >= 3);
                // Choices must be distinct, or scoring is ill-posed.
                let mut c2 = item.choices.clone();
                c2.sort();
                c2.dedup();
                assert_eq!(c2.len(), item.choices.len(), "{}: {:?}", task.name, item);
            }
        }
    }

    #[test]
    fn tasks_are_deterministic_per_seed() {
        let c = corpus();
        let a = syn_math(10, 7);
        let b = syn_math(10, 7);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
        let d = syn_math(10, 8);
        assert!(a.items.iter().zip(&d.items).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn syn_know_answers_match_fact_table() {
        let c = corpus();
        let t = syn_know(&c, 60, 3);
        for item in &t.items {
            // prompt: "the <attr> of <entity> is"
            let parts: Vec<&str> = item.prompt.split(' ').collect();
            let attr = parts[1];
            let entity = parts[3];
            let fact = c
                .facts
                .iter()
                .find(|f| f.attr == attr && f.entity == entity)
                .unwrap();
            assert_eq!(item.choices[item.answer].trim(), fact.value);
        }
    }

    #[test]
    fn syn_math_correct_answer_is_the_sum() {
        let t = syn_math(60, 9);
        for item in &t.items {
            let parts: Vec<&str> = item.prompt.split(' ').collect();
            let a: usize = parts[0].parse().unwrap();
            let b: usize = parts[2].parse().unwrap();
            let val: usize = item.choices[item.answer].trim().parse().unwrap();
            assert_eq!(val, a + b);
        }
    }

    #[test]
    fn syn_chart_answer_is_correct_series() {
        let t = syn_chart(60, 11);
        for item in &t.items {
            // Recompute from the prompt text.
            let is_max = item.prompt.ends_with("max");
            let body = item
                .prompt
                .trim_start_matches("chart : ")
                .split(" ;")
                .next()
                .unwrap();
            let mut best: Option<(char, i32)> = None;
            for pair in body.split(" , ") {
                let mut it = pair.split(' ');
                let name = it.next().unwrap().chars().next().unwrap();
                let v: i32 = it.next().unwrap().parse().unwrap();
                best = match best {
                    None => Some((name, v)),
                    Some((bn, bv)) => {
                        if (is_max && v > bv) || (!is_max && v < bv) {
                            Some((name, v))
                        } else {
                            Some((bn, bv))
                        }
                    }
                };
            }
            let want = best.unwrap().0;
            assert_eq!(item.choices[item.answer].trim().chars().next().unwrap(), want);
        }
    }
}
