//! End-to-end pipeline: train steps run and reduce loss; the anchor
//! checkpoint → Slice-and-Scale → serving path produces sane scores.

use mfqat::checkpoint::Checkpoint;
use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use mfqat::train::Trainer;
use std::path::PathBuf;

fn arts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping (run `make artifacts`)");
        None
    }
}

fn small_corpus(width: usize) -> Corpus {
    Corpus::generate(CorpusConfig {
        seed: 7,
        width,
        pretrain_sequences: 32,
        qat_sequences: 16,
        val_sequences: 8,
    })
}

#[test]
fn train_steps_reduce_loss_and_only_touch_trainables() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let corpus = small_corpus(arts.manifest.seq_len + 1);
    let params = ParamSet::init(&arts.manifest, 1);
    let before_emb = params.tensors[0].clone();
    let quant_idx = arts.manifest.quant_indices();
    let before_quant = params.tensors[quant_idx[0]].clone();

    let mut trainer = Trainer::new(&rt, &arts, params);
    // Two epochs of single-format QAT on a small slice.
    let s1 = trainer.train_epoch("qat_int4", &corpus.pretrain, 1e-3).unwrap();
    let s2 = trainer.train_epoch("qat_int4", &corpus.pretrain, 1e-3).unwrap();
    assert!(s1.mean_loss.is_finite());
    assert!(
        s2.mean_loss < s1.mean_loss,
        "loss should fall: {} -> {}",
        s1.mean_loss,
        s2.mean_loss
    );
    // Frozen params (embedding) unchanged; quantized weights moved.
    assert_eq!(trainer.params.tensors[0], before_emb, "emb frozen in QAT");
    assert_ne!(trainer.params.tensors[quant_idx[0]], before_quant);
}

#[test]
fn pretrain_updates_everything() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let corpus = small_corpus(arts.manifest.seq_len + 1);
    let params = ParamSet::init(&arts.manifest, 2);
    let before_emb = params.tensors[0].clone();
    let mut trainer = Trainer::new(&rt, &arts, params);
    let rows = &corpus.pretrain[..8];
    let s = trainer.train_epoch("pretrain", rows, 1e-3).unwrap();
    assert!(s.mean_loss.is_finite());
    assert_ne!(trainer.params.tensors[0], before_emb, "emb trains in pretrain");
}

#[test]
fn optimizer_state_persists_across_formats_in_a_schedule() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let corpus = small_corpus(arts.manifest.seq_len + 1);
    let params = ParamSet::init(&arts.manifest, 3);
    let mut trainer = Trainer::new(&rt, &arts, params);
    let rows = &corpus.qat[..8];
    trainer.train_epoch("qat_int2", rows, 1e-3).unwrap();
    let step_after_first = trainer.step;
    trainer.train_epoch("qat_int4", rows, 1e-3).unwrap();
    // Same trainable set → the step counter keeps counting (no reset).
    assert_eq!(trainer.step, step_after_first * 2);
}

#[test]
fn anchor_checkpoint_to_elastic_scoring() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let corpus = small_corpus(arts.manifest.seq_len + 1);
    let params = ParamSet::init(&arts.manifest, 4);

    // Store anchor, reload through the engine, score at several formats.
    let tmp = std::env::temp_dir().join("mfqat_e2e_anchor.mfq");
    params
        .to_anchor_checkpoint(&arts.manifest, ElementFormat::int(8))
        .unwrap()
        .save(&tmp)
        .unwrap();
    let ck = Checkpoint::load(&tmp).unwrap();
    let engine = ElasticEngine::from_parts(rt, arts, ck, ElementFormat::int(8), 64 << 20);

    let dims = engine.dims().clone();
    let mut batch = Vec::new();
    for r in 0..dims.train_batch {
        batch.extend_from_slice(&corpus.val[r][..dims.seq_len + 1]);
    }
    let nll8 = engine.score_batch(&batch, ElementFormat::int(8)).unwrap();
    let nll4 = engine.score_batch(&batch, ElementFormat::int(4)).unwrap();
    let nll2 = engine.score_batch(&batch, ElementFormat::int(2)).unwrap();
    for row in [&nll8, &nll4, &nll2] {
        assert_eq!(row.len(), dims.train_batch);
        assert!(row.iter().all(|x| x.is_finite() && *x > 0.0));
    }
    // Untrained model ≈ uniform everywhere; formats shouldn't explode it.
    let uniform = (dims.vocab as f32).ln();
    assert!((nll8[0] - uniform).abs() < 1.5, "nll8 {} vs {}", nll8[0], uniform);

    // Each distinct format = exactly one conversion; repeats are cache hits.
    assert_eq!(engine.conversions(), 3);
    engine.score_batch(&batch, ElementFormat::int(4)).unwrap();
    assert_eq!(engine.conversions(), 3, "cache hit on repeat");
    assert_eq!(engine.cached_formats(), 3);
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn generation_produces_valid_tokens() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let params = ParamSet::init(&arts.manifest, 6);
    let lits = mfqat::eval::ParamLiterals::build(&params).unwrap();
    let cfg = mfqat::eval::generate::SampleCfg {
        temperature: 1.0,
        top_k: 16,
        seed: 9,
    };
    let out = mfqat::eval::generate::generate(&rt, &arts, &lits, "the color of", 24, &cfg)
        .unwrap();
    assert_eq!(out.chars().count(), 24, "one byte-token per step: {out:?}");
    // Deterministic per seed.
    let out2 = mfqat::eval::generate::generate(&rt, &arts, &lits, "the color of", 24, &cfg)
        .unwrap();
    assert_eq!(out, out2);
    // Greedy differs from seeded sampling in general but is also stable.
    let greedy_cfg = mfqat::eval::generate::SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 1,
    };
    let g1 = mfqat::eval::generate::generate(&rt, &arts, &lits, "3 plus 4", 8, &greedy_cfg)
        .unwrap();
    let g2 = mfqat::eval::generate::generate(&rt, &arts, &lits, "3 plus 4", 8, &greedy_cfg)
        .unwrap();
    assert_eq!(g1, g2);
}

#[test]
fn ss_training_variants_execute() {
    let Some(dir) = arts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = ArtifactSet::open(&dir).unwrap();
    let corpus = small_corpus(arts.manifest.seq_len + 1);
    let params = ParamSet::init(&arts.manifest, 5);
    let mut trainer = Trainer::new(&rt, &arts, params);
    let rows = &corpus.qat[..8];
    // The §3.5 anchor-composition graphs run and produce finite losses.
    let a = trainer.train_epoch("qat_ss_int4", rows, 1e-3).unwrap();
    let b = trainer.train_epoch("qat_ss_fp4", rows, 1e-3).unwrap();
    assert!(a.mean_loss.is_finite() && b.mean_loss.is_finite());
}
