//! The native CPU backend: anchor checkpoint → packed per-format weights →
//! block-major GEMM forward. No XLA, no AOT artifacts.
//!
//! The unquantized f32 parameters (embeddings, norms, LM head) are loaded
//! **once** from the anchor and `Arc`-shared into every cached format's
//! weight set, so a `FormatCache` entry costs only its packed planes; the
//! cache budget is charged accordingly ([`NativeWeights::packed_bytes`]).

use super::forward::{self, ActMode, KvCache, NativeWeights, SharedParams};
use super::kvpool::{KvMemory, KvPageCfg};
use super::{Backend, DecodeSession};
use crate::checkpoint::Checkpoint;
use crate::coordinator::format_cache::{CacheStats, FormatCache};
use crate::eval::generate::{ContinuousBatch, FinishedRow, RowStepEvent, SampleCfg};
use crate::formats::ElementFormat;
use crate::model::ModelDims;
use crate::util::sync::RobustMutex;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::Arc;

/// Native packed-MX inference engine.
///
/// One anchor checkpoint serves every target format; scoring windows are
/// `seq_len + 1` tokens wide:
///
/// ```
/// use mfqat::backend::{Backend, NativeBackend};
/// use mfqat::formats::ElementFormat;
/// use mfqat::model::{ModelDims, ParamSet};
///
/// let mut dims = ModelDims::new("doc", 64, 32, 2, 2, 16);
/// dims.train_batch = 2;
/// let manifest = dims.to_manifest();
/// let ck = ParamSet::init(&manifest, 1)
///     .to_anchor_checkpoint(&manifest, ElementFormat::int(8))
///     .unwrap();
/// let be = NativeBackend::new(dims, ck, 64 << 20).unwrap();
/// let tokens: Vec<i32> = (0..2 * 17).map(|i| i % 64).collect();
/// let nll = be.score_batch(&tokens, ElementFormat::int(4)).unwrap();
/// assert_eq!(nll.len(), 2);
/// assert!(nll.iter().all(|v| v.is_finite()));
/// ```
pub struct NativeBackend {
    dims: ModelDims,
    anchor: Checkpoint,
    anchor_fmt: ElementFormat,
    act: ActMode,
    shared: Arc<SharedParams>,
    /// Poison-proof: a server worker that panics while deriving weights
    /// must not wedge every other worker's cache lookups.
    cache: RobustMutex<FormatCache<NativeWeights>>,
}

impl NativeBackend {
    /// Build from an in-memory anchor checkpoint. The anchor format comes
    /// from the checkpoint's `anchor` metadata; master (all-f32)
    /// checkpoints work too and serve each format via direct PTQ.
    pub fn new(dims: ModelDims, anchor: Checkpoint, cache_bytes: usize) -> Result<NativeBackend> {
        // Master checkpoints carry no anchor meta; record the family
        // default so `anchor_fmt` always names a sensible precision.
        let anchor_fmt = anchor.anchor_format()?.unwrap_or(ElementFormat::int(8));
        let shared = Arc::new(SharedParams::from_checkpoint(&dims, &anchor)?);
        log::info!(
            "native: shared f32 params loaded once ({:.2} MB, Arc-shared across formats)",
            shared.storage_bytes() as f64 / 1e6
        );
        Ok(NativeBackend {
            dims,
            anchor,
            anchor_fmt,
            act: ActMode::F32,
            shared,
            cache: RobustMutex::new(FormatCache::new(cache_bytes)),
        })
    }

    /// Load the anchor checkpoint from disk.
    pub fn open(dims: ModelDims, checkpoint: &Path, cache_bytes: usize) -> Result<NativeBackend> {
        let anchor = Checkpoint::load(checkpoint)?;
        NativeBackend::new(dims, anchor, cache_bytes)
    }

    /// Select the activation pipeline for packed linears (builder-style).
    /// [`ActMode::Int8`] runs MXINT formats through the integer-MAC GEMM.
    pub fn with_act(mut self, act: ActMode) -> NativeBackend {
        self.act = act;
        self
    }

    /// Activation pipeline in use.
    pub fn act(&self) -> ActMode {
        self.act
    }

    /// Anchor precision the checkpoint stores.
    pub fn anchor_fmt(&self) -> ElementFormat {
        self.anchor_fmt
    }

    /// Packed serving weights for `fmt`, derived from the anchor via
    /// Slice-and-Scale + block-major repack (cached, LRU; the shared f32
    /// set rides along by `Arc`).
    pub fn weights(&self, fmt: ElementFormat) -> Result<Arc<NativeWeights>> {
        if let Some(w) = self.cache.lock().get(fmt) {
            return Ok(w);
        }
        let t = std::time::Instant::now();
        let w = Arc::new(NativeWeights::packed_with_shared(
            &self.dims,
            &self.anchor,
            fmt,
            self.shared.clone(),
            self.act,
        )?);
        // Charge the cache for this entry's own bytes only: the f32
        // parameters are shared across every entry, not duplicated.
        let bytes = w.packed_bytes();
        log::info!(
            "native: derived packed {} weights from anchor {} in {:.1} ms \
             ({:.2} MB packed + {:.2} MB shared f32, act={})",
            fmt,
            self.anchor_fmt,
            t.elapsed().as_secs_f64() * 1e3,
            bytes as f64 / 1e6,
            self.shared.storage_bytes() as f64 / 1e6,
            self.act.name()
        );
        self.cache.lock().put(fmt, w.clone(), bytes);
        Ok(w)
    }

    /// Fresh single-sequence KV cache sized for this model.
    pub fn kv_cache(&self) -> KvCache {
        KvCache::new(&self.dims)
    }

    /// Fresh batched KV cache for `rows` step-synchronized sequences.
    pub fn kv_cache_rows(&self, rows: usize) -> KvCache {
        KvCache::with_rows(&self.dims, rows)
    }

    /// Greedy/temperature generation at `fmt` with KV-cached incremental
    /// decode (see [`crate::eval::generate::generate_native`]).
    pub fn generate(
        &self,
        prompt: &str,
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<String> {
        let w = self.weights(fmt)?;
        crate::eval::generate::generate_native(&w, prompt, n_tokens, cfg)
    }

    /// Batched generation at `fmt`: all prompts decode step-synchronized
    /// through one batched KV cache, token-identical to per-prompt
    /// [`Self::generate`] calls (see
    /// [`crate::eval::generate::generate_native_batch`]).
    pub fn generate_batch(
        &self,
        prompts: &[&str],
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<Vec<String>> {
        let w = self.weights(fmt)?;
        crate::eval::generate::generate_native_batch(&w, prompts, n_tokens, cfg)
    }

    /// Open a continuous-batching decode session over `slots` KV rows.
    /// Joined rows pull their weight sets from this backend's `FormatCache`
    /// (so every format in the session shares one `Arc`'d f32 parameter
    /// set), letting rows of *different* formats decode in one
    /// step-synchronized pass. KV storage is paged with the environment's
    /// default sizing ([`KvPageCfg::from_env`]).
    pub fn decode_session(&self, slots: usize) -> Result<NativeDecodeSession<'_>> {
        self.decode_session_cfg(slots, KvPageCfg::from_env())
    }

    /// [`Self::decode_session`] with an explicit KV page-pool sizing: the
    /// session's resident KV memory tracks live context in `kv` pages, and
    /// a `kv.budget_pages` below the dense-equivalent pool makes
    /// [`DecodeSession::can_admit`] memory-aware (joins defer while the
    /// pool cannot fund a worst-case row).
    pub fn decode_session_cfg(
        &self,
        slots: usize,
        kv: KvPageCfg,
    ) -> Result<NativeDecodeSession<'_>> {
        if slots == 0 {
            anyhow::bail!("a decode session wants at least one slot");
        }
        Ok(NativeDecodeSession {
            backend: self,
            inner: ContinuousBatch::with_kv(&self.dims, slots, kv),
        })
    }
}

/// [`DecodeSession`] over the native backend: a
/// [`ContinuousBatch`] whose per-row weight sets resolve through the
/// backend's format cache at join time.
pub struct NativeDecodeSession<'a> {
    backend: &'a NativeBackend,
    inner: ContinuousBatch<Arc<NativeWeights>>,
}

impl NativeDecodeSession<'_> {
    /// Batch-pressure threshold for speculative rows (see
    /// [`ContinuousBatch::set_spec_pressure`]): on steps with more live
    /// rows than this, speculative rows skip drafting and decode plainly.
    pub fn set_spec_pressure(&mut self, rows: usize) {
        self.inner.set_spec_pressure(rows);
    }

    /// Lifetime `(drafted, accepted)` draft-token counts for the
    /// speculative row in `slot` (see [`ContinuousBatch::spec_stats`]).
    pub fn spec_stats(&self, slot: usize) -> Option<(u64, u64)> {
        self.inner.spec_stats(slot)
    }
}

impl DecodeSession for NativeDecodeSession<'_> {
    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn active(&self) -> usize {
        self.inner.active()
    }

    fn join(
        &mut self,
        prompt: &str,
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<usize> {
        let w = self.backend.weights(fmt)?;
        self.inner.join(w, prompt, n_tokens, cfg)
    }

    fn join_spec(
        &mut self,
        prompt: &str,
        fmt: ElementFormat,
        spec: &crate::eval::generate::SpecCfg,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<usize> {
        if fmt == spec.draft_format {
            // Drafting with the verify weights buys nothing — decode
            // plainly rather than erroring (the server picks `fmt` per
            // request; a request *at* the draft format is legitimate).
            return self.join(prompt, fmt, n_tokens, cfg);
        }
        let w = self.backend.weights(fmt)?;
        let draft = self.backend.weights(spec.draft_format)?;
        self.inner
            .join_spec(w, draft, prompt, n_tokens, cfg, spec.k, spec.policy)
    }

    fn cancel(&mut self, slot: usize) -> Result<()> {
        self.inner.retire(slot)
    }

    fn step(&mut self) -> Result<Vec<FinishedRow>> {
        self.inner.step()
    }

    fn step_with_events(&mut self) -> Result<(Vec<FinishedRow>, Vec<RowStepEvent>)> {
        self.inner.step_with_events()
    }

    fn can_admit(&self) -> bool {
        self.inner.can_admit()
    }

    fn kv_memory(&self) -> KvMemory {
        self.inner.kv_memory()
    }

    fn shrink_kv_budget(&mut self, pages: usize) -> usize {
        self.inner.shrink_kv_budget(pages)
    }

    fn attach_kv_ledger(&mut self, ledger: std::sync::Arc<crate::backend::PageLedger>) {
        self.inner.attach_kv_ledger(ledger);
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn forward_logits(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let w = self.weights(fmt)?;
        let t = self.dims.seq_len;
        if tokens.is_empty() || tokens.len() % t != 0 {
            return Err(anyhow!(
                "forward wants a non-empty multiple of seq_len ({t}) tokens, got {}",
                tokens.len()
            ));
        }
        forward::forward_logits(&w, tokens, tokens.len() / t)
    }

    fn score_batch(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let w = self.weights(fmt)?;
        let width = self.dims.seq_len + 1;
        if tokens.is_empty() || tokens.len() % width != 0 {
            return Err(anyhow!(
                "scoring wants a non-empty multiple of seq_len+1 ({width}) tokens, got {}",
                tokens.len()
            ));
        }
        // Short batches run at their true size — no padding waste.
        forward::score_rows(&w, tokens, tokens.len() / width)
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.lock().stats()
    }

    fn generate(
        &self,
        prompt: &str,
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<String> {
        NativeBackend::generate(self, prompt, fmt, n_tokens, cfg)
    }

    fn generate_batch(
        &self,
        prompts: &[&str],
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &SampleCfg,
    ) -> Result<Vec<String>> {
        NativeBackend::generate_batch(self, prompts, fmt, n_tokens, cfg)
    }

    fn decode_session(&self, slots: usize) -> Result<Box<dyn DecodeSession + '_>> {
        Ok(Box::new(NativeBackend::decode_session(self, slots)?))
    }

    fn decode_session_cfg(
        &self,
        slots: usize,
        kv: KvPageCfg,
    ) -> Result<Box<dyn DecodeSession + '_>> {
        Ok(Box::new(NativeBackend::decode_session_cfg(self, slots, kv)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;

    fn backend(cache_bytes: usize) -> NativeBackend {
        let mut dims = ModelDims::new("unit", 64, 32, 2, 2, 16);
        dims.train_batch = 2;
        let m = dims.to_manifest();
        let ck = ParamSet::init(&m, 7)
            .to_anchor_checkpoint(&m, ElementFormat::int(8))
            .unwrap();
        NativeBackend::new(dims, ck, cache_bytes).unwrap()
    }

    #[test]
    fn scores_and_caches_per_format() {
        let be = backend(64 << 20);
        let tokens: Vec<i32> = (0..2 * 17).map(|i| (i % 64) as i32).collect();
        for fmt in [ElementFormat::int(8), ElementFormat::int(4)] {
            let nll = be.score_batch(&tokens, fmt).unwrap();
            assert_eq!(nll.len(), 2);
            assert!(nll.iter().all(|v| v.is_finite() && *v > 0.0));
        }
        // Repeat scoring hits the cache.
        be.score_batch(&tokens, ElementFormat::int(4)).unwrap();
        let s = be.cache_stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.entries, 2);
        assert!(s.used_bytes > 0);
    }

    #[test]
    fn cache_entries_share_one_f32_set() {
        let be = backend(64 << 20);
        let w8 = be.weights(ElementFormat::int(8)).unwrap();
        let w4 = be.weights(ElementFormat::int(4)).unwrap();
        assert!(
            Arc::ptr_eq(&w8.shared, &w4.shared),
            "formats must share the f32 params"
        );
        // Cache charges packed planes only.
        let s = be.cache_stats();
        assert_eq!(s.used_bytes, w8.packed_bytes() + w4.packed_bytes());
        assert!(s.used_bytes < w8.storage_bytes() + w4.storage_bytes());
    }

    #[test]
    fn int8_act_mode_scores_close_to_f32() {
        let mut dims = ModelDims::new("unit", 64, 32, 2, 2, 16);
        dims.train_batch = 2;
        let m = dims.to_manifest();
        let ck = ParamSet::init(&m, 9)
            .to_anchor_checkpoint(&m, ElementFormat::int(8))
            .unwrap();
        let exact = NativeBackend::new(dims.clone(), ck.clone(), 1 << 20).unwrap();
        let intmac = NativeBackend::new(dims, ck, 1 << 20)
            .unwrap()
            .with_act(ActMode::Int8);
        let tokens: Vec<i32> = (0..2 * 17).map(|i| (i * 3 % 64) as i32).collect();
        let a = exact.score_batch(&tokens, ElementFormat::int(8)).unwrap();
        let b = intmac.score_batch(&tokens, ElementFormat::int(8)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(y.is_finite());
            assert!((x - y).abs() < 0.05, "act quantization drift: {x} vs {y}");
        }
    }

    #[test]
    fn tiny_cache_evicts() {
        let be = backend(1); // everything is over-budget → single resident set
        let tokens: Vec<i32> = (0..2 * 17).map(|i| (i % 64) as i32).collect();
        be.score_batch(&tokens, ElementFormat::int(8)).unwrap();
        be.score_batch(&tokens, ElementFormat::int(4)).unwrap();
        let s = be.cache_stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn wrong_batch_shape_is_rejected() {
        let be = backend(1 << 20);
        assert!(be.score_batch(&[1, 2, 3], ElementFormat::int(8)).is_err());
        assert!(be.forward_logits(&[1, 2, 3], ElementFormat::int(8)).is_err());
    }
}
