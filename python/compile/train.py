"""L2: AdamW train-step builders for every training variant.

Variants (paper section 3.2 / 3.5):

* ``pretrain``      — all parameters trainable, no quantization (builds the
                      "pretrained model" substrate the paper starts from).
* ``ft_fp``         — full-precision finetune: only the decoder-stack
                      linears are trainable (paper's FP baseline).
* ``qat_<fmt>``     — single-format QAT at ``fmt``; the weight transform is
                      the L1 Pallas fake-quant kernel behind an STE.
* ``qat_ss_<fmt>``  — anchor-storage QAT (section 3.5):
                      ``W_t = Q_{A->t}(Q_A(W))`` with the 8-bit anchor of the
                      same family; STE through both operators.

Multi-format QAT is a *schedule over* these steps (the rust trainer cycles
formats across epochs in increasing bit order), so no extra graph is needed.

Each builder returns a function with signature

    step(lr, tokens, *train_params, *frozen_params, *m, *v)
      -> (loss, *new_train_params, *new_m, *new_v)

where the train/frozen split follows ``variant_trainable`` and the AdamW
state covers the trainable set only. ``lr`` is a runtime scalar so learning
-rate sweeps reuse one compiled executable.
"""

from typing import Optional

import jax
import jax.numpy as jnp

from . import formats as F
from . import model as M

# torch.optim.AdamW defaults (paper: "default hyperparameters").
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
ADAM_WD = 0.01


def adamw_update(p, g, m, v, step, lr):
    """One AdamW step (decoupled weight decay), f32 state."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mh = m / (1.0 - ADAM_B1 ** step)
    vh = v / (1.0 - ADAM_B2 ** step)
    p = p - lr * (mh / (jnp.sqrt(vh) + ADAM_EPS) + ADAM_WD * p)
    return p, m, v


# --------------------------------------------------------------------------
# variants
# --------------------------------------------------------------------------

def parse_variant(name: str):
    """-> (fmt, anchor, trainable) for a variant name."""
    if name == "pretrain":
        return None, None, "all"
    if name == "ft_fp":
        return None, None, "quant"
    if name.startswith("qat_ss_"):
        fmt = F.parse(name[len("qat_ss_"):])
        anchor = F.mxint(8) if fmt.kind == "int" else F.mxfp(8)
        return fmt, anchor, "quant"
    if name.startswith("qat_"):
        return F.parse(name[len("qat_"):]), None, "quant"
    raise ValueError(f"unknown train variant {name!r}")


def variant_trainable(cfg: M.ModelConfig, name: str):
    """Indices (into param_specs order) of the trainable parameter set."""
    _, _, which = parse_variant(name)
    specs = M.param_specs(cfg)
    if which == "all":
        return list(range(len(specs)))
    return [i for i, s in enumerate(specs) if s.quantized]


def all_variants():
    """Every train-step graph exported by aot.py."""
    names = ["pretrain", "ft_fp"]
    names += [f"qat_int{b}" for b in (2, 4, 6, 8)]
    names += [f"qat_fp{b}" for b in (4, 6, 8)]
    # Anchor-SS targets below the anchor (the anchor epoch itself reuses
    # qat_int8 / qat_fp8 — fake-quant is idempotent at the anchor format).
    names += [f"qat_ss_int{b}" for b in (2, 4, 6)]
    names += [f"qat_ss_fp{b}" for b in (4, 6)]
    return names


def make_train_step(cfg: M.ModelConfig, variant: str):
    """Build the flat-signature train step for AOT lowering.

    Signature: ``(lr f32[], step i32[], tokens i32[B,T+1],
    *train, *frozen, *m, *v) -> (loss, *train', *m', *v')``.
    """
    fmt, anchor, _ = parse_variant(variant)
    wq = M.make_weight_quantizer(fmt, anchor, cfg.block_size)
    specs = M.param_specs(cfg)
    t_idx = variant_trainable(cfg, variant)
    t_set = set(t_idx)
    f_idx = [i for i in range(len(specs)) if i not in t_set]

    def loss_fn(train_list, frozen_list, tokens):
        flat = [None] * len(specs)
        for j, i in enumerate(t_idx):
            flat[i] = train_list[j]
        for j, i in enumerate(f_idx):
            flat[i] = frozen_list[j]
        params = M.params_from_flat(cfg, flat)
        return M.nll_loss(params, tokens, cfg, wq=wq)

    n_t = len(t_idx)
    n_f = len(f_idx)

    def step_fn(lr, step, tokens, *rest):
        assert len(rest) == n_t + n_f + 2 * n_t, (len(rest), n_t, n_f)
        train = list(rest[:n_t])
        frozen = list(rest[n_t:n_t + n_f])
        m = list(rest[n_t + n_f:n_t + n_f + n_t])
        v = list(rest[n_t + n_f + n_t:])
        loss, grads = jax.value_and_grad(loss_fn)(train, frozen, tokens)
        stepf = step.astype(jnp.float32)
        new_t, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(train, grads, m, v):
            p2, m2, v2 = adamw_update(p, g, mi, vi, stepf, lr)
            new_t.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple([loss] + new_t + new_m + new_v)

    return step_fn, t_idx, f_idx
