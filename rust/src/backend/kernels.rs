//! Native CPU compute kernels over packed MX tensors.
//!
//! Two generations of packed GEMM live here:
//!
//! * [`gemm_packed`] — the original fused-scale scalar kernel on the
//!   row-major [`MxTensor`] layout (`y += (x_k · 2^{s_{k,j}}) · P_{k,n}`),
//!   kept as the bench baseline and as a second reference implementation
//!   for differential tests. Its per-`k` scale expansion is precomputed
//!   once per call (it used to be re-expanded inside every row tile).
//! * The **block-major pipeline** on [`RepackedMx`] — the serving hot path:
//!   - [`gemm_repacked`]: exact f32 path. Each `(out-block, k-chunk)` tile
//!     of codes is decoded **once per row tile** into an L1-resident f32
//!     scratch with the E8M0 scale folded in (`w = code · 2^s`, both
//!     factors exact), then consumed by plain f32 MACs — the per-row,
//!     per-element scale multiply and i8→f32 convert of the old kernel are
//!     gone. Bit-identical to [`gemm_packed`] (same product rounding, same
//!     summation order).
//!   - [`gemm_repacked_int`]: the integer-MAC path for MXINT formats.
//!     Activations are quantized on the fly to i8, one E8M0 exponent per
//!     MX block along the reduction ([`quantize_acts`]); inside each
//!     `(k-block, out-block)` tile the activation codes are aligned to the
//!     tile's max weight exponent (an exact-or-RNE right shift, see below)
//!     and the dot products run as pure `i8 × code` MACs accumulated in
//!     `i32` — `i16` for ≤4-bit elements, where the narrow code range
//!     doubles the SIMD lane count (this is why MXINT4 outruns MXINT8).
//!     The **combined** activation×weight scale `2^{s_x + s_w^{max}}` is
//!     applied once per tile at the end. MXFP formats fall back to
//!     [`gemm_repacked`] via the element-decode LUT. The per-tile MACs
//!     dispatch to explicit AVX2/NEON kernels when the host supports them
//!     ([`super::simd`]); `MFQAT_SIMD=off` forces the portable loop, which
//!     is bit-identical by construction.
//!
//! Integer-path numerics: weight scale blocks run along the *out* dimension
//! (the paper's layout), so within a reduction chunk the weight exponent
//! `s_w[k]` varies per `k`. The kernel folds that variation into the
//! activation side: `m_k = rne(x_q[k] >> (s_w^{max} − s_w[k]))`, which is
//! exactly an i8 requantization of the scaled activation `x·2^{s_w[k]}` at
//! the tile's coarsest step — so the only approximation anywhere in the
//! path is i8 activation quantization (bounded by ½ ulp at
//! `2^{s_x + s_w^{max}}` per element). When activations are exactly
//! representable and the tile's scales agree, the path is *exact* (integer
//! arithmetic end to end, final multiply by a power of two). Parity against
//! the dequantize-f32 oracle is enforced by unit tests here and end-to-end
//! by `rust/tests/native_backend.rs`.
//!
//! Threading: std scoped threads over contiguous row tiles
//! ([`par_chunks_mut`]); activation rows everywhere in this module are the
//! *flattened token positions* of whatever batch the forward assembled —
//! one sequence, a fixed batch, or a continuously batched mixed-format
//! step — and every kernel treats them independently, which is what makes
//! batched decode bit-identical per sequence. The `MFQAT_THREADS` /
//! `MFQAT_SIMD` environment knobs are documented once, in
//! [`crate::util::cli`] (runtime configuration surface).

use super::repack::RepackedMx;
use super::simd;
use crate::formats::int::shift_round;
use crate::formats::{exp2i, floor_log2, pack, RoundMode};
use crate::tensor::MxTensor;

/// Worker threads for the native kernels (`MFQAT_THREADS` overrides the
/// detected core count; decided once per process).
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("MFQAT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Below this many elements the fan-out cost exceeds the win; run serial.
const PAR_MIN_LEN: usize = 1 << 15;

/// Rows of `y` processed per tile in the GEMM kernels (amortizes the
/// per-tile code decode and scale setup across the tile).
const ROW_TILE: usize = 32;

/// Apply `f(chunk_index, chunk)` to consecutive `chunk`-sized pieces of
/// `data`, fanned out over scoped threads (serial for small inputs). Chunks
/// are disjoint, so the closure may freely mutate its piece.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk);
    let nt = num_threads().min(n_chunks);
    if nt <= 1 || data.len() < PAR_MIN_LEN {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let per = n_chunks.div_ceil(nt);
    std::thread::scope(|s| {
        for (g, group) in data.chunks_mut(per * chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, c) in group.chunks_mut(chunk).enumerate() {
                    f(g * per + i, c);
                }
            });
        }
    });
}

// --------------------------------------------------------------------------
// Activation quantization (the paper is weight-only; this is the serving-
// side extension that unlocks integer MACs).
// --------------------------------------------------------------------------

/// Int8-quantized activations: one code per element, one E8M0 exponent per
/// `(row, k-block)` — the same microscaling structure as the weights, with
/// blocks along the reduction dimension.
pub struct ActPlane {
    /// `[rows, in_f]` i8 codes, clamped to `[-127, 127]` (symmetric range:
    /// keeps `|code × int4-code| × block ≤ i16::MAX` for the narrow path).
    pub codes: Vec<i8>,
    /// `[rows, kblocks]` shared-scale exponents.
    pub exps: Vec<i8>,
    /// Scale blocks along the reduction dimension (`ceil(in_f / bs)`).
    pub kblocks: usize,
}

/// Quantize `[rows, in_f]` activations to i8 codes with one power-of-two
/// scale per `bs`-wide block along `in_f`. The exponent is chosen so the
/// block max lands in `[64, 128)` before rounding (≈7.5 significant bits);
/// values that are already `int · 2^e` with magnitude ≤ 127 round-trip
/// exactly.
///
/// `rows` are flattened token positions, not sequences: a KV-batched (or
/// continuously batched, mixed-format) decode step hands this function the
/// concatenated new positions of *all* its sequence rows, and because each
/// row quantizes independently the result is bit-identical to quantizing
/// each sequence's positions alone — the property the batched-decode
/// exactness tests lean on.
///
/// Edge blocks always yield a *valid* E8M0 scale — one whose `2^e` and
/// `2^{-e}` are both finite f32 — so no downstream `exp2i` can overflow or
/// collapse the inverse scale:
/// * **all-zero blocks** keep exponent 0 and zero codes (exact);
/// * **subnormal-max blocks** clamp to `e = -126`: the ideal exponent
///   (`floor_log2(amax) − 6 < −126`) would need `2^{-e} > 2^{127} = ∞`,
///   turning every code into saturated garbage — at the clamp the values
///   sit below half a quantization step and round to 0 instead (they are
///   unrepresentable at any finite E8M0 step);
/// * **non-finite block maxima** (±∞ anywhere in the block) pin the
///   exponent to the largest finite choice, saturating infinities to ±127
///   without feeding `floor_log2` a value it rejects.
pub fn quantize_acts(x: &[f32], rows: usize, in_f: usize, bs: usize) -> ActPlane {
    assert_eq!(x.len(), rows * in_f);
    let kblocks = in_f.div_ceil(bs).max(1);
    let mut codes = vec![0i8; rows * in_f];
    let mut exps = vec![0i8; rows * kblocks];
    for r in 0..rows {
        let xr = &x[r * in_f..(r + 1) * in_f];
        for (kb, chunk) in xr.chunks(bs).enumerate() {
            // NaN elements quantize to code 0 below and must not poison the
            // shared exponent (`f32::max` ignores a NaN operand).
            let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if amax == 0.0 {
                continue; // all-zero block: exponent 0, codes 0
            }
            let e = if amax.is_finite() {
                (floor_log2(amax) - 6).clamp(-126, 126)
            } else {
                // What a block whose max were f32::MAX would get
                // (floor_log2(MAX) − 6 = 121): infinities saturate to ±127
                // below, finite neighbours scale to ~0.
                121
            };
            exps[r * kblocks + kb] = e as i8;
            let inv = exp2i(-e);
            let out = &mut codes[r * in_f + kb * bs..][..chunk.len()];
            for (o, &v) in out.iter_mut().zip(chunk) {
                *o = (v * inv).round_ties_even().clamp(-127.0, 127.0) as i8;
            }
        }
    }
    ActPlane {
        codes,
        exps,
        kblocks,
    }
}

// --------------------------------------------------------------------------
// Block-major GEMM kernels (the serving hot path).
// --------------------------------------------------------------------------

/// 256-entry element-decode LUT for minifloat formats (`None` for integer
/// formats, whose codes sign-extend to the element value directly). Shared
/// by every GEMM generation so their decode semantics cannot drift apart.
fn fp_decode_lut(elem: crate::formats::ElementFormat) -> Option<Vec<f32>> {
    elem.fp_spec().map(|spec| {
        let mask = ((1u16 << spec.bits()) - 1) as u8;
        (0..256u16).map(|b| spec.decode(b as u8 & mask)).collect()
    })
}

/// Exact-path `y[r, :] = x[r, :] @ W` over the block-major layout: per
/// `(out-block, k-chunk)` tile, decode codes once into an f32 scratch with
/// the block scale folded (`code · 2^s` — two exact factors, one rounding,
/// identical to the fused-scale reference), then run plain f32 MACs
/// amortized over the row tile.
pub fn gemm_repacked(x: &[f32], rows: usize, w: &RepackedMx, y: &mut [f32]) {
    let (in_f, out_f) = (w.in_f, w.out_f);
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 || in_f == 0 || out_f == 0 {
        y.fill(0.0);
        return;
    }
    let bs = w.block_size;
    let lut = fp_decode_lut(w.elem);
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        let mut ct = vec![0i8; bs * bs];
        let mut ctu = vec![0u8; bs * bs];
        let mut wt = vec![0.0f32; bs * bs];
        for jb in 0..w.blocks() {
            let n0 = jb * bs;
            let nl = (out_f - n0).min(bs);
            let sc = w.scale_col(jb);
            let mut k0 = 0usize;
            while k0 < in_f {
                let kl = (in_f - k0).min(bs);
                match &lut {
                    None => {
                        w.decode_tile_signed(jb, k0, kl, &mut ct[..kl * bs]);
                        for k in 0..kl {
                            let s = exp2i(sc[k0 + k] as i32);
                            let (src, dst) = (&ct[k * bs..][..bs], &mut wt[k * bs..][..bs]);
                            for (o, &c) in dst.iter_mut().zip(src) {
                                *o = c as f32 * s;
                            }
                        }
                    }
                    Some(lut) => {
                        w.decode_tile_unsigned(jb, k0, kl, &mut ctu[..kl * bs]);
                        for k in 0..kl {
                            let s = exp2i(sc[k0 + k] as i32);
                            let (src, dst) = (&ctu[k * bs..][..bs], &mut wt[k * bs..][..bs]);
                            for (o, &c) in dst.iter_mut().zip(src) {
                                *o = lut[c as usize] * s;
                            }
                        }
                    }
                }
                for r in 0..rn {
                    let xrow = &x[(r0 + r) * in_f + k0..][..kl];
                    let yr = &mut yc[r * out_f + n0..][..nl];
                    for (k, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &wt[k * bs..][..nl];
                        for (yv, &wv) in yr.iter_mut().zip(wrow) {
                            *yv += xv * wv;
                        }
                    }
                }
                k0 += kl;
            }
        }
    });
}

/// Integer-MAC `y[r, :] = x[r, :] @ W` for MXINT weights: activations are
/// i8-quantized per MX block ([`quantize_acts`]), dot products accumulate
/// code×code in integers, and the combined activation×weight E8M0 scale is
/// applied once per `(k-block, out-block)` tile. `≤4`-bit elements use an
/// `i16` accumulator (provably overflow-free for `block ≤ 32`: `127 · 8 ·
/// 32 = 32512`), doubling the vector width. MXFP weights fall back to the
/// exact f32 path.
///
/// The per-tile rank update dispatches to the explicit SIMD kernels
/// ([`super::simd`]) when available; [`gemm_repacked_int_portable`] pins the
/// scalar loop (bit-identical output — enforced by differential tests).
pub fn gemm_repacked_int(x: &[f32], rows: usize, w: &RepackedMx, y: &mut [f32]) {
    gemm_repacked_int_with(x, rows, w, y, simd::tile_mac_i16, simd::tile_mac_i32)
}

/// Forced-portable integer-MAC GEMM — the PR 2 autovectorized pipeline,
/// kept as the bench baseline and the SIMD differential-test oracle.
pub fn gemm_repacked_int_portable(x: &[f32], rows: usize, w: &RepackedMx, y: &mut [f32]) {
    gemm_repacked_int_with(
        x,
        rows,
        w,
        y,
        simd::tile_mac_i16_portable,
        simd::tile_mac_i32_portable,
    )
}

/// Shared integer-MAC pipeline, parametric in the tile MAC kernels.
fn gemm_repacked_int_with(
    x: &[f32],
    rows: usize,
    w: &RepackedMx,
    y: &mut [f32],
    mac16: fn(&mut [i16], &[i16], &[i16], usize),
    mac32: fn(&mut [i32], &[i32], &[i32], usize),
) {
    if !w.elem.is_int() {
        return gemm_repacked(x, rows, w, y);
    }
    let (in_f, out_f) = (w.in_f, w.out_f);
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 || in_f == 0 || out_f == 0 {
        y.fill(0.0);
        return;
    }
    let bs = w.block_size;
    let acts = quantize_acts(x, rows, in_f, bs);
    let narrow = w.elem.bits() <= 4 && bs <= 32;
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        let mut ct = vec![0i8; bs * bs];
        let mut cw16 = vec![0i16; bs * bs];
        let mut cw32 = vec![0i32; bs * bs];
        let mut m16 = vec![0i16; bs];
        let mut m32 = vec![0i32; bs];
        let mut acc16 = vec![0i16; bs];
        let mut acc32 = vec![0i32; bs];
        for jb in 0..w.blocks() {
            let n0 = jb * bs;
            let nl = (out_f - n0).min(bs);
            let sc = w.scale_col(jb);
            let mut k0 = 0usize;
            while k0 < in_f {
                let kl = (in_f - k0).min(bs);
                w.decode_tile_signed(jb, k0, kl, &mut ct[..kl * bs]);
                if narrow {
                    for (o, &c) in cw16[..kl * bs].iter_mut().zip(&ct[..kl * bs]) {
                        *o = c as i16;
                    }
                } else {
                    for (o, &c) in cw32[..kl * bs].iter_mut().zip(&ct[..kl * bs]) {
                        *o = c as i32;
                    }
                }
                let scc = &sc[k0..k0 + kl];
                let smax = scc.iter().copied().max().unwrap() as i32;
                let kb = k0 / bs;
                for r in 0..rn {
                    let sx = acts.exps[(r0 + r) * acts.kblocks + kb] as i32;
                    let xq = &acts.codes[(r0 + r) * in_f + k0..][..kl];
                    // Align activation codes to the tile's max weight
                    // exponent: m_k = rne(x_q >> (smax - s_k)). |m| ≤ 127.
                    let mut any = false;
                    for k in 0..kl {
                        let d = (smax - scc[k] as i32) as u32;
                        let m = if d >= 8 {
                            0 // |x_q|/2^d < 0.5 — rounds to zero
                        } else {
                            shift_round(xq[k] as i32, d, RoundMode::HalfEven)
                        };
                        any |= m != 0;
                        if narrow {
                            m16[k] = m as i16;
                        } else {
                            m32[k] = m;
                        }
                    }
                    if !any {
                        continue;
                    }
                    let scale = exp2i(sx + smax);
                    let yr = &mut yc[r * out_f + n0..][..nl];
                    // Rank-`kl` update over the decoded tile, dispatched to
                    // the explicit AVX2/NEON kernels (or the bit-identical
                    // portable loop — `MFQAT_SIMD=off`, other ISAs). The
                    // accumulator runs the full padded block width: decode
                    // pads tail columns with zero codes, so lanes ≥ nl stay
                    // zero and only `acc[..nl]` is consumed.
                    if narrow {
                        acc16.fill(0);
                        mac16(&mut acc16, &m16[..kl], &cw16[..kl * bs], bs);
                        for (yv, &a) in yr.iter_mut().zip(&acc16[..nl]) {
                            *yv += a as f32 * scale;
                        }
                    } else {
                        acc32.fill(0);
                        mac32(&mut acc32, &m32[..kl], &cw32[..kl * bs], bs);
                        for (yv, &a) in yr.iter_mut().zip(&acc32[..nl]) {
                            *yv += a as f32 * scale;
                        }
                    }
                }
                k0 += kl;
            }
        }
    });
}

// --------------------------------------------------------------------------
// Reference fused-scale kernel (row-major MxTensor layout).
// --------------------------------------------------------------------------

/// `y[r, :] = x[r, :] @ W` with `W` a packed 2-D [`MxTensor`] — the
/// original fused-scale scalar kernel, kept as the bench baseline and a
/// differential reference for the block-major pipeline. The per-block scale
/// expansion (`exp2i` over the whole scale matrix) is hoisted out of the
/// row-tile loop and computed once per call.
pub fn gemm_packed(x: &[f32], rows: usize, w: &MxTensor, y: &mut [f32]) {
    assert_eq!(w.shape.len(), 2, "packed GEMM wants a 2-D weight");
    let in_f = w.shape[0];
    let out_f = w.shape[1];
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 || in_f == 0 || out_f == 0 {
        if out_f > 0 {
            y.fill(0.0);
        }
        return;
    }
    let bs = w.format.block_size;
    let bpr = out_f.div_ceil(bs);
    let wbits = w.format.elem.bits();
    debug_assert_eq!(w.scales.len(), in_f * bpr);
    let lut = fp_decode_lut(w.format.elem);
    // Scale expansion, once per call (shared read-only across row tiles).
    let scf: Vec<f32> = w.scales.iter().map(|&s| exp2i(s as i32)).collect();
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        let mut int_row = vec![0i8; out_f];
        let mut fp_row = vec![0u8; out_f];
        for k in 0..in_f {
            let sc = &scf[k * bpr..(k + 1) * bpr];
            // Unpack weight row `k` straight out of the packed stream.
            if lut.is_none() {
                pack::unpack_signed_at(&w.packed, wbits, k * out_f, &mut int_row);
            } else {
                pack::unpack_unsigned_at(&w.packed, wbits, k * out_f, &mut fp_row);
            }
            for r in 0..rn {
                let xv = x[(r0 + r) * in_f + k];
                if xv == 0.0 {
                    continue;
                }
                let yr = &mut yc[r * out_f..(r + 1) * out_f];
                match &lut {
                    // MXINT path: y += (x_k · scale_j) · code.
                    None => {
                        for (j, &s) in sc.iter().enumerate() {
                            let f = xv * s;
                            let n0 = j * bs;
                            let n1 = (n0 + bs).min(out_f);
                            for n in n0..n1 {
                                yr[n] += f * int_row[n] as f32;
                            }
                        }
                    }
                    // MXFP path: same shape, element value via the LUT.
                    Some(lut) => {
                        for (j, &s) in sc.iter().enumerate() {
                            let f = xv * s;
                            let n0 = j * bs;
                            let n1 = (n0 + bs).min(out_f);
                            for n in n0..n1 {
                                yr[n] += f * lut[fp_row[n] as usize];
                            }
                        }
                    }
                }
            }
        }
    });
}

/// `y[r, :] = x[r, :] @ W` for a dense f32 weight `[in_features,
/// out_features]` — the reference oracle path (dequantize-then-matmul) and
/// the kernel for unquantized parameters (`head`). Same loop structure and
/// summation order as [`gemm_packed`] so the two paths are comparable to
/// float-rounding error.
pub fn gemm_dense(x: &[f32], rows: usize, w: &[f32], in_f: usize, out_f: usize, y: &mut [f32]) {
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(w.len(), in_f * out_f, "w must be [in_features, out_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 {
        return;
    }
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        for k in 0..in_f {
            let wrow = &w[k * out_f..(k + 1) * out_f];
            for r in 0..rn {
                let xv = x[(r0 + r) * in_f + k];
                if xv == 0.0 {
                    continue;
                }
                let yr = &mut yc[r * out_f..(r + 1) * out_f];
                for (yv, &wv) in yr.iter_mut().zip(wrow) {
                    *yv += xv * wv;
                }
            }
        }
    });
}

/// RMSNorm over the last dimension: `out = x · rsqrt(mean(x²) + 1e-6) · g`
/// (matches `_rmsnorm` in `python/compile/model.py`).
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    let d = gain.len();
    assert!(d > 0 && x.len() % d == 0, "x must be [n, {d}]");
    assert_eq!(x.len(), out.len());
    for (xr, or) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-6).sqrt();
        for ((o, &v), &g) in or.iter_mut().zip(xr).zip(gain) {
            *o = v * r * g;
        }
    }
}

/// Tanh-approximate GELU, in place (jax.nn.gelu `approximate=True`).
pub fn gelu_in_place(x: &mut [f32]) {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    for v in x.iter_mut() {
        let u = *v;
        let inner = SQRT_2_OVER_PI * (u + 0.044_715 * u * u * u);
        *v = 0.5 * u * (1.0 + inner.tanh());
    }
}

/// `acc += delta`, element-wise (residual connections).
pub fn add_assign(acc: &mut [f32], delta: &[f32]) {
    assert_eq!(acc.len(), delta.len());
    for (a, &b) in acc.iter_mut().zip(delta) {
        *a += b;
    }
}

/// Multi-head causal self-attention.
///
/// `qkv` is the fused projection output `[rows·t, 3·d_model]` (row `b·t + i`
/// holds `[q | k | v]` for sequence `b`, position `i`); `out` is
/// `[rows·t, d_model]`. Softmax is computed per (sequence, head, query) over
/// the causal prefix — numerically identical to the python reference's
/// masked full-softmax (masked scores underflow to exactly 0 probability).
pub fn causal_attention(
    qkv: &[f32],
    rows: usize,
    t: usize,
    n_heads: usize,
    d_model: usize,
    out: &mut [f32],
) {
    assert!(n_heads > 0 && d_model % n_heads == 0);
    assert_eq!(qkv.len(), rows * t * 3 * d_model);
    assert_eq!(out.len(), rows * t * d_model);
    let hd = d_model / n_heads;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    par_chunks_mut(out, t * d_model, |b, ob| {
        ob.fill(0.0);
        let base = b * t * 3 * d_model;
        let mut probs = vec![0.0f32; t];
        for h in 0..n_heads {
            let qo = h * hd;
            let ko = d_model + h * hd;
            let vo = 2 * d_model + h * hd;
            for i in 0..t {
                let q = &qkv[base + i * 3 * d_model + qo..][..hd];
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=i {
                    let krow = &qkv[base + j * 3 * d_model + ko..][..hd];
                    let mut s = 0.0f32;
                    for (&a, &k) in q.iter().zip(krow) {
                        s += a * k;
                    }
                    let s = s * inv_sqrt;
                    probs[j] = s;
                    if s > max_s {
                        max_s = s;
                    }
                }
                let mut denom = 0.0f32;
                for p in probs[..=i].iter_mut() {
                    *p = (*p - max_s).exp();
                    denom += *p;
                }
                let inv_denom = 1.0 / denom;
                let orow = &mut ob[i * d_model + qo..i * d_model + qo + hd];
                for j in 0..=i {
                    let wgt = probs[j] * inv_denom;
                    let vrow = &qkv[base + j * 3 * d_model + vo..][..hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += wgt * vv;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{ElementFormat, MxFormat};
    use crate::util::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    fn naive_matmul(x: &[f32], rows: usize, w: &[f32], in_f: usize, out_f: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; rows * out_f];
        for r in 0..rows {
            for n in 0..out_f {
                let mut acc = 0.0f64;
                for k in 0..in_f {
                    acc += x[r * in_f + k] as f64 * w[k * out_f + n] as f64;
                }
                y[r * out_f + n] = acc as f32;
            }
        }
        y
    }

    fn all_test_formats() -> Vec<ElementFormat> {
        vec![
            ElementFormat::int(2),
            ElementFormat::int(4),
            ElementFormat::int(6),
            ElementFormat::int(8),
            ElementFormat::fp_from_bits(4),
            ElementFormat::fp_from_bits(6),
            ElementFormat::fp_from_bits(8),
        ]
    }

    #[test]
    fn dense_gemm_matches_naive() {
        let (rows, in_f, out_f) = (5, 48, 33);
        let x = randvec(rows * in_f, 1);
        let w = randvec(in_f * out_f, 2);
        let mut y = vec![0.0f32; rows * out_f];
        gemm_dense(&x, rows, &w, in_f, out_f, &mut y);
        let want = naive_matmul(&x, rows, &w, in_f, out_f);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_gemm_matches_dequantized_dense() {
        // The fused-scale packed path must equal dequantize-then-f32-matmul
        // (the ref.py mx_matmul_ref oracle) to float rounding error.
        for fmt in all_test_formats() {
            let (rows, in_f, out_f) = (7, 64, 96);
            let x = randvec(rows * in_f, 3);
            let wdata = randvec(in_f * out_f, 4);
            let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::new(fmt, 32)).unwrap();
            let wd = w.dequantize();
            let mut y_packed = vec![0.0f32; rows * out_f];
            let mut y_dense = vec![0.0f32; rows * out_f];
            gemm_packed(&x, rows, &w, &mut y_packed);
            gemm_dense(&x, rows, &wd, in_f, out_f, &mut y_dense);
            for (i, (a, b)) in y_packed.iter().zip(&y_dense).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{}[{i}]: packed {a} vs dense {b}",
                    fmt.long_name()
                );
            }
        }
    }

    #[test]
    fn repacked_gemm_is_bit_identical_to_reference_kernel() {
        // The block-major f32 path re-orders storage, not math: same
        // product rounding, same per-output summation order as the
        // fused-scale reference — the outputs must agree exactly.
        for fmt in all_test_formats() {
            let (rows, in_f, out_f) = (ROW_TILE + 5, 48, 72); // ragged everywhere
            let x = randvec(rows * in_f, 5);
            let wdata = randvec(in_f * out_f, 6);
            let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::new(fmt, 32)).unwrap();
            let r = RepackedMx::from_mx(&w);
            let mut y_ref = vec![0.0f32; rows * out_f];
            let mut y_new = vec![0.0f32; rows * out_f];
            gemm_packed(&x, rows, &w, &mut y_ref);
            gemm_repacked(&x, rows, &r, &mut y_new);
            assert_eq!(y_ref, y_new, "{}", fmt.long_name());
        }
    }

    #[test]
    fn packed_gemm_handles_ragged_blocks_and_row_tiles() {
        // out_f not a multiple of the block size; rows beyond one ROW_TILE.
        let (rows, in_f, out_f) = (ROW_TILE + 3, 32, 40);
        let x = randvec(rows * in_f, 5);
        let wdata = randvec(in_f * out_f, 6);
        let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::mxint(5, 32)).unwrap();
        let wd = w.dequantize();
        let mut y_packed = vec![0.0f32; rows * out_f];
        gemm_packed(&x, rows, &w, &mut y_packed);
        let want = naive_matmul(&x, rows, &wd, in_f, out_f);
        for (a, b) in y_packed.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        let r = RepackedMx::from_mx(&w);
        let mut y_r = vec![0.0f32; rows * out_f];
        gemm_repacked(&x, rows, &r, &mut y_r);
        assert_eq!(y_packed, y_r);
    }

    #[test]
    fn quantize_acts_exact_for_representable_values() {
        // Values that are already int·2^e with |int| ≤ 127 round-trip
        // exactly through the activation quantizer.
        let bs = 32;
        let x: Vec<f32> = (0..64).map(|i| (i as i32 - 31) as f32 * 0.5).collect();
        let a = quantize_acts(&x, 1, 64, bs);
        for (i, &v) in x.iter().enumerate() {
            let kb = i / bs;
            let got = a.codes[i] as f32 * exp2i(a.exps[kb] as i32);
            assert_eq!(got, v, "i={i}");
        }
    }

    #[test]
    fn int_mac_exact_when_scales_align() {
        // When activations are exactly i8·2^e representable and every
        // weight block in a reduction tile shares one scale exponent, the
        // integer path has no rounding anywhere: it must equal the f64
        // reference exactly.
        for bits in [2u8, 4, 6, 8] {
            let (rows, in_f, out_f) = (4usize, 64usize, 64usize);
            // Integer activations in [-100, 100].
            let x: Vec<f32> = (0..rows * in_f)
                .map(|i| ((i * 37 + 11) % 201) as f32 - 100.0)
                .collect();
            // Weight data with the same max magnitude in every block so all
            // scale exponents agree.
            let hi = (1i32 << (bits - 1)) - 1;
            let wdata: Vec<f32> = (0..in_f * out_f)
                .map(|i| {
                    let v = (i as i32 * 29 + 3) % (2 * hi + 1) - hi;
                    if i % 8 == 0 {
                        hi as f32 // every 8-run carries the max
                    } else {
                        v as f32
                    }
                })
                .collect();
            let w =
                MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::mxint(bits, 32)).unwrap();
            let r = RepackedMx::from_mx(&w);
            let wd = w.dequantize();
            assert_eq!(wd, wdata, "bits={bits}: weights must be exact");
            let want = naive_matmul(&x, rows, &wd, in_f, out_f);
            let mut y = vec![0.0f32; rows * out_f];
            gemm_repacked_int(&x, rows, &r, &mut y);
            assert_eq!(y, want, "bits={bits}");
        }
    }

    #[test]
    fn int_mac_tracks_f32_oracle_within_activation_error() {
        // With random data the only approximation is i8 activation
        // quantization (~2^-7.5 relative per element); against the
        // f32-activation dequantize oracle the error must stay at that
        // scale: small relative RMS, no outliers beyond a few ulp of the
        // activation step.
        for fmt in [ElementFormat::int(4), ElementFormat::int(8)] {
            let (rows, in_f, out_f) = (9usize, 128usize, 96usize);
            let x = randvec(rows * in_f, 7);
            let wdata = randvec(in_f * out_f, 8);
            let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::new(fmt, 32)).unwrap();
            let r = RepackedMx::from_mx(&w);
            let wd = w.dequantize();
            let mut y_int = vec![0.0f32; rows * out_f];
            let mut y_ora = vec![0.0f32; rows * out_f];
            gemm_repacked_int(&x, rows, &r, &mut y_int);
            gemm_dense(&x, rows, &wd, in_f, out_f, &mut y_ora);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            let mut max_abs = 0.0f64;
            for (a, b) in y_int.iter().zip(&y_ora) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
                max_abs = max_abs.max(((a - b) as f64).abs());
            }
            let rel_rms = (num / den.max(1e-30)).sqrt();
            // i8 activation quantization is ~2^-7.5 relative per element,
            // plus up to one alignment bit where block scales differ.
            assert!(rel_rms < 2.5e-2, "{}: rel rms {rel_rms}", fmt.long_name());
            // Deterministic bound: Σ_k |Δx_k|·|w_kn| with |Δx| ≤ ulp/2 at
            // the block scale; bound loosely by the row norms.
            let ymax = y_ora.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
            assert!(
                max_abs < 0.05 * ymax.max(1.0),
                "{}: max abs err {max_abs} vs ymax {ymax}",
                fmt.long_name()
            );
        }
    }

    #[test]
    fn prop_int_mac_simd_matches_portable_bit_exact() {
        // The dispatched integer-MAC GEMM (AVX2/NEON on capable hosts,
        // scalar elsewhere or under MFQAT_SIMD=off) must be bit-identical
        // to the forced-portable pipeline on random repacked planes: the
        // SIMD kernels reassociate wrapping integer MACs only, so every
        // f32 output — and the i16/i32 accumulators behind it — agrees
        // exactly for every MXINT width, block size and ragged shape.
        use crate::util::props::{run_cases, Gen};
        run_cases("gemm_repacked_int simd == portable", 16, |g: &mut Gen| {
            let rows = g.len(1, 9);
            let in_f = g.len(1, 80);
            let out_f = g.len(1, 90);
            let bs = [8usize, 16, 32][g.rng.range(0, 3)];
            let x: Vec<f32> = (0..rows * in_f).map(|_| g.rng.normal()).collect();
            let wdata: Vec<f32> = (0..in_f * out_f).map(|_| g.rng.normal()).collect();
            for bits in [2u8, 4, 6, 8] {
                let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::mxint(bits, bs))
                    .map_err(|e| e.to_string())?;
                let r = RepackedMx::from_mx(&w);
                let mut y_simd = vec![0.0f32; rows * out_f];
                let mut y_port = vec![0.0f32; rows * out_f];
                gemm_repacked_int(&x, rows, &r, &mut y_simd);
                gemm_repacked_int_portable(&x, rows, &r, &mut y_port);
                if y_simd != y_port {
                    return Err(format!(
                        "int{bits} {rows}x{in_f}x{out_f}@{bs}: simd != portable"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_acts_edge_blocks_yield_valid_scales() {
        // Every block — all-zero, subnormal-max, wild-magnitude — must
        // produce an E8M0 exponent whose scale AND inverse scale are
        // finite, codes in [-127, 127], and in-range finite values must
        // reconstruct within half a quantization step.
        use crate::util::props::{run_cases, Gen};
        run_cases("quantize_acts edge planes", 24, |g: &mut Gen| {
            let rows = g.len(2, 6);
            let bs = [8usize, 16, 32][g.rng.range(0, 3)];
            let in_f = g.len(1, 3 * bs + 5);
            let mut x = g.f32_vec_wild(rows * in_f);
            // Row 0: all zeros. Row 1: subnormal-max blocks.
            for v in x[..in_f].iter_mut() {
                *v = 0.0;
            }
            for (i, v) in x[in_f..2 * in_f].iter_mut().enumerate() {
                *v = f32::from_bits(1 + (i as u32 % 1000)) * if i % 2 == 0 { 1.0 } else { -1.0 };
            }
            let a = quantize_acts(&x, rows, in_f, bs);
            let kblocks = in_f.div_ceil(bs).max(1);
            if a.kblocks != kblocks {
                return Err("kblocks mismatch".into());
            }
            for r in 0..rows {
                for kb in 0..kblocks {
                    let e = a.exps[r * kblocks + kb] as i32;
                    let (s, inv) = (exp2i(e), exp2i(-e));
                    if !(s.is_finite() && s > 0.0 && inv.is_finite() && inv > 0.0) {
                        return Err(format!("row {r} block {kb}: invalid scale 2^{e}"));
                    }
                }
                for (i, &v) in x[r * in_f..(r + 1) * in_f].iter().enumerate() {
                    let code = a.codes[r * in_f + i];
                    if !(-127..=127).contains(&code) {
                        return Err(format!("row {r} col {i}: code {code} out of range"));
                    }
                    let step = exp2i(a.exps[r * kblocks + i / bs] as i32);
                    let got = code as f32 * step;
                    if !got.is_finite() {
                        return Err(format!("row {r} col {i}: non-finite reconstruction"));
                    }
                    // In-range finite values: |err| ≤ step/2 (RNE), with a
                    // hair of slack for the subnormal-product rounding.
                    if v.is_finite() && v.abs() <= 127.0 * step {
                        let tol = 0.5 * step + step * 1e-6 + f32::MIN_POSITIVE;
                        if (got - v).abs() > tol {
                            return Err(format!(
                                "row {r} col {i}: {v} -> {got} (step {step})"
                            ));
                        }
                    }
                }
            }
            // Row 0 must be exactly zero codes with exponent 0.
            if a.codes[..in_f].iter().any(|&c| c != 0) || a.exps[..kblocks].iter().any(|&e| e != 0)
            {
                return Err("all-zero row must quantize to zero codes, exponent 0".into());
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_acts_subnormal_and_nonfinite_blocks() {
        // Deterministic spot checks of the edge-block contract.
        let bs = 32;
        // Subnormal-max block: exponent clamps to -126, codes round to 0
        // (the values sit below half the smallest representable step).
        let tiny = vec![f32::from_bits(1); bs]; // 2^-149
        let a = quantize_acts(&tiny, 1, bs, bs);
        assert_eq!(a.exps[0], -126);
        assert!(a.codes.iter().all(|&c| c == 0), "below half a step: rounds to 0");
        // An infinity saturates its own code and leaves neighbours sane.
        let mut x = vec![1.0f32; bs];
        x[3] = f32::INFINITY;
        x[7] = f32::NEG_INFINITY;
        let a = quantize_acts(&x, 1, bs, bs);
        assert_eq!(a.codes[3], 127);
        assert_eq!(a.codes[7], -127);
        let inv = exp2i(-(a.exps[0] as i32));
        assert!(inv.is_finite() && inv > 0.0);
        // NaN elements quantize to 0 without poisoning the block exponent.
        let mut x = vec![2.0f32; bs];
        x[5] = f32::NAN;
        let a = quantize_acts(&x, 1, bs, bs);
        assert_eq!(a.codes[5], 0);
        let step = exp2i(a.exps[0] as i32);
        assert_eq!(a.codes[0] as f32 * step, 2.0, "finite neighbours exact");
    }

    #[test]
    fn int_mac_zero_and_empty_inputs() {
        let w = MxTensor::quantize(&vec![0.5f32; 32 * 40], &[32, 40], MxFormat::mxint(4, 32))
            .unwrap();
        let r = RepackedMx::from_mx(&w);
        let mut y = vec![1.0f32; 2 * 40];
        gemm_repacked_int(&vec![0.0f32; 2 * 32], 2, &r, &mut y);
        assert!(y.iter().all(|&v| v == 0.0), "zero x ⇒ zero y");
    }

    #[test]
    fn rmsnorm_scales_to_unit_rms() {
        let d = 16;
        let x = randvec(3 * d, 7);
        let gain = vec![1.0f32; d];
        let mut out = vec![0.0f32; x.len()];
        rmsnorm(&x, &gain, &mut out);
        for row in out.chunks_exact(d) {
            let rms = (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
            assert!((rms - 1.0).abs() < 1e-2, "rms={rms}");
        }
    }

    #[test]
    fn gelu_known_values() {
        let mut x = vec![0.0f32, 10.0, -10.0, 1.0];
        gelu_in_place(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 10.0).abs() < 1e-4);
        assert!(x[2].abs() < 1e-4);
        assert!((x[3] - 0.8412).abs() < 1e-3); // gelu(1) ≈ 0.8412
    }

    #[test]
    fn attention_with_one_position_returns_v() {
        // t = 1: softmax over a single score is 1, so out == v.
        let (rows, t, heads, d) = (2, 1, 2, 8);
        let qkv = randvec(rows * t * 3 * d, 8);
        let mut out = vec![0.0f32; rows * t * d];
        causal_attention(&qkv, rows, t, heads, d, &mut out);
        for b in 0..rows {
            let v = &qkv[b * 3 * d + 2 * d..][..d];
            let o = &out[b * d..][..d];
            for (a, e) in o.iter().zip(v) {
                assert!((a - e).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attention_is_causal() {
        // The output at position i must not change when future positions do.
        let (rows, t, heads, d) = (1, 6, 2, 8);
        let qkv = randvec(rows * t * 3 * d, 9);
        let mut full = vec![0.0f32; t * d];
        causal_attention(&qkv, rows, t, heads, d, &mut full);
        let t2 = 4;
        let mut prefix = vec![0.0f32; t2 * d];
        causal_attention(&qkv[..t2 * 3 * d], rows, t2, heads, d, &mut prefix);
        for i in 0..t2 * d {
            assert_eq!(full[i], prefix[i], "position {} differs", i / d);
        }
    }

    #[test]
    fn par_chunks_mut_visits_every_chunk_once() {
        let mut data = vec![0u32; 100_000];
        par_chunks_mut(&mut data, 7, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        for (pos, &v) in data.iter().enumerate() {
            assert_eq!(v, (pos / 7) as u32 + 1, "pos {pos}");
        }
    }
}
