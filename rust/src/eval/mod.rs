//! Evaluation harness: perplexity and 0-shot multiple-choice scoring.
//!
//! Mirrors the paper's protocol (§3.1/§3.2): WikiText-style validation
//! perplexity via the AOT `nll_b8` graph, and lm-eval-harness-style 0-shot
//! accuracy — each choice is appended to the prompt, scored by
//! length-normalized continuation log-likelihood over the `forward_b8`
//! logits, and the argmax choice is compared to the answer.
//!
//! The PJRT execution paths are gated behind the `pjrt` feature; the native
//! equivalents ([`mean_nll_native`], [`perplexity_native`]) run everywhere
//! through `backend::forward` and need no AOT artifacts.

pub mod generate;

#[cfg(feature = "pjrt")]
use crate::data::{self, Task, PAD};
#[cfg(feature = "pjrt")]
use crate::model::ParamSet;
#[cfg(feature = "pjrt")]
use crate::runtime::{self, ArtifactSet, Runtime};
#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::Result;

/// Pre-built parameter literals (reused across many eval calls).
#[cfg(feature = "pjrt")]
pub struct ParamLiterals {
    /// Per-parameter XLA literals in manifest order.
    pub literals: Vec<xla::Literal>,
}

#[cfg(feature = "pjrt")]
impl ParamLiterals {
    /// Build literals from a parameter set.
    pub fn build(params: &ParamSet) -> Result<ParamLiterals> {
        let literals = params
            .tensors
            .iter()
            .map(runtime::tensor_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamLiterals { literals })
    }
}

/// Per-row mean next-token NLL from flat logits `[rows, width-1, vocab]`
/// against the shift-by-one targets of `tokens` (`rows` windows of `width`
/// tokens each). Shared by the native and PJRT backends so both score with
/// the identical definition.
pub fn nll_from_logits(
    logits: &[f32],
    tokens: &[i32],
    rows: usize,
    width: usize,
    vocab: usize,
) -> Result<Vec<f32>> {
    anyhow::ensure!(width >= 2, "windows need at least 2 tokens, got {width}");
    let t = width - 1;
    anyhow::ensure!(
        tokens.len() == rows * width,
        "expected {rows}x{width} tokens, got {}",
        tokens.len()
    );
    anyhow::ensure!(
        logits.len() == rows * t * vocab,
        "expected {rows}x{t}x{vocab} logits, got {}",
        logits.len()
    );
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let mut nll = 0.0f64;
        for pos in 0..t {
            let target = tokens[r * width + pos + 1];
            anyhow::ensure!(
                target >= 0 && (target as usize) < vocab,
                "target token {target} out of vocab range 0..{vocab}"
            );
            let off = (r * t + pos) * vocab;
            nll -= log_softmax_pick(&logits[off..off + vocab], target as usize);
        }
        out.push((nll / t as f64) as f32);
    }
    Ok(out)
}

/// Mean next-token NLL over token windows, scored by the native backend
/// (no artifacts). Windows must fill whole batches of `rows_per_batch`.
pub fn mean_nll_native(
    weights: &crate::backend::NativeWeights,
    rows: &[Vec<i32>],
    rows_per_batch: usize,
) -> Result<f64> {
    if rows.is_empty() || rows.len() % rows_per_batch != 0 {
        anyhow::bail!(
            "mean_nll_native wants a multiple of {rows_per_batch} rows, got {}",
            rows.len()
        );
    }
    let width = rows[0].len();
    let mut total = 0.0f64;
    for chunk in rows.chunks(rows_per_batch) {
        let mut flat = Vec::with_capacity(rows_per_batch * width);
        for row in chunk {
            anyhow::ensure!(row.len() == width, "ragged row in eval set");
            flat.extend_from_slice(row);
        }
        let nll = crate::backend::forward::score_rows(weights, &flat, rows_per_batch)?;
        total += nll.iter().map(|&v| v as f64).sum::<f64>() / rows_per_batch as f64;
    }
    Ok(total / (rows.len() / rows_per_batch) as f64)
}

/// Perplexity via the native backend: `exp(mean NLL)`.
pub fn perplexity_native(
    weights: &crate::backend::NativeWeights,
    rows: &[Vec<i32>],
    rows_per_batch: usize,
) -> Result<f64> {
    Ok(mean_nll_native(weights, rows, rows_per_batch)?.exp())
}

/// Mean next-token NLL over token windows (width `seq_len + 1`).
///
/// Windows must fill whole batches (`rows.len() % train_batch == 0`) so the
/// metric is exact — the corpus splits are sized accordingly.
#[cfg(feature = "pjrt")]
pub fn mean_nll(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    rows: &[Vec<i32>],
) -> Result<f64> {
    let b = arts.manifest.train_batch;
    let width = arts.manifest.seq_len + 1;
    if rows.is_empty() || rows.len() % b != 0 {
        bail!("mean_nll wants a multiple of {b} rows, got {}", rows.len());
    }
    let exe = arts.executable(rt, "nll_b8")?;
    let mut total = 0.0f64;
    let batches = data::batches(rows, b, width);
    for flat in &batches {
        let tokens = runtime::i32_literal(flat, &[b, width])?;
        let mut args: Vec<&xla::Literal> = vec![&tokens];
        args.extend(params.literals.iter());
        let out = exe.run(&args)?;
        total += runtime::literal_f32(&out[0])? as f64;
    }
    Ok(total / batches.len() as f64)
}

#[cfg(feature = "pjrt")]
/// Perplexity = exp(mean NLL).
pub fn perplexity(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    rows: &[Vec<i32>],
) -> Result<f64> {
    Ok(mean_nll(rt, arts, params, rows)?.exp())
}

#[cfg(feature = "pjrt")]
/// Score a task: returns accuracy in [0, 1].
pub fn mc_accuracy(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    task: &Task,
) -> Result<f64> {
    let scores = mc_choice_scores(rt, arts, params, task)?;
    let mut correct = 0usize;
    for (item, s) in task.items.iter().zip(&scores) {
        let best = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == item.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.items.len() as f64)
}

#[cfg(feature = "pjrt")]
/// Length-normalized continuation log-likelihood per (item, choice).
pub fn mc_choice_scores(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    task: &Task,
) -> Result<Vec<Vec<f64>>> {
    let b = arts.manifest.train_batch;
    let t_len = arts.manifest.seq_len;
    let vocab = arts.manifest.vocab;
    let exe = arts.executable(rt, "forward_b8")?;

    // Flatten all (item, choice) pairs into padded rows.
    struct Pair {
        item: usize,
        choice: usize,
        row: Vec<i32>,
        /// Continuation token positions: logits at p-1 predict token p.
        start: usize,
        end: usize,
    }
    let mut pairs = Vec::new();
    for (ii, item) in task.items.iter().enumerate() {
        let prompt = data::encode(&item.prompt);
        for (ci, choice) in item.choices.iter().enumerate() {
            let cont = data::encode(choice);
            let mut row = prompt.clone();
            row.extend_from_slice(&cont);
            let (start, end) = if row.len() > t_len {
                // Truncate from the left, keeping the continuation.
                let drop = row.len() - t_len;
                row.drain(..drop);
                let s = prompt.len().saturating_sub(drop).max(1);
                (s, row.len())
            } else {
                (prompt.len(), row.len())
            };
            row.resize(t_len, PAD as i32);
            pairs.push(Pair {
                item: ii,
                choice: ci,
                row,
                start,
                end,
            });
        }
    }

    let mut scores: Vec<Vec<f64>> = task
        .items
        .iter()
        .map(|i| vec![f64::NEG_INFINITY; i.choices.len()])
        .collect();

    for chunk in pairs.chunks(b) {
        let mut flat = Vec::with_capacity(b * t_len);
        for j in 0..b {
            let p = chunk.get(j).unwrap_or(&chunk[0]); // pad batch by repeat
            flat.extend_from_slice(&p.row);
        }
        let tokens = runtime::i32_literal(&flat, &[b, t_len])?;
        let mut args: Vec<&xla::Literal> = vec![&tokens];
        args.extend(params.literals.iter());
        let out = exe.run(&args)?;
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        debug_assert_eq!(logits.len(), b * t_len * vocab);
        for (j, p) in chunk.iter().enumerate() {
            let mut lp = 0.0f64;
            let n = (p.end - p.start).max(1);
            for pos in p.start..p.end {
                let target = p.row[pos] as usize;
                let off = (j * t_len + (pos - 1)) * vocab;
                lp += log_softmax_pick(&logits[off..off + vocab], target);
            }
            scores[p.item][p.choice] = lp / n as f64;
        }
    }
    Ok(scores)
}

/// log softmax(logits)[target], computed stably in f64.
pub fn log_softmax_pick(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let denom: f64 = logits.iter().map(|&x| ((x as f64) - max).exp()).sum();
    (logits[target] as f64 - max) - denom.ln()
}

#[cfg(feature = "pjrt")]
/// Average accuracy over a suite of tasks (the paper's Tables 1/2 metric).
pub fn suite_accuracy(
    rt: &Runtime,
    arts: &ArtifactSet,
    params: &ParamLiterals,
    tasks: &[Task],
) -> Result<Vec<(String, f64)>> {
    tasks
        .iter()
        .map(|t| Ok((t.name.clone(), mc_accuracy(rt, arts, params, t)?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_is_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0, -1.0];
        let total: f64 = (0..4).map(|i| log_softmax_pick(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
        // Highest logit has highest logprob.
        assert!(log_softmax_pick(&logits, 2) > log_softmax_pick(&logits, 0));
    }

    #[test]
    fn log_softmax_stable_for_large_logits() {
        let logits = vec![1000.0f32, 999.0];
        let lp = log_softmax_pick(&logits, 0);
        assert!(lp < 0.0 && lp > -1.0);
    }

    #[test]
    fn mean_nll_native_scores_without_artifacts() {
        use crate::backend::NativeWeights;
        use crate::formats::ElementFormat;
        use crate::model::{ModelDims, ParamSet};
        let mut dims = ModelDims::new("evalnat", 64, 32, 1, 2, 8);
        dims.train_batch = 2;
        let m = dims.to_manifest();
        let ck = ParamSet::init(&m, 1)
            .to_anchor_checkpoint(&m, ElementFormat::int(8))
            .unwrap();
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
        let rows: Vec<Vec<i32>> = (0..4)
            .map(|r| (0..9).map(|i| ((r * 9 + i) % 64) as i32).collect())
            .collect();
        let nll = mean_nll_native(&w, &rows, 2).unwrap();
        assert!(nll.is_finite() && nll > 0.0);
        // Random init stays near the uniform baseline ln(vocab).
        assert!((nll - (64f64).ln()).abs() < 2.0, "nll={nll}");
        assert!(
            mean_nll_native(&w, &rows[..3], 2).is_err(),
            "non-multiple of batch is rejected"
        );
    }
}
