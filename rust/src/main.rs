//! `mfqat` — CLI for the MF-QAT elastic-inference stack.
//!
//! Subcommands:
//!   info                         inspect artifacts + manifest
//!   pretrain                     train the base LM (substrate)
//!   train --plan <name>          run a QAT/FT plan from the pretrained base
//!   eval --checkpoint <p>        PPL + task grid for a checkpoint
//!   convert --in <p> --format f  Slice-and-Scale convert a checkpoint
//!   inspect --checkpoint <p>     dump checkpoint contents
//!   serve                        run the elastic server demo workload
//!   experiment <id>              regenerate a paper figure/table (or `all`)
//!
//! Global options: --config tiny|small|base (default tiny), --root <dir>,
//! --seed N, --lrs a,b,c

use anyhow::{anyhow, Context, Result};
use mfqat::checkpoint::Checkpoint;
use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::experiments::{self, Ctx};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::ArtifactSet;
use mfqat::server::{Policy, Server, ServerConfig};
use mfqat::util::cli::Args;
use std::path::PathBuf;


fn main() {
    mfqat::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn repo_root(args: &Args) -> PathBuf {
    args.get("root")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")))
}

fn open_ctx(args: &Args) -> Result<Ctx> {
    let config = args.get_or("config", "tiny").to_string();
    let seed = args.u64("seed", 20260710)?;
    let mut ctx = Ctx::open(&repo_root(args), &config, seed)?;
    if let Some(lrs) = args.list("lrs") {
        ctx.lrs = lrs
            .iter()
            .map(|s| s.parse::<f32>().map_err(|_| anyhow!("bad lr '{s}'")))
            .collect::<Result<_>>()?;
    }
    ctx.pretrain_epochs = args.usize("pretrain-epochs", ctx.pretrain_epochs)?;
    ctx.task_items = args.usize("task-items", ctx.task_items)?;
    Ok(ctx)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "pretrain" => {
            let ctx = open_ctx(&args)?;
            let p = ctx.ensure_pretrained()?;
            println!("pretrained: {} params, val ppl {:.3}", p.n_params(), ctx.val_ppl(&p)?);
            Ok(())
        }
        "train" => train(&args),
        "eval" => eval_cmd(&args),
        "generate" => generate_cmd(&args),
        "convert" => convert(&args),
        "inspect" => inspect(&args),
        "serve" => serve(&args),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: mfqat experiment <fig1|fig2|fig3|fig4|tab1|tab2|tab3|fig19|fig20|all>"))?;
            let ctx = open_ctx(&args)?;
            experiments::run(&ctx, id)
        }
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "mfqat — Multi-Format QAT for Elastic Inference (paper reproduction)

USAGE: mfqat <command> [--config tiny] [--root DIR] [options]

COMMANDS:
  info                              show artifact manifest
  pretrain [--pretrain-epochs N]    train the base LM on the synthetic corpus
  train --plan <name> [--lr X]      run a training plan (mf_int, qat_int4, ...)
  eval --checkpoint P [--formats..] PPL grid for a checkpoint
  generate --checkpoint P --prompt S [--format F] [--tokens N] [--temp X]
                                    sample a continuation (elastic precision)
  convert --in P --format F --out Q Slice-and-Scale convert an anchor checkpoint
  inspect --checkpoint P            dump checkpoint metadata
  serve [--policy ladder] [--requests N] [--burst N]
                                    run the elastic serving demo workload
  experiment <id>                   regenerate a paper figure/table; id in
                                    fig1 fig2 fig3 fig4 tab1 tab2 tab3 fig19 fig20 all
";

fn info(args: &Args) -> Result<()> {
    let root = repo_root(args);
    let config = args.get_or("config", "tiny");
    let arts = ArtifactSet::open(&root.join("artifacts").join(config))?;
    let m = &arts.manifest;
    println!(
        "config {}: d_model={} layers={} heads={} seq={} vocab={} block={}",
        m.config_name, m.d_model, m.n_layers, m.n_heads, m.seq_len, m.vocab, m.block_size
    );
    println!(
        "params: {} tensors, {} total ({} quantized tensors)",
        m.params.len(),
        m.n_params,
        m.quant_indices().len()
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!("  {name:<20} {}", a.file);
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let plan = args
        .get("plan")
        .ok_or_else(|| anyhow!("--plan required (e.g. mf_int, qat_int4, ft_fp_int)"))?;
    let params = if let Some(lr) = args.get("lr") {
        ctx.ensure_variant(plan, lr.parse().context("--lr")?)?
    } else {
        ctx.ensure_variant_best(plan)?
    };
    println!("trained {plan}: val ppl {:.3}", ctx.val_ppl(&params)?);
    // Also emit the anchor checkpoints for serving.
    for (anchor, name) in [
        (ElementFormat::int(8), "int8"),
        (ElementFormat::fp_from_bits(8), "fp8"),
    ] {
        let ck = params.to_anchor_checkpoint(&ctx.arts.manifest, anchor)?;
        let path = ctx.runs_dir.join(format!("anchor_{plan}_{name}.mfq"));
        ck.save(&path)?;
        println!(
            "anchor checkpoint ({}): {} ({} KB)",
            anchor,
            path.display(),
            ck.storage_bytes() / 1024
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    let params = ParamSet::from_checkpoint(&ctx.arts.manifest, &ck, None)?;
    let fmts: Vec<ElementFormat> = match args.list("formats") {
        Some(list) => list
            .iter()
            .map(|s| ElementFormat::parse(s))
            .collect::<Result<_>>()?,
        None => ElementFormat::all_int(),
    };
    println!("{:<14} {:>10}", "format", "val_ppl");
    println!("{:<14} {:>10.3}", "fp32", ctx.val_ppl(&params)?);
    for fmt in fmts {
        let q = params.ptq(&ctx.arts.manifest, fmt)?;
        println!("{:<14} {:>10.3}", fmt.long_name(), ctx.val_ppl(&q)?);
    }
    Ok(())
}

fn generate_cmd(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let prompt = args.get_or("prompt", "the color of kova is");
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    let fmt = args
        .get("format")
        .map(ElementFormat::parse)
        .transpose()?;
    let params = ParamSet::from_checkpoint(&ctx.arts.manifest, &ck, fmt)?;
    let lits = mfqat::eval::ParamLiterals::build(&params)?;
    let cfg = mfqat::eval::generate::SampleCfg {
        temperature: args.f64("temp", 0.8)? as f32,
        top_k: args.usize("top-k", 8)?,
        seed: args.u64("seed", 0)?,
    };
    let n = args.usize("tokens", 64)?;
    let out = mfqat::eval::generate::generate(&ctx.rt, &ctx.arts, &lits, prompt, n, &cfg)?;
    println!("{prompt}│{out}");
    Ok(())
}

fn convert(args: &Args) -> Result<()> {
    let input = args.get("in").ok_or_else(|| anyhow!("--in required"))?;
    let output = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let fmt = ElementFormat::parse(
        args.get("format")
            .ok_or_else(|| anyhow!("--format required"))?,
    )?;
    let ck = Checkpoint::load(&PathBuf::from(input))?;
    let mut out = Checkpoint::new();
    out.meta = ck.meta.clone();
    out.set_meta("anchor", mfqat::util::json::Json::from(fmt.name()));
    out.raw = ck.raw.clone();
    let t = std::time::Instant::now();
    let mut converted = 0usize;
    for (name, tensor) in &ck.tensors {
        let q = if tensor.format.elem == fmt {
            tensor.clone()
        } else {
            tensor.slice_and_scale(fmt).with_context(|| name.clone())?
        };
        converted += q.len();
        out.insert(name, q);
    }
    out.save(&PathBuf::from(output))?;
    println!(
        "slice-and-scale {} -> {}: {} elements in {:.1} ms ({} KB -> {} KB)",
        input,
        output,
        converted,
        t.elapsed().as_secs_f64() * 1e3,
        ck.storage_bytes() / 1024,
        out.storage_bytes() / 1024,
    );
    Ok(())
}

fn inspect(args: &Args) -> Result<()> {
    let ck_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let ck = Checkpoint::load(&PathBuf::from(ck_path))?;
    println!("meta:");
    for (k, v) in &ck.meta {
        println!("  {k} = {}", v.to_string());
    }
    println!("mx tensors ({}):", ck.tensors.len());
    for (name, t) in &ck.tensors {
        println!(
            "  {name:<14} {:?} {} ({} bytes packed)",
            t.shape,
            t.format,
            t.storage_bytes()
        );
    }
    println!("raw tensors ({}):", ck.raw.len());
    for (name, t) in &ck.raw {
        println!("  {name:<14} {:?} f32 ({} bytes)", t.shape, t.len() * 4);
    }
    println!("total storage: {} KB", ck.storage_bytes() / 1024);
    Ok(())
}

/// Serving demo: fire a bursty synthetic workload at the elastic server and
/// report the precision mix + latency profile.
fn serve(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    let policy = Policy::parse(args.get_or("policy", "ladder"))?;
    let n_requests = args.usize("requests", 256)?;
    let burst = args.usize("burst", 32)?;

    // Need an anchor checkpoint: build one from the pretrained base if the
    // user didn't provide one.
    let ck_path = match args.get("checkpoint") {
        Some(p) => PathBuf::from(p),
        None => {
            let path = ctx.runs_dir.join("anchor_serve_int8.mfq");
            if !path.exists() {
                let base = ctx.ensure_pretrained()?;
                std::fs::create_dir_all(&ctx.runs_dir)?;
                base.to_anchor_checkpoint(&ctx.arts.manifest, ElementFormat::int(8))?
                    .save(&path)?;
            }
            path
        }
    };
    let config = args.get_or("config", "tiny").to_string();
    let arts_dir = repo_root(args).join("artifacts").join(&config);
    let width = ctx.arts.manifest.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || ElasticEngine::open(&arts_dir, &ck_path, 256 << 20),
        ServerConfig {
            policy,
            gather_window: std::time::Duration::from_millis(2),
        },
    )?;

    let corpus = Corpus::generate(CorpusConfig {
        seed: 42,
        width: ctx.arts.manifest.seq_len + 1,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: n_requests.div_ceil(64).max(1) * 64,
    });
    println!("firing {n_requests} requests in bursts of {burst}…");
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let mut sent = 0usize;
    while sent < n_requests {
        for _ in 0..burst.min(n_requests - sent) {
            let row = &corpus.val[sent % corpus.val.len()];
            pending.push(client.submit(row, None)?);
            sent += 1;
        }
        // Drain this burst.
        for rx in pending.drain(..) {
            let resp = rx
                .recv()
                .map_err(|_| anyhow!("server dropped request"))?
                .map_err(|e| anyhow!(e))?;
            log::debug!(
                "nll {:.3} fmt {} batch {} depth {}",
                resp.nll,
                resp.format,
                resp.batch_size,
                resp.queue_depth
            );
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let metrics = server.metrics.lock().unwrap().clone();
    println!(
        "done: {} requests in {:.2}s ({:.1} req/s)",
        metrics.requests,
        elapsed,
        metrics.requests as f64 / elapsed
    );
    println!("  {}", metrics.summary());
    println!("  format conversions performed: {}", metrics.conversions);
    drop(client);
    server.shutdown();
    Ok(())
}
