//! Native decoder forward pass over packed MX weights.
//!
//! Mirrors the python reference model (`python/compile/model.py::forward`):
//! token + learned positional embeddings → `n_layers` × (RMSNorm → causal
//! attention → RMSNorm → GELU MLP, both with residuals) → final RMSNorm →
//! LM head. Decoder-stack linears (`qkv`/`proj`/`up`/`down`) are served
//! straight from their packed microscaling form ([`Mat::Packed`] →
//! [`super::kernels::gemm_packed`]); embeddings, norms and the head stay f32
//! exactly as the paper leaves them unquantized.
//!
//! [`Mat::Dense`] is the dequantize-then-f32-matmul oracle — the same
//! forward over materialized f32 weights — used by parity tests and as the
//! `fp32` reference row in native evaluation.

use super::kernels;
use crate::checkpoint::Checkpoint;
use crate::formats::{ElementFormat, MxFormat};
use crate::model::ModelDims;
use crate::tensor::MxTensor;
use anyhow::{anyhow, bail, Result};

/// A weight matrix as the native kernels consume it.
#[derive(Debug, Clone)]
pub enum Mat {
    /// Packed microscaling weights (codes + per-block scales, never
    /// expanded to f32).
    Packed(MxTensor),
    /// Dense f32 `[in_features, out_features]` (oracle path / unquantized
    /// parameters).
    Dense {
        data: Vec<f32>,
        in_f: usize,
        out_f: usize,
    },
}

impl Mat {
    pub fn in_features(&self) -> usize {
        match self {
            Mat::Packed(t) => t.shape[0],
            Mat::Dense { in_f, .. } => *in_f,
        }
    }

    pub fn out_features(&self) -> usize {
        match self {
            Mat::Packed(t) => t.shape[1],
            Mat::Dense { out_f, .. } => *out_f,
        }
    }

    /// Resident bytes (packed codes + scales, or f32 payload).
    pub fn storage_bytes(&self) -> usize {
        match self {
            Mat::Packed(t) => t.storage_bytes(),
            Mat::Dense { data, .. } => data.len() * 4,
        }
    }

    /// `y[r, :] = x[r, :] @ W`.
    pub fn gemm(&self, x: &[f32], rows: usize, y: &mut [f32]) {
        match self {
            Mat::Packed(t) => kernels::gemm_packed(x, rows, t, y),
            Mat::Dense { data, in_f, out_f } => {
                kernels::gemm_dense(x, rows, data, *in_f, *out_f, y)
            }
        }
    }
}

/// One decoder layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub qkv: Mat,
    pub proj: Mat,
    pub ln2: Vec<f32>,
    pub up: Mat,
    pub down: Mat,
}

/// A full serving weight set for one element format.
///
/// Note: the unquantized f32 parameters (`emb`/`pos`/norms/`head`) are
/// owned per weight set, so each cached format currently duplicates them;
/// `Arc`-sharing them across `FormatCache` entries is a known follow-up
/// (see ROADMAP open items).
#[derive(Debug, Clone)]
pub struct NativeWeights {
    pub dims: ModelDims,
    /// Element format of the quantized linears (`None` = dense f32 oracle).
    pub fmt: Option<ElementFormat>,
    pub emb: Vec<f32>,
    pub pos: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub lnf: Vec<f32>,
    pub head: Mat,
}

/// Convert a stored MX tensor to the target element format: Slice-and-Scale
/// when the target is a lower-precision member of the same family (the
/// paper's runtime conversion, §3.5), otherwise requantize from the
/// dequantized anchor values (cross-family or up-precision targets).
/// Applicability is decided up front so genuine SS failures propagate
/// instead of silently switching numerics path.
fn derive_packed(src: &MxTensor, target: ElementFormat) -> Result<MxTensor> {
    if src.format.elem == target {
        return Ok(src.clone());
    }
    let ss_applicable = match (src.format.elem, target) {
        (ElementFormat::Int { bits: bh }, ElementFormat::Int { bits: bl }) => bl <= bh,
        (ElementFormat::Fp { .. }, ElementFormat::Fp { .. }) => {
            let sh = src.format.elem.fp_spec().unwrap();
            let sl = target.fp_spec().unwrap();
            sl.emax() < sh.emax() || (sl.emax() == sh.emax() && sl.m <= sh.m)
        }
        _ => false,
    };
    if ss_applicable {
        src.slice_and_scale(target)
    } else {
        log::debug!(
            "{} -> {} is outside Slice-and-Scale support; requantizing from dequantized values",
            src.format.elem,
            target
        );
        MxTensor::quantize(
            &src.dequantize(),
            &src.shape,
            MxFormat::new(target, src.format.block_size),
        )
    }
}

/// Fetch a raw f32 parameter of exactly `want` elements.
fn fetch_raw(ck: &Checkpoint, name: &str, want: &[usize]) -> Result<Vec<f32>> {
    let t = ck
        .get_raw(name)
        .ok_or_else(|| anyhow!("checkpoint missing raw parameter '{name}'"))?;
    if t.shape != want {
        bail!("'{name}': checkpoint shape {:?} != expected {:?}", t.shape, want);
    }
    Ok(t.data.clone())
}

/// Fetch a quantized linear as a packed tensor at `target` precision.
/// Stored-MX entries ride Slice-and-Scale; raw f32 entries are PTQ'd
/// directly (master checkpoints).
fn fetch_packed(
    ck: &Checkpoint,
    name: &str,
    want: &[usize],
    target: ElementFormat,
    block_size: usize,
) -> Result<MxTensor> {
    if let Some(q) = ck.get(name) {
        if q.shape != want {
            bail!("'{name}': checkpoint shape {:?} != expected {:?}", q.shape, want);
        }
        return derive_packed(q, target);
    }
    if let Some(t) = ck.get_raw(name) {
        if t.shape != want {
            bail!("'{name}': checkpoint shape {:?} != expected {:?}", t.shape, want);
        }
        return MxTensor::quantize(&t.data, &t.shape, MxFormat::new(target, block_size));
    }
    bail!("checkpoint missing quantized parameter '{name}'")
}

/// Fetch a quantized linear as dense f32 at `target` precision (`None` ⇒
/// dequantize whatever is stored / keep raw f32 as-is). This is the
/// dequantize-then-matmul oracle path.
fn fetch_dense(
    ck: &Checkpoint,
    name: &str,
    want: &[usize],
    target: Option<ElementFormat>,
    block_size: usize,
) -> Result<Vec<f32>> {
    match target {
        Some(fmt) => Ok(fetch_packed(ck, name, want, fmt, block_size)?.dequantize()),
        None => {
            if let Some(q) = ck.get(name) {
                if q.shape != want {
                    bail!("'{name}': checkpoint shape {:?} != expected {:?}", q.shape, want);
                }
                Ok(q.dequantize())
            } else {
                fetch_raw(ck, name, want)
            }
        }
    }
}

impl NativeWeights {
    /// Build the packed serving weight set at `target` precision.
    pub fn packed_from_checkpoint(
        dims: &ModelDims,
        ck: &Checkpoint,
        target: ElementFormat,
    ) -> Result<NativeWeights> {
        Self::build(dims, ck, Some(target), true)
    }

    /// Build the dense-f32 oracle weight set (`target = None` dequantizes
    /// whatever precision the checkpoint stores).
    pub fn dense_from_checkpoint(
        dims: &ModelDims,
        ck: &Checkpoint,
        target: Option<ElementFormat>,
    ) -> Result<NativeWeights> {
        Self::build(dims, ck, target, false)
    }

    fn build(
        dims: &ModelDims,
        ck: &Checkpoint,
        target: Option<ElementFormat>,
        packed: bool,
    ) -> Result<NativeWeights> {
        let d = dims.d_model;
        let bs = dims.block_size;
        let mat = |name: &str, in_f: usize, out_f: usize| -> Result<Mat> {
            let want = [in_f, out_f];
            if packed {
                let fmt = target.expect("packed build requires a target format");
                Ok(Mat::Packed(fetch_packed(ck, name, &want, fmt, bs)?))
            } else {
                Ok(Mat::Dense {
                    data: fetch_dense(ck, name, &want, target, bs)?,
                    in_f,
                    out_f,
                })
            }
        };
        let mut layers = Vec::with_capacity(dims.n_layers);
        for i in 0..dims.n_layers {
            layers.push(LayerWeights {
                ln1: fetch_raw(ck, &format!("l{i}.ln1"), &[d])?,
                qkv: mat(&format!("l{i}.qkv"), d, 3 * d)?,
                proj: mat(&format!("l{i}.proj"), d, d)?,
                ln2: fetch_raw(ck, &format!("l{i}.ln2"), &[d])?,
                up: mat(&format!("l{i}.up"), d, dims.d_ff)?,
                down: mat(&format!("l{i}.down"), dims.d_ff, d)?,
            });
        }
        Ok(NativeWeights {
            dims: dims.clone(),
            fmt: if packed { target } else { None },
            emb: fetch_raw(ck, "emb", &[dims.vocab, d])?,
            pos: fetch_raw(ck, "pos", &[dims.seq_len, d])?,
            layers,
            lnf: fetch_raw(ck, "lnf", &[d])?,
            head: Mat::Dense {
                data: fetch_raw(ck, "head", &[d, dims.vocab])?,
                in_f: d,
                out_f: dims.vocab,
            },
        })
    }

    /// Resident bytes of this weight set (cache accounting).
    pub fn storage_bytes(&self) -> usize {
        let mut total = (self.emb.len() + self.pos.len() + self.lnf.len()) * 4;
        total += self.head.storage_bytes();
        for l in &self.layers {
            total += (l.ln1.len() + l.ln2.len()) * 4;
            total += l.qkv.storage_bytes()
                + l.proj.storage_bytes()
                + l.up.storage_bytes()
                + l.down.storage_bytes();
        }
        total
    }
}

/// Full forward pass: `tokens` is `rows` sequences of `tokens.len() / rows`
/// positions each; returns flat logits `[rows, t, vocab]`.
pub fn forward_logits(w: &NativeWeights, tokens: &[i32], rows: usize) -> Result<Vec<f32>> {
    let dims = &w.dims;
    if rows == 0 || tokens.len() % rows != 0 {
        bail!("tokens ({}) must split into {rows} equal rows", tokens.len());
    }
    let t = tokens.len() / rows;
    if t == 0 || t > dims.seq_len {
        bail!("sequence length {t} out of range 1..={}", dims.seq_len);
    }
    let d = dims.d_model;
    let n = rows * t;

    // Token + positional embeddings.
    let mut x = vec![0.0f32; n * d];
    for (i, &tok) in tokens.iter().enumerate() {
        if tok < 0 || tok as usize >= dims.vocab {
            bail!("token {tok} out of vocab range 0..{}", dims.vocab);
        }
        let er = &w.emb[tok as usize * d..(tok as usize + 1) * d];
        let pr = &w.pos[(i % t) * d..(i % t + 1) * d];
        let xr = &mut x[i * d..(i + 1) * d];
        for j in 0..d {
            xr[j] = er[j] + pr[j];
        }
    }

    let mut xn = vec![0.0f32; n * d];
    let mut qkv = vec![0.0f32; n * 3 * d];
    let mut att = vec![0.0f32; n * d];
    let mut delta = vec![0.0f32; n * d];
    let mut hidden = vec![0.0f32; n * dims.d_ff];
    for layer in &w.layers {
        kernels::rmsnorm(&x, &layer.ln1, &mut xn);
        layer.qkv.gemm(&xn, n, &mut qkv);
        kernels::causal_attention(&qkv, rows, t, dims.n_heads, d, &mut att);
        layer.proj.gemm(&att, n, &mut delta);
        kernels::add_assign(&mut x, &delta);
        kernels::rmsnorm(&x, &layer.ln2, &mut xn);
        layer.up.gemm(&xn, n, &mut hidden);
        kernels::gelu_in_place(&mut hidden);
        layer.down.gemm(&hidden, n, &mut delta);
        kernels::add_assign(&mut x, &delta);
    }
    kernels::rmsnorm(&x, &w.lnf, &mut xn);
    let mut logits = vec![0.0f32; n * dims.vocab];
    w.head.gemm(&xn, n, &mut logits);
    Ok(logits)
}

/// Per-row mean next-token NLL for `rows` token windows of width
/// `tokens.len() / rows` (inputs are positions `..width-1`, targets the
/// shift by one) — the native equivalent of the AOT `nll_b8` graph.
pub fn score_rows(w: &NativeWeights, tokens: &[i32], rows: usize) -> Result<Vec<f32>> {
    if rows == 0 || tokens.len() % rows != 0 {
        bail!("tokens ({}) must split into {rows} equal rows", tokens.len());
    }
    let width = tokens.len() / rows;
    if width < 2 {
        bail!("scoring wants windows of at least 2 tokens, got {width}");
    }
    let t = width - 1;
    let mut inputs = Vec::with_capacity(rows * t);
    for r in 0..rows {
        inputs.extend_from_slice(&tokens[r * width..r * width + t]);
    }
    let logits = forward_logits(w, &inputs, rows)?;
    crate::eval::nll_from_logits(&logits, tokens, rows, width, w.dims.vocab)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamSet;

    fn tiny_dims() -> ModelDims {
        let mut d = ModelDims::new("unit", 64, 32, 2, 2, 16);
        d.train_batch = 2;
        d
    }

    fn anchor_ck(dims: &ModelDims, seed: u64, anchor: ElementFormat) -> Checkpoint {
        let m = dims.to_manifest();
        let p = ParamSet::init(&m, seed);
        p.to_anchor_checkpoint(&m, anchor).unwrap()
    }

    #[test]
    fn packed_forward_matches_dense_oracle() {
        let dims = tiny_dims();
        let ck = anchor_ck(&dims, 1, ElementFormat::int(8));
        let tokens: Vec<i32> = (0..2 * 8).map(|i| (i * 7 % 64) as i32).collect();
        for fmt in [ElementFormat::int(8), ElementFormat::int(4)] {
            let packed = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
            let dense = NativeWeights::dense_from_checkpoint(&dims, &ck, Some(fmt)).unwrap();
            let lp = forward_logits(&packed, &tokens, 2).unwrap();
            let ld = forward_logits(&dense, &tokens, 2).unwrap();
            assert_eq!(lp.len(), 2 * 8 * 64);
            for (a, b) in lp.iter().zip(&ld) {
                assert!((a - b).abs() < 1e-4, "{fmt}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn score_rows_is_finite_and_positive() {
        let dims = tiny_dims();
        let ck = anchor_ck(&dims, 2, ElementFormat::int(8));
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(6)).unwrap();
        let tokens: Vec<i32> = (0..2 * 17).map(|i| (i * 11 % 64) as i32).collect();
        let nll = score_rows(&w, &tokens, 2).unwrap();
        assert_eq!(nll.len(), 2);
        for v in nll {
            assert!(v.is_finite() && v > 0.0, "nll={v}");
        }
    }

    #[test]
    fn rejects_bad_tokens_and_shapes() {
        let dims = tiny_dims();
        let ck = anchor_ck(&dims, 3, ElementFormat::int(8));
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
        assert!(forward_logits(&w, &[0, 1, 2], 2).is_err(), "ragged rows");
        assert!(forward_logits(&w, &[999, 0], 2).is_err(), "oov token");
        let too_long: Vec<i32> = vec![0; 2 * (dims.seq_len + 1)];
        assert!(forward_logits(&w, &too_long, 2).is_err(), "over seq_len");
    }

    #[test]
    fn cross_family_target_requantizes() {
        // int8 anchor served at fp4: SS cannot cross families, so the
        // builder requantizes from dequantized anchor values.
        let dims = tiny_dims();
        let ck = anchor_ck(&dims, 4, ElementFormat::int(8));
        let w =
            NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::fp_from_bits(4))
                .unwrap();
        let tokens: Vec<i32> = (0..2 * 9).map(|i| (i % 64) as i32).collect();
        let nll = score_rows(&w, &tokens, 2).unwrap();
        assert!(nll.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn storage_bytes_shrink_with_bits() {
        let dims = tiny_dims();
        let ck = anchor_ck(&dims, 5, ElementFormat::int(8));
        let w8 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
        let w4 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(4)).unwrap();
        let dense = NativeWeights::dense_from_checkpoint(&dims, &ck, None).unwrap();
        assert!(w4.storage_bytes() < w8.storage_bytes());
        assert!(w8.storage_bytes() < dense.storage_bytes());
    }
}
