//! Deterministic fault injection for the serving runtime.
//!
//! A [`FaultPlan`] arms a set of one-shot faults, each pinned to a worker
//! and a decode-step number, so tests and smoke runs can reproduce the
//! exact failure interleavings the supervisor must survive:
//!
//! * [`FaultKind::Panic`] — the worker thread panics mid-loop, exercising
//!   `catch_unwind` supervision, fail-fast of its in-flight rows, KV-pool
//!   reclamation and respawn;
//! * [`FaultKind::Stall`] — the worker sleeps before a step, exercising
//!   deadline expiry and cancellation while a decode is wedged;
//! * [`FaultKind::ShrinkPages`] — the worker's KV page budget shrinks
//!   mid-run, exercising memory-aware admission under a collapsing pool.
//!
//! Plans come from the `MFQAT_FAULT` environment variable (picked up by
//! [`crate::server::ServerConfig`]'s `Default`) or are built
//! programmatically in tests. The grammar is `;`-separated specs:
//!
//! ```text
//! panic:worker=0,step=12;stall:worker=1,step=3,ms=50;shrink:worker=0,step=5,pages=4
//! ```
//!
//! Workers poll the plan once per loop iteration with their cumulative
//! step count; each spec fires **at most once** (an atomic flag), at the
//! first poll whose step reaches its trigger. The poll is two relaxed
//! atomic loads per armed spec and servers without a plan pay one `Option`
//! check, so the hook is safe to leave compiled into release builds.

use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What an armed fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker thread (the supervisor must fail its in-flight
    /// rows, reclaim its KV pages and respawn it).
    Panic,
    /// Sleep the worker for the given duration before its next step.
    Stall(Duration),
    /// Shrink the worker's KV page budget by the given number of pages
    /// (never below what live rows are guaranteed).
    ShrinkPages(usize),
}

/// One armed fault: fires on `worker` at the first poll whose cumulative
/// step count reaches `step`, then never again.
#[derive(Debug)]
pub struct FaultSpec {
    /// Worker index the fault targets.
    pub worker: usize,
    /// Cumulative loop-iteration count that triggers the fault (the
    /// worker's counter starts at 1 on its first iteration).
    pub step: u64,
    /// What happens when it fires.
    pub kind: FaultKind,
    fired: AtomicBool,
}

/// A set of armed one-shot faults, shared read-only by every worker.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Plan from the `MFQAT_FAULT` environment variable; `None` when unset
    /// or empty. A malformed value aborts loudly (a silently ignored fault
    /// plan would make a CI fault leg vacuous) — panicking here is fine,
    /// the server has not started yet.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let raw = std::env::var("MFQAT_FAULT").ok()?;
        if raw.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&raw) {
            Ok(plan) => Some(Arc::new(plan)),
            Err(e) => panic!("bad MFQAT_FAULT '{raw}': {e:#}"),
        }
    }

    /// Parse the `;`-separated spec grammar (see the module docs).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut specs = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind_name, rest) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fault spec '{part}' wants '<kind>:<params>'"))?;
            let mut worker = None;
            let mut step = None;
            let mut ms = None;
            let mut pages = None;
            for kv in rest.split(',') {
                let (k, v) = kv
                    .trim()
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("fault param '{kv}' wants 'key=value'"))?;
                let n: u64 = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault param '{kv}' wants an integer value"))?;
                match k.trim() {
                    "worker" => worker = Some(n as usize),
                    "step" => step = Some(n),
                    "ms" => ms = Some(n),
                    "pages" => pages = Some(n as usize),
                    other => anyhow::bail!("unknown fault param '{other}' in '{part}'"),
                }
            }
            let worker = worker.ok_or_else(|| anyhow::anyhow!("'{part}' wants worker=<n>"))?;
            let step = step.ok_or_else(|| anyhow::anyhow!("'{part}' wants step=<n>"))?;
            let kind = match kind_name.trim() {
                "panic" => FaultKind::Panic,
                "stall" => FaultKind::Stall(Duration::from_millis(
                    ms.ok_or_else(|| anyhow::anyhow!("'{part}' wants ms=<n>"))?,
                )),
                "shrink" => FaultKind::ShrinkPages(
                    pages.ok_or_else(|| anyhow::anyhow!("'{part}' wants pages=<n>"))?,
                ),
                other => anyhow::bail!("unknown fault kind '{other}' (panic|stall|shrink)"),
            };
            specs.push(FaultSpec { worker, step, kind, fired: AtomicBool::new(false) });
        }
        if specs.is_empty() {
            anyhow::bail!("fault plan is empty");
        }
        Ok(FaultPlan { specs })
    }

    /// Plan with a single armed fault (test builder).
    pub fn single(worker: usize, step: u64, kind: FaultKind) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            specs: vec![FaultSpec { worker, step, kind, fired: AtomicBool::new(false) }],
        })
    }

    /// Armed specs (inspection/tests).
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Called by worker `worker` with its cumulative loop-iteration count;
    /// returns the kind of the first matching unfired spec (marking it
    /// fired), or `None`. `>=` rather than `==` so a spec armed for a step
    /// the counter skips (e.g. the worker respawned) still fires once.
    pub fn poll(&self, worker: usize, step: u64) -> Option<FaultKind> {
        for spec in &self.specs {
            if spec.worker == worker
                && step >= spec.step
                && !spec.fired.swap(true, Ordering::AcqRel)
            {
                return Some(spec.kind);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let spec = "panic:worker=0,step=12;stall:worker=1,step=3,ms=50;\
                    shrink:worker=0,step=5,pages=4";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.specs().len(), 3);
        assert_eq!(plan.specs()[0].kind, FaultKind::Panic);
        assert_eq!(plan.specs()[1].kind, FaultKind::Stall(Duration::from_millis(50)));
        assert_eq!(plan.specs()[2].kind, FaultKind::ShrinkPages(4));
        assert_eq!(plan.specs()[1].worker, 1);
        assert_eq!(plan.specs()[2].step, 5);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic:worker=0").is_err(), "missing step");
        assert!(FaultPlan::parse("stall:worker=0,step=1").is_err(), "missing ms");
        assert!(FaultPlan::parse("shrink:worker=0,step=1").is_err(), "missing pages");
        assert!(FaultPlan::parse("explode:worker=0,step=1").is_err());
        assert!(FaultPlan::parse("panic:worker=a,step=1").is_err());
    }

    #[test]
    fn faults_fire_once_at_or_after_their_step() {
        let plan = FaultPlan::single(0, 5, FaultKind::Panic);
        assert_eq!(plan.poll(1, 10), None, "wrong worker");
        assert_eq!(plan.poll(0, 4), None, "too early");
        assert_eq!(plan.poll(0, 7), Some(FaultKind::Panic), "fires late too");
        assert_eq!(plan.poll(0, 8), None, "one-shot");
    }
}
