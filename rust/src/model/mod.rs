//! Host-side model parameter management.
//!
//! The model *computation* lives in the AOT HLO artifacts; this module owns
//! the parameter values: deterministic initialization from the manifest's
//! spec table, PTQ (direct or via the anchor + Slice-and-Scale), and the
//! anchor-checkpoint round trip of paper §3.5.

use crate::checkpoint::Checkpoint;
use crate::formats::{ElementFormat, MxFormat};
use crate::runtime::Manifest;
use crate::tensor::{MxTensor, Tensor};
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::{bail, Result};

/// An ordered set of parameter tensors (order = manifest = HLO args).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    /// Parameter tensors in manifest order.
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// Deterministic initialization from the manifest spec table.
    ///
    /// `normal` params get N(0, 0.02²); `ones`/`zeros` as named. This is the
    /// same family the python reference uses; exact equality with python is
    /// not required (training runs from rust-owned init).
    pub fn init(manifest: &Manifest, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let tensors = manifest
            .params
            .iter()
            .map(|p| match p.init.as_str() {
                "ones" => Tensor::full(&p.shape, 1.0),
                "zeros" => Tensor::zeros(&p.shape),
                _ => Tensor::randn(&p.shape, 0.02, &mut rng),
            })
            .collect();
        ParamSet { tensors }
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Look up a parameter tensor by name.
    pub fn get(&self, manifest: &Manifest, name: &str) -> Option<&Tensor> {
        manifest.param_index(name).map(|i| &self.tensors[i])
    }

    /// Apply post-training quantization to the quantized-parameter set
    /// (direct FP32 → target, paper's PTQ evaluation protocol).
    pub fn ptq(&self, manifest: &Manifest, target: ElementFormat) -> Result<ParamSet> {
        self.ptq_block(manifest, target, manifest.block_size)
    }

    /// PTQ with an explicit scaling block size (Figs. 2/3 block sweeps).
    pub fn ptq_block(
        &self,
        manifest: &Manifest,
        target: ElementFormat,
        block_size: usize,
    ) -> Result<ParamSet> {
        let fmt = MxFormat::new(target, block_size);
        let mut out = self.clone();
        for i in manifest.quant_indices() {
            let t = &self.tensors[i];
            let q = MxTensor::quantize(&t.data, &t.shape, fmt)?;
            out.tensors[i] = Tensor::new(&t.shape, q.dequantize())?;
        }
        Ok(out)
    }

    /// PTQ via the anchor path: FP32 → anchor → Slice-and-Scale → target
    /// (the elastic-inference runtime conversion, §3.5).
    pub fn ptq_via_anchor(
        &self,
        manifest: &Manifest,
        anchor: ElementFormat,
        target: ElementFormat,
    ) -> Result<ParamSet> {
        self.ptq_via_anchor_block(manifest, anchor, target, manifest.block_size)
    }

    /// Anchor-path PTQ with an explicit scaling block size.
    pub fn ptq_via_anchor_block(
        &self,
        manifest: &Manifest,
        anchor: ElementFormat,
        target: ElementFormat,
        block_size: usize,
    ) -> Result<ParamSet> {
        let afmt = MxFormat::new(anchor, block_size);
        let mut out = self.clone();
        for i in manifest.quant_indices() {
            let t = &self.tensors[i];
            let a = MxTensor::quantize(&t.data, &t.shape, afmt)?;
            let q = if target == anchor {
                a
            } else {
                a.slice_and_scale(target)?
            };
            out.tensors[i] = Tensor::new(&t.shape, q.dequantize())?;
        }
        Ok(out)
    }

    /// Store as an anchor checkpoint: quantized params in the anchor MX
    /// format, everything else raw f32.
    pub fn to_anchor_checkpoint(
        &self,
        manifest: &Manifest,
        anchor: ElementFormat,
    ) -> Result<Checkpoint> {
        if self.tensors.len() != manifest.params.len() {
            bail!("param count mismatch");
        }
        let afmt = MxFormat::new(anchor, manifest.block_size);
        let mut ck = Checkpoint::new();
        ck.set_meta("config", Json::from(manifest.config_name.as_str()));
        ck.set_meta("anchor", Json::from(anchor.name()));
        ck.set_meta("block_size", Json::from(manifest.block_size));
        for (info, t) in manifest.params.iter().zip(&self.tensors) {
            if info.quantized {
                ck.insert(&info.name, MxTensor::quantize(&t.data, &t.shape, afmt)?);
            } else {
                ck.insert_raw(&info.name, t.clone());
            }
        }
        Ok(ck)
    }

    /// Store all params raw (FP32 master checkpoint — training state).
    pub fn to_master_checkpoint(&self, manifest: &Manifest) -> Result<Checkpoint> {
        if self.tensors.len() != manifest.params.len() {
            bail!("param count mismatch");
        }
        let mut ck = Checkpoint::new();
        ck.set_meta("config", Json::from(manifest.config_name.as_str()));
        ck.set_meta("kind", Json::from("master_fp32"));
        for (info, t) in manifest.params.iter().zip(&self.tensors) {
            ck.insert_raw(&info.name, t.clone());
        }
        Ok(ck)
    }

    /// Load from a checkpoint, converting quantized entries to ``target``
    /// via Slice-and-Scale when needed (None ⇒ dequantize the stored format
    /// as-is; raw entries load unchanged).
    pub fn from_checkpoint(
        manifest: &Manifest,
        ck: &Checkpoint,
        target: Option<ElementFormat>,
    ) -> Result<ParamSet> {
        let mut tensors = Vec::with_capacity(manifest.params.len());
        for info in &manifest.params {
            if let Some(t) = ck.get_raw(&info.name) {
                if t.shape != info.shape {
                    bail!("'{}': checkpoint shape {:?} != manifest {:?}", info.name, t.shape, info.shape);
                }
                tensors.push(t.clone());
            } else if let Some(q) = ck.get(&info.name) {
                if q.shape != info.shape {
                    bail!("'{}': checkpoint shape {:?} != manifest {:?}", info.name, q.shape, info.shape);
                }
                let q2;
                let qref = match target {
                    Some(t) if t != q.format.elem => {
                        q2 = q.slice_and_scale(t)?;
                        &q2
                    }
                    _ => q,
                };
                tensors.push(Tensor::new(&info.shape, qref.dequantize())?);
            } else {
                bail!("checkpoint missing parameter '{}'", info.name);
            }
        }
        Ok(ParamSet { tensors })
    }

    /// Sub-list by indices (trainable split for the train step).
    pub fn select(&self, idx: &[usize]) -> Vec<&Tensor> {
        idx.iter().map(|&i| &self.tensors[i]).collect()
    }

    /// Overwrite the tensors at `idx` with `new` (train-step outputs).
    pub fn scatter(&mut self, idx: &[usize], new: Vec<Tensor>) -> Result<()> {
        if idx.len() != new.len() {
            bail!("scatter: {} indices vs {} tensors", idx.len(), new.len());
        }
        for (&i, t) in idx.iter().zip(new) {
            if self.tensors[i].shape != t.shape {
                bail!("scatter: shape mismatch at {i}");
            }
            self.tensors[i] = t;
        }
        Ok(())
    }
}

/// Anchor format for a format family (paper: MXINT8 / MXFP8).
pub fn anchor_for(target: ElementFormat) -> ElementFormat {
    match target {
        ElementFormat::Int { .. } => ElementFormat::int(8),
        ElementFormat::Fp { .. } => ElementFormat::fp_from_bits(8),
    }
}

/// Model dimensions — everything a backend needs to run a forward pass and
/// to lay out the parameter table. Mirrors `python/compile/model.py`
/// (`ModelConfig` + `param_specs`), so the native backend can serve a
/// checkpoint with *no* AOT artifacts on disk: the built-in config table
/// ([`ModelDims::by_name`]) or an artifact manifest
/// ([`ModelDims::from_manifest`]) both produce the same spec table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelDims {
    /// Config name (`tiny`, `small`, `base`, ...).
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Context window in tokens.
    pub seq_len: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// MX scaling block size.
    pub block_size: usize,
    /// Serving/AOT batch size (rows per scoring batch).
    pub train_batch: usize,
}

impl ModelDims {
    /// Dims with the python defaults (`ff_mult = 4`, block 32, batch 8).
    pub fn new(
        name: &str,
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        seq_len: usize,
    ) -> ModelDims {
        assert!(d_model % n_heads == 0, "d_model must divide into heads");
        ModelDims {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            seq_len,
            d_ff: d_model * 4,
            block_size: 32,
            train_batch: 8,
        }
    }

    /// The built-in config table (mirrors `CONFIGS` in python).
    pub fn by_name(name: &str) -> Option<ModelDims> {
        match name {
            "tiny" => Some(ModelDims::new("tiny", 256, 128, 4, 4, 128)),
            "small" => Some(ModelDims::new("small", 256, 256, 6, 8, 128)),
            "base" => Some(ModelDims::new("base", 256, 512, 8, 8, 256)),
            _ => None,
        }
    }

    /// Dims from an AOT artifact manifest (`d_ff` recovered from the
    /// `l0.up` parameter shape; falls back to `4 * d_model`).
    pub fn from_manifest(m: &Manifest) -> ModelDims {
        let d_ff = m
            .params
            .iter()
            .find(|p| p.name == "l0.up")
            .and_then(|p| p.shape.last().copied())
            .unwrap_or(m.d_model * 4);
        ModelDims {
            name: m.config_name.clone(),
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            seq_len: m.seq_len,
            d_ff,
            block_size: m.block_size,
            train_batch: m.train_batch,
        }
    }

    /// Per-head dimension (`d_model / n_heads`).
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Ordered parameter table (= HLO argument order in python exports).
    pub fn param_specs(&self) -> Vec<crate::runtime::ParamInfo> {
        use crate::runtime::ParamInfo;
        let d = self.d_model;
        let mut specs = vec![
            ParamInfo {
                name: "emb".into(),
                shape: vec![self.vocab, d],
                quantized: false,
                init: "normal".into(),
            },
            ParamInfo {
                name: "pos".into(),
                shape: vec![self.seq_len, d],
                quantized: false,
                init: "normal".into(),
            },
        ];
        for i in 0..self.n_layers {
            specs.push(ParamInfo {
                name: format!("l{i}.ln1"),
                shape: vec![d],
                quantized: false,
                init: "ones".into(),
            });
            specs.push(ParamInfo {
                name: format!("l{i}.qkv"),
                shape: vec![d, 3 * d],
                quantized: true,
                init: "normal".into(),
            });
            specs.push(ParamInfo {
                name: format!("l{i}.proj"),
                shape: vec![d, d],
                quantized: true,
                init: "normal".into(),
            });
            specs.push(ParamInfo {
                name: format!("l{i}.ln2"),
                shape: vec![d],
                quantized: false,
                init: "ones".into(),
            });
            specs.push(ParamInfo {
                name: format!("l{i}.up"),
                shape: vec![d, self.d_ff],
                quantized: true,
                init: "normal".into(),
            });
            specs.push(ParamInfo {
                name: format!("l{i}.down"),
                shape: vec![self.d_ff, d],
                quantized: true,
                init: "normal".into(),
            });
        }
        specs.push(ParamInfo {
            name: "lnf".into(),
            shape: vec![d],
            quantized: false,
            init: "ones".into(),
        });
        specs.push(ParamInfo {
            name: "head".into(),
            shape: vec![d, self.vocab],
            quantized: false,
            init: "normal".into(),
        });
        specs
    }

    /// Synthesize a [`Manifest`] (empty artifact table) so the ParamSet /
    /// checkpoint machinery works without any AOT export on disk.
    pub fn to_manifest(&self) -> Manifest {
        let params = self.param_specs();
        let n_params = params.iter().map(|p| p.numel()).sum();
        Manifest {
            config_name: self.name.clone(),
            vocab: self.vocab,
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            seq_len: self.seq_len,
            block_size: self.block_size,
            n_params,
            train_batch: self.train_batch,
            params,
            artifacts: std::collections::BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{ArtifactEntry, ParamInfo};
    use std::collections::BTreeMap;

    pub(crate) fn test_manifest() -> Manifest {
        Manifest {
            config_name: "test".into(),
            vocab: 16,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            seq_len: 8,
            block_size: 32,
            n_params: 0,
            train_batch: 2,
            params: vec![
                ParamInfo { name: "emb".into(), shape: vec![16, 32], quantized: false, init: "normal".into() },
                ParamInfo { name: "l0.qkv".into(), shape: vec![32, 96], quantized: true, init: "normal".into() },
                ParamInfo { name: "l0.ln1".into(), shape: vec![32], quantized: false, init: "ones".into() },
            ],
            artifacts: BTreeMap::from([(
                "forward_b1".into(),
                ArtifactEntry { file: "forward_b1.hlo.txt".into(), trainable: None },
            )]),
        }
    }

    #[test]
    fn init_is_deterministic_and_typed() {
        let m = test_manifest();
        let a = ParamSet::init(&m, 7);
        let b = ParamSet::init(&m, 7);
        assert_eq!(a, b);
        assert!(ParamSet::init(&m, 8) != a);
        // ones init
        assert!(a.tensors[2].data.iter().all(|&x| x == 1.0));
        // normal init has reasonable scale
        let std = (a.tensors[0].data.iter().map(|x| x * x).sum::<f32>()
            / a.tensors[0].len() as f32)
            .sqrt();
        assert!((std - 0.02).abs() < 0.005, "std={std}");
    }

    #[test]
    fn ptq_touches_only_quantized_params() {
        let m = test_manifest();
        let p = ParamSet::init(&m, 1);
        let q = p.ptq(&m, ElementFormat::int(4)).unwrap();
        assert_eq!(p.tensors[0], q.tensors[0]); // emb untouched
        assert_eq!(p.tensors[2], q.tensors[2]); // ln untouched
        assert_ne!(p.tensors[1], q.tensors[1]); // qkv quantized
    }

    #[test]
    fn ptq_via_anchor_matches_ss_semantics() {
        let m = test_manifest();
        let p = ParamSet::init(&m, 2);
        let via = p
            .ptq_via_anchor(&m, ElementFormat::int(8), ElementFormat::int(4))
            .unwrap();
        // Equivalent to: quantize int8, SS to int4, dequant.
        let t = &p.tensors[1];
        let a = MxTensor::quantize(&t.data, &t.shape, MxFormat::mxint(8, 32)).unwrap();
        let want = a.slice_and_scale(ElementFormat::int(4)).unwrap().dequantize();
        assert_eq!(via.tensors[1].data, want);
    }

    #[test]
    fn anchor_checkpoint_roundtrip() {
        let m = test_manifest();
        let p = ParamSet::init(&m, 3);
        let ck = p.to_anchor_checkpoint(&m, ElementFormat::int(8)).unwrap();
        // Quantized param stored packed; others raw.
        assert!(ck.get("l0.qkv").is_some());
        assert!(ck.get_raw("emb").is_some());
        // Load at anchor precision = dequantized anchor values.
        let loaded = ParamSet::from_checkpoint(&m, &ck, None).unwrap();
        assert_eq!(loaded.tensors[0], p.tensors[0]);
        let want = p.ptq(&m, ElementFormat::int(8)).unwrap();
        assert_eq!(loaded.tensors[1], want.tensors[1]);
        // Load at int3 = SS conversion.
        let at3 = ParamSet::from_checkpoint(&m, &ck, Some(ElementFormat::int(3))).unwrap();
        let want3 = p
            .ptq_via_anchor(&m, ElementFormat::int(8), ElementFormat::int(3))
            .unwrap();
        assert_eq!(at3.tensors[1], want3.tensors[1]);
    }

    #[test]
    fn master_checkpoint_is_lossless() {
        let m = test_manifest();
        let p = ParamSet::init(&m, 4);
        let ck = p.to_master_checkpoint(&m).unwrap();
        let re = ParamSet::from_checkpoint(&m, &ck, None).unwrap();
        assert_eq!(p, re);
    }

    #[test]
    fn select_scatter_roundtrip() {
        let m = test_manifest();
        let mut p = ParamSet::init(&m, 5);
        let idx = vec![1usize];
        let newt = Tensor::full(&[32, 96], 0.5);
        p.scatter(&idx, vec![newt.clone()]).unwrap();
        assert_eq!(p.tensors[1], newt);
        assert!(p.scatter(&idx, vec![Tensor::zeros(&[2, 2])]).is_err());
    }

    #[test]
    fn missing_param_in_checkpoint_errors() {
        let m = test_manifest();
        let p = ParamSet::init(&m, 6);
        let mut ck = p.to_anchor_checkpoint(&m, ElementFormat::int(8)).unwrap();
        ck.tensors.remove("l0.qkv");
        assert!(ParamSet::from_checkpoint(&m, &ck, None).is_err());
    }

    #[test]
    fn model_dims_spec_table_matches_python_layout() {
        let dims = ModelDims::by_name("tiny").unwrap();
        let m = dims.to_manifest();
        // emb/pos + 6 per layer + lnf/head.
        assert_eq!(m.params.len(), 2 + 6 * dims.n_layers + 2);
        assert_eq!(m.quant_indices().len(), 4 * dims.n_layers);
        // tiny: 869,504 params (~0.9M, matching python's n_params()).
        assert_eq!(m.n_params, 869_504);
        assert_eq!(ModelDims::from_manifest(&m), dims);
        assert_eq!(dims.head_dim(), 32);
        assert!(ModelDims::by_name("bogus").is_none());
    }

    #[test]
    fn anchor_for_families() {
        assert_eq!(anchor_for(ElementFormat::int(3)), ElementFormat::int(8));
        assert_eq!(
            anchor_for(ElementFormat::fp(2, 1)),
            ElementFormat::fp(4, 3)
        );
    }
}
