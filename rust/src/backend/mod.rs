//! Pluggable inference backends.
//!
//! The elastic coordinator ([`crate::coordinator::ElasticEngine`]) executes
//! batches through a [`Backend`]:
//!
//! * [`NativeBackend`] — pure-Rust CPU engine ([`kernels`], [`forward`])
//!   that computes directly on packed MX codes with fused per-block scales.
//!   Needs only an anchor checkpoint + model dims: no XLA install, no AOT
//!   artifacts — any CPU-only deployment target can serve every format.
//! * `PjrtBackend` (feature `pjrt`) — wraps the PJRT runtime and the AOT
//!   HLO artifacts exported by `python/compile/aot.py`; formats execute as
//!   dequantized-f32 weight literals through one compiled graph.
//!
//! Both cache derived per-format weight sets in a byte-bounded LRU
//! ([`crate::coordinator::FormatCache`]); the native cache holds *packed*
//! weights, so a cached low-bit format costs a fraction of an f32 set.

pub mod forward;
pub mod kernels;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use forward::{LayerWeights, Mat, NativeWeights};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::coordinator::format_cache::CacheStats;
use crate::formats::ElementFormat;
use crate::model::ModelDims;
use anyhow::Result;

/// An inference engine that can score token batches at any element format.
///
/// Implementations are *not* required to be `Send` (PJRT handles are
/// thread-bound); the server constructs its backend inside the worker
/// thread.
pub trait Backend {
    /// Short identifier (`"native"`, `"pjrt"`) for logs and metrics.
    fn name(&self) -> &'static str;

    /// Model dimensions this backend serves.
    fn dims(&self) -> &ModelDims;

    /// Forward pass on a flat buffer of `seq_len`-wide token rows;
    /// returns flat logits `[rows, seq_len, vocab]`. The native backend
    /// accepts any row count; PJRT executes its fixed `train_batch` graph.
    fn forward_logits(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>>;

    /// Per-row mean NLL for a flat buffer of `1..=train_batch` token
    /// windows of width `seq_len + 1`; returns one NLL per window. Short
    /// batches execute at their true size on the native backend (the PJRT
    /// graph pads internally to its fixed shape).
    fn score_batch(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>>;

    /// Weight-cache counters (hits/misses/evictions/bytes).
    fn cache_stats(&self) -> CacheStats;
}
