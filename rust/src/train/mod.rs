//! Training driver: runs the AOT-compiled train-step HLOs from rust.
//!
//! Python never executes at training time — the AdamW update, the STE
//! fake-quant (L1 Pallas kernel), and the loss are all inside the compiled
//! graph. The driver owns the FP32 master weights ([`ParamSet`]), the
//! optimizer state, and the format *schedule* (multi-format QAT is a
//! schedule over per-format train steps, paper §3.2).

pub mod optimizer;
pub mod schedule;

pub use schedule::{Phase, TrainPlan};

#[cfg(feature = "pjrt")]
use crate::model::ParamSet;
#[cfg(feature = "pjrt")]
use crate::runtime::{self, ArtifactSet, Runtime};
#[cfg(feature = "pjrt")]
use crate::tensor::Tensor;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use optimizer::OptState;

/// Training driver bound to one artifact set.
#[cfg(feature = "pjrt")]
pub struct Trainer<'a> {
    /// PJRT runtime.
    pub rt: &'a Runtime,
    /// AOT artifacts (train-step graphs).
    pub arts: &'a ArtifactSet,
    /// Current parameters.
    pub params: ParamSet,
    /// Optimizer step counter.
    pub step: i32,
    opt: Option<OptState>,
}

/// Per-epoch training record.
#[derive(Debug, Clone)]
pub struct EpochStats {
    /// Plan/variant name.
    pub variant: String,
    /// Mean loss across the epoch.
    pub mean_loss: f64,
    /// First-step loss.
    pub first_loss: f32,
    /// Last-step loss.
    pub last_loss: f32,
    /// Steps executed.
    pub steps: usize,
}

#[cfg(feature = "pjrt")]
impl<'a> Trainer<'a> {
    /// New trainer over `params` bound to a runtime + artifact set.
    pub fn new(rt: &'a Runtime, arts: &'a ArtifactSet, params: ParamSet) -> Trainer<'a> {
        Trainer {
            rt,
            arts,
            params,
            step: 0,
            opt: None,
        }
    }

    /// Reset optimizer state and the step counter (fresh training run).
    pub fn reset_opt(&mut self) {
        self.opt = None;
        self.step = 0;
    }

    /// Run one epoch of `variant` over `rows` (token windows of width
    /// `seq_len + 1`) at learning rate `lr`. Returns loss stats.
    pub fn train_epoch(&mut self, variant: &str, rows: &[Vec<i32>], lr: f32) -> Result<EpochStats> {
        let name = format!("train_{variant}");
        let exe = self.arts.executable(self.rt, &name)?;
        let t_idx = self.arts.trainable(&name)?;
        let m = &self.arts.manifest;
        let b = m.train_batch;
        let width = m.seq_len + 1;
        if rows.is_empty() {
            bail!("train_epoch: no data");
        }

        // (Re)build optimizer state if the trainable set changed (e.g.
        // pretrain -> QAT). Within a multi-format schedule the set is
        // identical across formats, so AdamW moments persist (paper trains
        // sequentially with one optimizer).
        let reset = match &self.opt {
            Some(o) => o.idx != t_idx,
            None => true,
        };
        if reset {
            self.opt = Some(OptState::zeros(&self.params, &t_idx));
            log::debug!("optimizer state reset for {} ({} tensors)", variant, t_idx.len());
        }

        let f_idx: Vec<usize> = (0..m.params.len()).filter(|i| !t_idx.contains(i)).collect();

        let mut first_loss = f32::NAN;
        let mut last_loss = f32::NAN;
        let mut total = 0.0f64;
        let batches = crate::data::batches(rows, b, width);
        for flat in &batches {
            self.step += 1;
            let tokens = runtime::i32_literal(flat, &[b, width])?;
            let lr_lit = runtime::f32_scalar(lr);
            let step_lit = runtime::i32_scalar(self.step);
            let opt = self.opt.as_ref().unwrap();

            // Literal assembly in HLO argument order:
            // (lr, step, tokens, *train, *frozen, *m, *v).
            let train_lits: Vec<xla::Literal> = t_idx
                .iter()
                .map(|&i| runtime::tensor_literal(&self.params.tensors[i]))
                .collect::<Result<_>>()?;
            let frozen_lits: Vec<xla::Literal> = f_idx
                .iter()
                .map(|&i| runtime::tensor_literal(&self.params.tensors[i]))
                .collect::<Result<_>>()?;
            let m_lits: Vec<xla::Literal> = opt
                .m
                .iter()
                .map(runtime::tensor_literal)
                .collect::<Result<_>>()?;
            let v_lits: Vec<xla::Literal> = opt
                .v
                .iter()
                .map(runtime::tensor_literal)
                .collect::<Result<_>>()?;
            let mut args: Vec<&xla::Literal> = vec![&lr_lit, &step_lit, &tokens];
            args.extend(train_lits.iter());
            args.extend(frozen_lits.iter());
            args.extend(m_lits.iter());
            args.extend(v_lits.iter());

            let out = exe.run(&args).context("train step")?;
            let n_t = t_idx.len();
            if out.len() != 1 + 3 * n_t {
                bail!("train step returned {} outputs, expected {}", out.len(), 1 + 3 * n_t);
            }
            let loss = runtime::literal_f32(&out[0])?;
            if !loss.is_finite() {
                bail!("non-finite loss at step {} ({variant}, lr {lr})", self.step);
            }
            let new_t: Vec<Tensor> = out[1..1 + n_t]
                .iter()
                .map(runtime::literal_tensor)
                .collect::<Result<_>>()?;
            let new_m: Vec<Tensor> = out[1 + n_t..1 + 2 * n_t]
                .iter()
                .map(runtime::literal_tensor)
                .collect::<Result<_>>()?;
            let new_v: Vec<Tensor> = out[1 + 2 * n_t..]
                .iter()
                .map(runtime::literal_tensor)
                .collect::<Result<_>>()?;
            self.params.scatter(&t_idx, new_t)?;
            let opt = self.opt.as_mut().unwrap();
            opt.m = new_m;
            opt.v = new_v;

            if first_loss.is_nan() {
                first_loss = loss;
            }
            last_loss = loss;
            total += loss as f64;
            log::debug!("step {:>5} [{}] loss {:.4}", self.step, variant, loss);
        }
        let stats = EpochStats {
            variant: variant.to_string(),
            mean_loss: total / batches.len() as f64,
            first_loss,
            last_loss,
            steps: batches.len(),
        };
        log::info!(
            "epoch [{}] {} steps, loss {:.4} -> {:.4} (mean {:.4})",
            stats.variant,
            stats.steps,
            stats.first_loss,
            stats.last_loss,
            stats.mean_loss
        );
        Ok(stats)
    }

    /// Execute a full training plan; returns per-epoch stats.
    pub fn run_plan(&mut self, plan: &TrainPlan, rows: &[Vec<i32>], lr: f32) -> Result<Vec<EpochStats>> {
        let mut out = Vec::new();
        for phase in &plan.phases {
            for _ in 0..phase.epochs {
                out.push(self.train_epoch(&phase.variant, rows, lr)?);
            }
        }
        Ok(out)
    }

    /// Subsample `rows` evenly to `n` rows (the paper's equal-step split for
    /// >2B models; we use it to keep the experiment matrix affordable).
    pub fn subsample(rows: &[Vec<i32>], n: usize) -> Vec<Vec<i32>> {
        if n >= rows.len() {
            return rows.to_vec();
        }
        (0..n)
            .map(|i| rows[i * rows.len() / n].clone())
            .collect()
    }
}
