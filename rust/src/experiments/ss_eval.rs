//! Slice-and-Scale fidelity experiments: Figs. 2/3 (end-to-end perplexity)
//! and Appendix C Figs. 19/20 (tensor-level MSE).
//!
//! Figs. 19/20 are an *exact* reproduction: 100 random tensors of shape
//! (1, 1024), comparing direct quantization (FP32 → target) against SS from
//! the 8-bit anchor, sweeping (a) bit precision at block size 64 and
//! (b) block size at 4-bit.
//!
//! Figs. 2/3 run the same comparison end-to-end: the pretrained LM is PTQ'd
//! either directly or via the anchor, and WikiText-style validation
//! perplexity is measured per setting.

use super::report::{ascii_plot, save_text, ResultTable, Series};
#[cfg(feature = "pjrt")]
use super::Ctx;
use crate::formats::{ElementFormat, MxFormat};
use crate::tensor::MxTensor;
use crate::util::stats::mse;
use crate::util::Rng;
use anyhow::Result;
use std::path::Path;

fn family_bits(family: &str) -> Vec<u8> {
    match family {
        "int" => (2..=8).collect(),
        "fp" => (4..=8).collect(),
        _ => panic!("family must be int|fp"),
    }
}

fn fmt_of(family: &str, bits: u8) -> ElementFormat {
    match family {
        "int" => ElementFormat::int(bits),
        _ => ElementFormat::fp_from_bits(bits),
    }
}

/// Figures 2 (int) / 3 (fp): direct vs SS perplexity. Left panel: bits at
/// block size 64; right panel: block size at 4-bit.
#[cfg(feature = "pjrt")]
pub fn fig2_or_3(ctx: &Ctx, family: &str) -> Result<()> {
    let params = ctx.ensure_pretrained()?;
    let base_ppl = ctx.val_ppl(&params)?;
    let anchor = fmt_of(family, 8);
    let stem = if family == "int" { "fig2" } else { "fig3" };

    // Panel A: bits sweep at block size 64.
    let mut table = ResultTable::new(&["panel", "bits", "block", "direct_ppl", "ss_ppl"]);
    let mut direct_s = Vec::new();
    let mut ss_s = Vec::new();
    for bits in family_bits(family) {
        let t = fmt_of(family, bits);
        let d = ctx.val_ppl(&params.ptq_block(&ctx.arts.manifest, t, 64)?)?;
        let s = ctx.val_ppl(&params.ptq_via_anchor_block(&ctx.arts.manifest, anchor, t, 64)?)?;
        log::info!("[{stem}] bits={bits} bs=64: direct {d:.3} ss {s:.3}");
        table.push(vec![
            "bits@64".into(),
            bits.to_string(),
            "64".into(),
            format!("{d:.4}"),
            format!("{s:.4}"),
        ]);
        direct_s.push((bits as f64, d));
        ss_s.push((bits as f64, s));
    }
    let plot_a = ascii_plot(
        &format!("{stem} left: PPL vs bits at block 64 (base fp32 {base_ppl:.3})"),
        "bits",
        "perplexity",
        &[
            Series { name: format!("direct MX{}", family.to_uppercase()), points: direct_s },
            Series { name: format!("SSMX{}", family.to_uppercase()), points: ss_s },
        ],
        true,
    );

    // Panel B: block-size sweep at 4-bit.
    let t4 = fmt_of(family, 4);
    let mut direct_b = Vec::new();
    let mut ss_b = Vec::new();
    for bs in [16usize, 32, 64, 128] {
        let d = ctx.val_ppl(&params.ptq_block(&ctx.arts.manifest, t4, bs)?)?;
        let s = ctx.val_ppl(&params.ptq_via_anchor_block(&ctx.arts.manifest, anchor, t4, bs)?)?;
        log::info!("[{stem}] 4-bit bs={bs}: direct {d:.3} ss {s:.3}");
        table.push(vec![
            "block@4bit".into(),
            "4".into(),
            bs.to_string(),
            format!("{d:.4}"),
            format!("{s:.4}"),
        ]);
        direct_b.push((bs as f64, d));
        ss_b.push((bs as f64, s));
    }
    let plot_b = ascii_plot(
        &format!("{stem} right: PPL vs block size at 4-bit"),
        "block size",
        "perplexity",
        &[
            Series { name: "direct".into(), points: direct_b },
            Series { name: "SS".into(), points: ss_b },
        ],
        false,
    );

    table.save_csv(&ctx.result_path(&format!("{stem}.csv")))?;
    save_text(
        &ctx.result_path(&format!("{stem}.txt")),
        &format!("{plot_a}\n{plot_b}\n{}", table.to_text()),
    )?;
    Ok(())
}

/// Appendix C Figures 19 (int) / 20 (fp): tensor-level reconstruction MSE on
/// 100 random (1, 1024) tensors — direct vs Slice-and-Scale.
pub fn fig19_or_20(family: &str, out_stem: &Path) -> Result<()> {
    let mut rng = Rng::new(0xA99C + family.len() as u64);
    let tensors: Vec<Vec<f32>> = (0..100).map(|_| rng.normal_vec(1024)).collect();
    let anchor = fmt_of(family, 8);

    let mut table = ResultTable::new(&["panel", "bits", "block", "direct_mse", "ss_mse", "ratio"]);
    let mut d_series = Vec::new();
    let mut s_series = Vec::new();

    let measure = |bits: u8, bs: usize| -> Result<(f64, f64)> {
        let t = fmt_of(family, bits);
        let mut d_total = 0.0;
        let mut s_total = 0.0;
        for data in &tensors {
            let direct = MxTensor::quantize(data, &[1, 1024], MxFormat::new(t, bs))?;
            d_total += mse(data, &direct.dequantize());
            let anc = MxTensor::quantize(data, &[1, 1024], MxFormat::new(anchor, bs))?;
            let ss = if t == anchor { anc } else { anc.slice_and_scale(t)? };
            s_total += mse(data, &ss.dequantize());
        }
        Ok((d_total / 100.0, s_total / 100.0))
    };

    for bits in family_bits(family) {
        let (d, s) = measure(bits, 64)?;
        table.push(vec![
            "bits@64".into(),
            bits.to_string(),
            "64".into(),
            format!("{d:.3e}"),
            format!("{s:.3e}"),
            format!("{:.3}", s / d.max(1e-300)),
        ]);
        d_series.push((bits as f64, d));
        s_series.push((bits as f64, s));
    }
    let plot_a = ascii_plot(
        &format!(
            "Fig.{} left: tensor MSE vs bits at block 64 (100 tensors, (1,1024))",
            if family == "int" { 19 } else { 20 }
        ),
        "bits",
        "MSE",
        &[
            Series { name: "direct".into(), points: d_series },
            Series { name: "slice-and-scale".into(), points: s_series },
        ],
        true,
    );

    let mut d_b = Vec::new();
    let mut s_b = Vec::new();
    for bs in [16usize, 32, 64, 128] {
        let (d, s) = measure(4, bs)?;
        table.push(vec![
            "block@4bit".into(),
            "4".into(),
            bs.to_string(),
            format!("{d:.3e}"),
            format!("{s:.3e}"),
            format!("{:.3}", s / d.max(1e-300)),
        ]);
        d_b.push((bs as f64, d));
        s_b.push((bs as f64, s));
    }
    let plot_b = ascii_plot(
        "right: tensor MSE vs block size at 4-bit",
        "block size",
        "MSE",
        &[
            Series { name: "direct".into(), points: d_b },
            Series { name: "slice-and-scale".into(), points: s_b },
        ],
        true,
    );

    let csv_path = out_stem.with_extension("csv");
    table.save_csv(&csv_path)?;
    save_text(
        &out_stem.with_extension("txt"),
        &format!("{plot_a}\n{plot_b}\n{}", table.to_text()),
    )?;
    log::info!("written {}", csv_path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig19_reproduces_paper_shape() {
        // The App. C claims, verified quantitatively: (i) MSE decreases with
        // bits, (ii) increases with block size, (iii) SS ≈ direct (small
        // ratio) at n = 100×1024 scale.
        let dir = std::env::temp_dir().join("mfqat_fig19_test");
        std::fs::create_dir_all(&dir).unwrap();
        fig19_or_20("int", &dir.join("fig19")).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig19.csv")).unwrap();
        let rows: Vec<Vec<&str>> = csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        // Bits sweep: direct MSE strictly decreasing.
        let bits_rows: Vec<&Vec<&str>> = rows.iter().filter(|r| r[0] == "bits@64").collect();
        assert_eq!(bits_rows.len(), 7);
        for w in bits_rows.windows(2) {
            let a: f64 = w[0][3].parse().unwrap();
            let b: f64 = w[1][3].parse().unwrap();
            assert!(b < a, "MSE must fall with bits: {a} -> {b}");
        }
        // SS/direct ratio stays modest everywhere (paper: "closely matches").
        for r in &rows {
            let ratio: f64 = r[5].parse().unwrap();
            assert!(ratio < 2.0, "SS within 2x of direct, got {ratio}");
            assert!(ratio >= 0.99, "SS can't beat direct meaningfully: {ratio}");
        }
        // Block sweep: MSE grows with block size.
        let blk: Vec<f64> = rows
            .iter()
            .filter(|r| r[0] == "block@4bit")
            .map(|r| r[3].parse().unwrap())
            .collect();
        assert_eq!(blk.len(), 4);
        for w in blk.windows(2) {
            assert!(w[1] > w[0], "MSE must grow with block size: {blk:?}");
        }
    }
}
