//! Serving metrics: request counts per format, latency distribution,
//! batch-size and execution-time statistics, and weight-cache counters.

use crate::coordinator::CacheStats;
use crate::formats::ElementFormat;
use crate::util::stats::{LatencyHist, Running};
use std::collections::BTreeMap;

/// Aggregated server metrics (guarded by a mutex in the server; the worker
/// takes that lock once per executed batch).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    per_format: BTreeMap<String, u64>,
    pub latency: LatencyHist,
    pub batch_size: Running,
    pub exec_time: Running,
    /// Weight-cache counter snapshot (hits/misses/evictions/bytes).
    pub cache: CacheStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            latency: LatencyHist::new(),
            ..Default::default()
        }
    }

    pub fn record(&mut self, fmt: ElementFormat, latency_s: f64, batch: usize, exec_s: f64) {
        self.requests += 1;
        *self.per_format.entry(fmt.name()).or_insert(0) += 1;
        self.latency.record(latency_s);
        self.batch_size.push(batch as f64);
        self.exec_time.push(exec_s);
    }

    /// Refresh the weight-cache counter snapshot (once per batch).
    pub fn set_cache(&mut self, stats: CacheStats) {
        self.cache = stats;
    }

    /// Anchor→target weight derivations performed (= format-cache misses).
    pub fn conversions(&self) -> u64 {
        self.cache.misses
    }

    pub fn format_counts(&self) -> &BTreeMap<String, u64> {
        &self.per_format
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mix: Vec<String> = self
            .per_format
            .iter()
            .map(|(f, n)| format!("{f}:{n}"))
            .collect();
        format!(
            "requests={} latency[{}] mean_batch={:.2} mix=[{}] cache[hit:{} miss:{} evict:{} {}KB]",
            self.requests,
            self.latency.summary(),
            self.batch_size.mean(),
            mix.join(" "),
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.used_bytes / 1024,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = Metrics::new();
        m.record(ElementFormat::int(8), 0.010, 4, 0.008);
        m.record(ElementFormat::int(8), 0.020, 8, 0.015);
        m.record(ElementFormat::int(4), 0.005, 8, 0.004);
        assert_eq!(m.requests, 3);
        assert_eq!(m.format_counts()["int8"], 2);
        assert_eq!(m.format_counts()["int4"], 1);
        assert!((m.batch_size.mean() - 20.0 / 3.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("int8:2"));
    }

    #[test]
    fn cache_counters_flow_into_summary() {
        let mut m = Metrics::new();
        m.set_cache(CacheStats {
            hits: 7,
            misses: 3,
            evictions: 2,
            entries: 1,
            used_bytes: 4096,
        });
        assert_eq!(m.conversions(), 3);
        let s = m.summary();
        assert!(s.contains("hit:7"), "{s}");
        assert!(s.contains("miss:3"), "{s}");
        assert!(s.contains("evict:2"), "{s}");
    }
}
