//! Slice-and-Scale conversion benchmarks — the paper's headline runtime
//! claim: deriving a low-precision model from the anchor must be much
//! cheaper than re-quantizing from FP32 (no FP32 weights are even stored).
//!
//! Rows map to the paper's elastic-inference pipeline (§3.5):
//!   ss/int8->intN          packed anchor → packed target (per element)
//!   ss/fp8->fpN            same for MXFP (LUT requant)
//!   baseline/fp32->intN    direct quantization from FP32 (the path SS avoids)
//!   pipeline/anchor->serve SS + dequant to the f32 serving buffer
//!   ablation/round-mode    SSMXINT RNE vs round-half-away (§3.3 variant)

use mfqat::formats::{ElementFormat, MxFormat, RoundMode};
use mfqat::tensor::MxTensor;
use mfqat::util::stats::mse;
use mfqat::util::timer::bench;
use mfqat::util::Rng;

const N: usize = 1 << 20;

fn main() {
    let mut rng = Rng::new(2);
    let data = rng.normal_vec(N);
    let shape = [N / 1024, 1024];
    let anchor_int = MxTensor::quantize(&data, &shape, MxFormat::mxint(8, 32)).unwrap();
    let anchor_fp = MxTensor::quantize(&data, &shape, MxFormat::mxfp(8, 32)).unwrap();

    println!("== slice-and-scale: anchor -> target (N = {N} elements) ==");
    for bits in [2u8, 4, 6] {
        let t = ElementFormat::int(bits);
        let r = bench(&format!("ss/int8->int{bits}"), 6, 0.4, || {
            std::hint::black_box(anchor_int.slice_and_scale(t).unwrap());
        });
        println!("{}", r.report(N as f64, "elem"));
    }
    for bits in [4u8, 6] {
        let t = ElementFormat::fp_from_bits(bits);
        let r = bench(&format!("ss/fp8->fp{bits}"), 6, 0.4, || {
            std::hint::black_box(anchor_fp.slice_and_scale(t).unwrap());
        });
        println!("{}", r.report(N as f64, "elem"));
    }

    println!("\n== baseline: direct quantization from FP32 ==");
    for bits in [2u8, 4, 6] {
        let f = MxFormat::mxint(bits, 32);
        let r = bench(&format!("baseline/fp32->int{bits}"), 6, 0.4, || {
            std::hint::black_box(MxTensor::quantize(&data, &shape, f).unwrap());
        });
        println!("{}", r.report(N as f64, "elem"));
    }

    println!("\n== full serving derivation: SS + dequantize ==");
    let mut out = vec![0.0f32; N];
    for bits in [4u8, 6] {
        let t = ElementFormat::int(bits);
        let r = bench(&format!("pipeline/anchor->serve/int{bits}"), 6, 0.4, || {
            let q = anchor_int.slice_and_scale(t).unwrap();
            q.dequantize_into(&mut out);
            std::hint::black_box(&out);
        });
        println!("{}", r.report(N as f64, "elem"));
    }

    println!("\n== ablation: SSMXINT rounding mode (quality + speed) ==");
    for (name, mode) in [("half-even", RoundMode::HalfEven), ("half-away", RoundMode::HalfAway)] {
        let r = bench(&format!("ablation/ss-int4/{name}"), 6, 0.3, || {
            std::hint::black_box(
                anchor_int
                    .slice_and_scale_mode(ElementFormat::int(4), mode)
                    .unwrap(),
            );
        });
        println!("{}", r.report(N as f64, "elem"));
        let q = anchor_int
            .slice_and_scale_mode(ElementFormat::int(4), mode)
            .unwrap();
        println!("    reconstruction mse vs fp32: {:.6e}", mse(&data, &q.dequantize()));
    }
}
