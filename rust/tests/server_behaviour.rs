//! Elastic server integration: batching, policy-driven format selection,
//! pinned formats, metrics, and graceful shutdown.

use mfqat::coordinator::ElasticEngine;
use mfqat::data::{Corpus, CorpusConfig};
use mfqat::formats::ElementFormat;
use mfqat::model::ParamSet;
use mfqat::runtime::{ArtifactSet, Runtime};
use mfqat::server::{Policy, Server, ServerConfig};
use std::path::PathBuf;
use std::time::Duration;

fn arts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping (run `make artifacts`)");
        None
    }
}

fn start_server(dir: PathBuf, policy: Policy) -> (Server, mfqat::server::Client, usize) {
    // Build the engine inside the worker (PJRT handles are not Send).
    let manifest = mfqat::runtime::Manifest::load(&dir).unwrap();
    let width = manifest.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || {
            let rt = Runtime::cpu()?;
            let arts = ArtifactSet::open(&dir)?;
            let params = ParamSet::init(&arts.manifest, 11);
            let ck = params.to_anchor_checkpoint(&arts.manifest, ElementFormat::int(8))?;
            Ok(ElasticEngine::from_parts(
                rt,
                arts,
                ck,
                ElementFormat::int(8),
                64 << 20,
            ))
        },
        ServerConfig {
            policy,
            gather_window: Duration::from_millis(1),
        },
    )
    .unwrap();
    (server, client, width)
}

#[test]
fn requests_are_scored_and_batched() {
    let Some(dir) = arts_dir() else { return };
    let corpus = Corpus::generate(CorpusConfig {
        seed: 9,
        width: 129,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 16,
    });
    let (server, client, _) = start_server(dir, Policy::Fixed(ElementFormat::int(8)));

    // Fire a burst; all must come back finite with the fixed format.
    let rxs: Vec<_> = (0..16)
        .map(|i| client.submit(&corpus.val[i % corpus.val.len()], None).unwrap())
        .collect();
    let mut max_batch = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.nll.is_finite() && resp.nll > 0.0);
        assert_eq!(resp.format, ElementFormat::int(8));
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "burst must be batched (got {max_batch})");
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.requests, 16);
    drop(client);
    server.shutdown();
}

#[test]
fn pinned_format_wins_over_policy() {
    let Some(dir) = arts_dir() else { return };
    let corpus = Corpus::generate(CorpusConfig {
        seed: 10,
        width: 129,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 8,
    });
    let (server, client, _) = start_server(dir, Policy::Fixed(ElementFormat::int(8)));
    let resp = client
        .score(&corpus.val[0], Some(ElementFormat::int(3)))
        .unwrap();
    assert_eq!(resp.format, ElementFormat::int(3), "pin honoured");
    drop(client);
    server.shutdown();
}

#[test]
fn ladder_policy_degrades_under_load() {
    let Some(dir) = arts_dir() else { return };
    let corpus = Corpus::generate(CorpusConfig {
        seed: 11,
        width: 129,
        pretrain_sequences: 8,
        qat_sequences: 8,
        val_sequences: 64,
    });
    // Aggressive ladder so a modest burst crosses thresholds.
    let ladder = Policy::Ladder(vec![
        (2, ElementFormat::int(8)),
        (10, ElementFormat::int(6)),
        (usize::MAX, ElementFormat::int(4)),
    ]);
    let (server, client, _) = start_server(dir, ladder);

    // Single request under no load → highest precision.
    let solo = client.score(&corpus.val[0], None).unwrap();
    assert_eq!(solo.format, ElementFormat::int(8));

    // Big burst → later batches must see depth > 10 and degrade.
    let rxs: Vec<_> = (0..48)
        .map(|i| client.submit(&corpus.val[i % corpus.val.len()], None).unwrap())
        .collect();
    let mut formats = std::collections::BTreeSet::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        formats.insert(resp.format.bits());
    }
    assert!(
        formats.iter().any(|&b| b < 8),
        "burst must trigger lower precisions, saw {formats:?}"
    );
    let metrics = server.metrics.lock().unwrap().clone();
    assert!(metrics.conversions >= formats.len() as u64);
    drop(client);
    server.shutdown();
}

#[test]
fn shutdown_rejects_new_requests() {
    let Some(dir) = arts_dir() else { return };
    let (server, client, width) = start_server(dir, Policy::Fixed(ElementFormat::int(8)));
    let tokens = vec![65i32; width];
    client.score(&tokens, None).unwrap();
    server.shutdown();
    assert!(client.score(&tokens, None).is_err(), "post-shutdown submit fails");
}
