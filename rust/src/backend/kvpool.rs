//! Paged KV-cache storage: a fixed-size page-pool allocator with
//! refcounted sharing, a content-addressed prefix index, and a
//! cross-worker page ledger.
//!
//! Dense KV allocation sizes every slot for its worst case
//! (`slots × seq_len × d_model` per layer), so a mostly-idle pool of short
//! sequences pays full-window memory the whole time. [`KvPagePool`] instead
//! carves one arena per K and V into fixed-size **pages** of
//! [`KvPageCfg::page_positions`] positions (each page spans every layer, so
//! one allocation funds a position range across the whole stack), hands
//! them out from a free list as rows append tokens, and takes them back —
//! zeroed — when a row retires, resets, or re-prefills after window
//! overflow. Resident KV memory therefore tracks **live context**, not slot
//! capacity, and admission can be budgeted in pages instead of slots
//! ([`crate::backend::forward::KvCache::can_fund_row`]).
//!
//! Three structures layer sharing on top of the allocator:
//!
//! - **Per-page refcounts.** [`KvPagePool::alloc`] hands a page out with
//!   one reference; [`KvPagePool::retain`] adds more (a prefix-sharing row
//!   or the prefix index mapping the same immutable page) and
//!   [`KvPagePool::release`] drops one. Zeroing happens **only at the last
//!   drop**, so release is keyed to the refcount reaching zero, never to
//!   the call site — a page referenced by any other row or by the index is
//!   untouched, and a page that does reach zero can never leak a previous
//!   occupant's keys/values to the next sequence that maps it (the
//!   quarantine guarantee `rust/tests/kv_paging.rs` and
//!   `rust/tests/prefix_sharing.rs` regress).
//! - **[`PrefixIndex`]** — a content-addressed map from
//!   `(chained token hash, row tag)` to full pages already holding that
//!   prefix's K/V. Lookups verify **exact token equality** (the hash only
//!   narrows the search), so a hash collision can cause a missed share but
//!   never a wrong one. The index holds its own page reference, which is
//!   what keeps a retired conversation's prefix warm for the next turn;
//!   LRU eviction under pool pressure (or a retain cap) drops index-only
//!   pages back to the free list, and a later miss simply recomputes via
//!   normal prefill.
//! - **[`PageLedger`]** — a pool-wide admission budget shared across
//!   worker sessions through an `Arc`. Each admitted row claims its
//!   worst-case page count from the ledger and returns it at retire (or
//!   when the owning cache drops, so a panicking worker can never strand
//!   its share), letting admission trade memory between workers under
//!   skewed load instead of capping each worker independently.
//!
//! [`KvMemory`] is the accounting snapshot surfaced through
//! [`crate::backend::DecodeSession::kv_memory`] and
//! `server::Metrics::summary()`; `benches/serving.rs` records it as the
//! `kv_memory.*` and `prefix_sharing.*` sections of `BENCH_serving.json`.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Default page size in positions when `MFQAT_KV_PAGE` is unset.
pub const DEFAULT_PAGE_POSITIONS: usize = 64;

/// Page-pool sizing for a [`crate::backend::forward::KvCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPageCfg {
    /// Positions per page (the paging granularity). Clamped to the model
    /// window at cache construction; tiny values (e.g. `8`) force page
    /// boundaries mid-prompt and mid-decode, which CI exercises via
    /// `MFQAT_KV_PAGE=8`.
    pub page_positions: usize,
    /// Total pages in the pool; `0` funds every row's worst case
    /// (`rows × ceil(seq_len / page_positions)` — dense-equivalent
    /// capacity, the default). Smaller budgets make admission
    /// memory-aware: [`crate::backend::forward::KvCache::join_row`] defers
    /// rows the pool cannot fund. Clamped up to at least one row's worst
    /// case so a pool can always serve one sequence.
    pub budget_pages: usize,
    /// Enable prefix sharing: joining rows map full pages already holding
    /// an identical `(prefix tokens, row tag)` span and skip prefill for
    /// it, and retired rows leave their full pages behind in the
    /// [`PrefixIndex`] for later turns. Off by default — retention changes
    /// the "free list returns to baseline after drain" invariant, so it is
    /// strictly opt-in (`--prefix-share` / `MFQAT_PREFIX_SHARE`).
    pub prefix_share: bool,
    /// Cap on pages the prefix index may retain beyond live rows
    /// (LRU-evicted past the cap); `0` means no cap — index pages are
    /// evicted only under pool pressure (`MFQAT_KV_RETAIN` / `--kv-retain`).
    pub retain_pages: usize,
}

impl Default for KvPageCfg {
    fn default() -> Self {
        KvPageCfg::from_env()
    }
}

/// True for "1" / "true" / "on" (case-insensitive), false otherwise.
fn env_flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on"),
        Err(_) => false,
    }
}

impl KvPageCfg {
    /// Page size from the `MFQAT_KV_PAGE` environment pin (positions per
    /// page; see `util/cli.rs` for the env-var table), full funding.
    /// Prefix sharing follows `MFQAT_PREFIX_SHARE` and the retain cap
    /// follows `MFQAT_KV_RETAIN` (both optional).
    pub fn from_env() -> KvPageCfg {
        let page_positions = match std::env::var("MFQAT_KV_PAGE") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => {
                    log::warn!(
                        "MFQAT_KV_PAGE='{v}' is not a positive integer; \
                         using the default page of {DEFAULT_PAGE_POSITIONS} positions"
                    );
                    DEFAULT_PAGE_POSITIONS
                }
            },
            Err(_) => DEFAULT_PAGE_POSITIONS,
        };
        let retain_pages = match std::env::var("MFQAT_KV_RETAIN") {
            Ok(v) => v.trim().parse::<usize>().unwrap_or_else(|_| {
                log::warn!("MFQAT_KV_RETAIN='{v}' is not an integer; using no cap");
                0
            }),
            Err(_) => 0,
        };
        KvPageCfg {
            page_positions,
            budget_pages: 0,
            prefix_share: env_flag("MFQAT_PREFIX_SHARE"),
            retain_pages,
        }
    }

    /// Explicit page size, full funding, sharing off.
    pub fn with_page(page_positions: usize) -> KvPageCfg {
        KvPageCfg {
            page_positions: page_positions.max(1),
            budget_pages: 0,
            prefix_share: false,
            retain_pages: 0,
        }
    }

    /// Restrict the pool to `budget_pages` total pages (builder-style).
    pub fn budget(mut self, budget_pages: usize) -> KvPageCfg {
        self.budget_pages = budget_pages;
        self
    }

    /// Toggle prefix sharing (builder-style).
    pub fn share(mut self, on: bool) -> KvPageCfg {
        self.prefix_share = on;
        self
    }

    /// Cap retained prefix-index pages (builder-style; `0` = no cap).
    pub fn retain(mut self, retain_pages: usize) -> KvPageCfg {
        self.retain_pages = retain_pages;
        self
    }
}

/// A snapshot of paged-KV accounting: what is resident now versus what the
/// pre-paging dense layout would have preallocated, plus the
/// prefix-sharing economy counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvMemory {
    /// Bytes held by pages currently mapped into row page tables (K + V).
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` over the cache's lifetime,
    /// recorded **at page-allocation time** — so a row that maps pages and
    /// retires within one decode step still registers its footprint (a
    /// snapshot taken between steps would miss it).
    pub resident_peak_bytes: usize,
    /// Bytes the dense layout would preallocate for the same cache
    /// (`rows × n_layers × seq_len × d_model × 2 × 4`).
    pub dense_equivalent_bytes: usize,
    /// Total arena bytes backing the pool (all pages, free or mapped).
    pub pool_bytes: usize,
    /// Pages currently mapped into page tables.
    pub used_pages: usize,
    /// Pages on the free list.
    pub free_pages: usize,
    /// Pool size in pages.
    pub total_pages: usize,
    /// Positions per page.
    pub page_positions: usize,
    /// Bytes deduplicated by sharing: `Σ max(refcount − 1, 0) × page_bytes`
    /// — each extra reference to a page is one page of K/V some consumer
    /// did not have to store (or recompute) itself.
    pub shared_bytes: usize,
    /// Pages currently retained by the prefix index (each index entry
    /// holds exactly one page reference).
    pub retained_pages: usize,
    /// Row admissions that mapped at least one shared prefix page.
    pub prefix_hits: u64,
    /// Prompt positions whose prefill was skipped because a shared page
    /// already held their K/V.
    pub prefill_tokens_saved: u64,
    /// Prefix-index entries dropped by LRU eviction (pool pressure or the
    /// retain cap); a later lookup for that span recomputes via prefill.
    pub prefix_evictions: u64,
}

impl KvMemory {
    /// Fraction of the pool's pages currently mapped (0.0 on an empty or
    /// absent pool).
    pub fn utilization(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            self.used_pages as f64 / self.total_pages as f64
        }
    }

    /// Resident bytes over the dense-equivalent allocation (the headline
    /// paging win; 0.0 when there is no dense baseline).
    pub fn resident_over_dense(&self) -> f64 {
        if self.dense_equivalent_bytes == 0 {
            0.0
        } else {
            self.resident_bytes as f64 / self.dense_equivalent_bytes as f64
        }
    }
}

/// Fixed-size page arenas (one for K, one for V) plus a LIFO free list and
/// per-page reference counts.
///
/// The pool is position-layout-agnostic: it deals in pages of
/// `floats_per_page` f32s per arena and leaves the
/// `[layer, position-in-page, d_model]` indexing to the cache that owns it.
#[derive(Debug, Clone)]
pub struct KvPagePool {
    floats_per_page: usize,
    total: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    free: Vec<usize>,
    /// Reference count per page: `0` = free, `1` = one holder (a single
    /// row's table, or the prefix index alone), `> 1` = shared.
    refs: Vec<u32>,
    /// Pages removed from service by [`Self::shrink`]: still part of the
    /// arena (so release-time range asserts stay valid) but never handed
    /// out again and excluded from every capacity report.
    quarantined: Vec<usize>,
}

impl KvPagePool {
    /// Pool of `total` pages of `floats_per_page` f32s per arena, all free.
    pub fn new(total: usize, floats_per_page: usize) -> KvPagePool {
        KvPagePool {
            floats_per_page,
            total,
            k: vec![0.0; total * floats_per_page],
            v: vec![0.0; total * floats_per_page],
            // LIFO so recently-hot pages are remapped first.
            free: (0..total).rev().collect(),
            refs: vec![0; total],
            quarantined: Vec::new(),
        }
    }

    /// Permanently remove up to `want` **free** pages from service
    /// (mid-run budget shrink — the fault-injection harness and elastic
    /// memory pressure both use this). Mapped pages are never touched, so
    /// live rows keep every page they hold; the pool simply gets smaller.
    /// Returns how many pages were actually quarantined.
    pub fn shrink(&mut self, want: usize) -> usize {
        let take = want.min(self.free.len());
        for _ in 0..take {
            let p = self.free.pop().expect("free list length checked above");
            self.quarantined.push(p);
        }
        take
    }

    /// Pages removed from service by [`Self::shrink`].
    pub fn quarantined_pages(&self) -> usize {
        self.quarantined.len()
    }

    /// Claim a page with one reference; `None` when the pool is exhausted.
    /// Handed-out pages are always zeroed (arenas start zeroed,
    /// [`Self::release`]'s last drop re-zeroes).
    pub fn alloc(&mut self) -> Option<usize> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p], 0, "free page {p} had live references");
        self.refs[p] = 1;
        Some(p)
    }

    /// Add a reference to an already-held page (a sharing row or the
    /// prefix index mapping the same immutable content).
    pub fn retain(&mut self, page: usize) {
        debug_assert!(page < self.total, "retained page {page} out of range");
        assert!(
            self.refs[page] > 0,
            "retain of free KV page {page} (use alloc)"
        );
        self.refs[page] += 1;
    }

    /// Current reference count of `page` (`0` = free).
    pub fn ref_count(&self, page: usize) -> u32 {
        self.refs[page]
    }

    /// Drop one reference to `page`. The page is returned to the free
    /// list — **with its K and V spans zeroed** so no stale keys/values
    /// survive into the next mapping — only when the **last** reference
    /// drops; earlier drops leave the content untouched for the remaining
    /// holders. This keys zeroing to the refcount reaching zero rather
    /// than to any particular call site (`retire_row` / `truncate_row` /
    /// `reset_row` all funnel here), which is what makes those paths safe
    /// to run against shared pages.
    pub fn release(&mut self, page: usize) {
        debug_assert!(page < self.total, "released page {page} out of range");
        debug_assert!(!self.free.contains(&page), "double free of KV page {page}");
        assert!(self.refs[page] > 0, "release of free KV page {page}");
        self.refs[page] -= 1;
        if self.refs[page] == 0 {
            let s = page * self.floats_per_page;
            self.k[s..s + self.floats_per_page].fill(0.0);
            self.v[s..s + self.floats_per_page].fill(0.0);
            self.free.push(page);
        }
    }

    /// K-arena span of `page`.
    pub fn k(&self, page: usize) -> &[f32] {
        &self.k[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// V-arena span of `page`.
    pub fn v(&self, page: usize) -> &[f32] {
        &self.v[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Mutable K-arena span of `page`.
    pub fn k_mut(&mut self, page: usize) -> &mut [f32] {
        &mut self.k[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Mutable V-arena span of `page`.
    pub fn v_mut(&mut self, page: usize) -> &mut [f32] {
        &mut self.v[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Copy `floats` f32s at offset `off` within both arenas from page
    /// `src` to page `dst` (the copy-on-write primitive: the owner of
    /// `dst` gets a private copy of `src`'s span while `src` stays intact
    /// for its remaining holders).
    pub fn copy_span(&mut self, src: usize, dst: usize, off: usize, floats: usize) {
        debug_assert!(off + floats <= self.floats_per_page, "span exceeds page");
        let s = src * self.floats_per_page + off;
        let d = dst * self.floats_per_page + off;
        self.k.copy_within(s..s + floats, d);
        self.v.copy_within(s..s + floats, d);
    }

    /// Pages on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently handed out (distinct pages, however many references
    /// each carries).
    pub fn used_pages(&self) -> usize {
        self.total - self.free.len() - self.quarantined.len()
    }

    /// Pool size in pages (excluding pages quarantined by
    /// [`Self::shrink`]).
    pub fn total_pages(&self) -> usize {
        self.total - self.quarantined.len()
    }

    /// f32s per page per arena.
    pub fn floats_per_page(&self) -> usize {
        self.floats_per_page
    }

    /// Bytes one mapped page holds across both arenas (K + V).
    pub fn page_bytes(&self) -> usize {
        2 * self.floats_per_page * std::mem::size_of::<f32>()
    }

    /// Total in-service arena bytes (all pages, free or mapped; pages
    /// quarantined by [`Self::shrink`] no longer count).
    pub fn pool_bytes(&self) -> usize {
        self.total_pages() * self.page_bytes()
    }

    /// Bytes deduplicated by sharing: `Σ max(refcount − 1, 0) × page_bytes`.
    pub fn shared_bytes(&self) -> usize {
        let extra: usize = self
            .refs
            .iter()
            .map(|&r| (r as usize).saturating_sub(1))
            .sum();
        extra * self.page_bytes()
    }
}

/// A pool-wide page-admission budget shared across worker sessions.
///
/// Each admitted row claims its worst-case page count
/// ([`crate::backend::forward::KvCache`]'s `pages_per_row`) with
/// [`Self::try_claim`] and returns it at retire (or when the owning cache
/// drops — panic unwinding included — so a crashed worker can never strand
/// its share). Workers that attach a ledger run their local pool at full
/// size and let the ledger be the single admission gate, which is what
/// lets one hot worker borrow the headroom an idle worker isn't using.
#[derive(Debug)]
pub struct PageLedger {
    total: usize,
    claimed: AtomicUsize,
}

impl PageLedger {
    /// Ledger holding `total` claimable pages.
    pub fn new(total: usize) -> PageLedger {
        PageLedger {
            total,
            claimed: AtomicUsize::new(0),
        }
    }

    /// Total claimable pages.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Pages currently claimed.
    pub fn claimed(&self) -> usize {
        self.claimed.load(Ordering::Acquire)
    }

    /// Pages still claimable.
    pub fn available(&self) -> usize {
        self.total.saturating_sub(self.claimed())
    }

    /// Atomically claim `n` pages; `false` (claiming nothing) when fewer
    /// than `n` remain.
    pub fn try_claim(&self, n: usize) -> bool {
        let mut cur = self.claimed.load(Ordering::Acquire);
        loop {
            if cur + n > self.total {
                return false;
            }
            match self.claimed.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Return `n` claimed pages to the ledger.
    pub fn release(&self, n: usize) {
        let prev = self.claimed.fetch_sub(n, Ordering::AcqRel);
        debug_assert!(prev >= n, "ledger released {n} pages but held {prev}");
    }
}

/// One cache's claim against a shared [`PageLedger`].
///
/// Dropping the share (the owning cache retiring normally, or unwinding
/// through a worker panic) returns every still-claimed page, so ledger
/// capacity can never be stranded by a crashed worker.
#[derive(Debug)]
pub struct LedgerShare {
    ledger: Arc<PageLedger>,
    claimed: usize,
}

impl LedgerShare {
    /// A zero-claim share against `ledger`.
    pub fn new(ledger: Arc<PageLedger>) -> LedgerShare {
        LedgerShare { ledger, claimed: 0 }
    }

    /// The ledger this share draws from.
    pub fn ledger(&self) -> &Arc<PageLedger> {
        &self.ledger
    }

    /// Pages this share currently holds.
    pub fn claimed(&self) -> usize {
        self.claimed
    }

    /// Claim `n` more pages; `false` if the ledger cannot fund them.
    pub fn try_claim(&mut self, n: usize) -> bool {
        if self.ledger.try_claim(n) {
            self.claimed += n;
            true
        } else {
            false
        }
    }

    /// Return `n` of this share's pages to the ledger.
    pub fn release(&mut self, n: usize) {
        debug_assert!(n <= self.claimed, "share released more than it claimed");
        let n = n.min(self.claimed);
        self.claimed -= n;
        self.ledger.release(n);
    }
}

impl Drop for LedgerShare {
    fn drop(&mut self) {
        if self.claimed > 0 {
            self.ledger.release(self.claimed);
            self.claimed = 0;
        }
    }
}

impl Clone for LedgerShare {
    /// Clones start with **zero** claims: a claim belongs to the cache
    /// instance that made it, so a cloned cache re-claims as it admits
    /// rows rather than double-releasing the original's pages on drop.
    fn clone(&self) -> LedgerShare {
        LedgerShare {
            ledger: Arc::clone(&self.ledger),
            claimed: 0,
        }
    }
}

/// Chained content hash of a tagged token prefix: `hash(tag, len, tokens)`.
/// Used only to narrow [`PrefixIndex`] lookups — every hit is verified by
/// exact token comparison, so collisions can cost a share but never
/// fabricate one.
fn chain_hash<K: Hash>(tag: &K, tokens: &[i32]) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    tag.hash(&mut h);
    tokens.len().hash(&mut h);
    tokens.hash(&mut h);
    h.finish()
}

#[derive(Debug, Clone)]
struct PrefixEntry {
    page: usize,
    /// Positions covered from the window start: `(ordinal + 1) × page`.
    positions: usize,
    /// The registering row's full token window (shared, not copied per
    /// entry); `tokens[..positions]` is this entry's exact content key.
    tokens: Arc<Vec<i32>>,
    /// Last-touched tick for LRU eviction.
    tick: u64,
}

/// Content-addressed index of full KV pages by `(token prefix, row tag)`.
///
/// Every entry maps one **full, immutable** page: the page holding
/// positions `[i × page, (i + 1) × page)` of some row whose window began
/// with `tokens[..(i + 1) × page]` under tag `K` (K/V bytes are a pure
/// function of that pair — positions are cache-absolute — so any row with
/// the same tagged prefix can map the page verbatim). The index holds its
/// own reference to each page ([`KvPagePool::retain`]), which is what
/// keeps a retired session's prefix warm; [`Self::evict_lru`] hands pages
/// back under pressure.
///
/// Chains are looked up page by page and stop at the first miss, so
/// evicting an early page of a chain orphans the later ones — they stay
/// evictable and age out by the same LRU order.
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex<K> {
    entries: HashMap<(u64, K), PrefixEntry>,
    tick: u64,
}

impl<K: Eq + Hash + Copy> PrefixIndex<K> {
    /// An empty index.
    pub fn new() -> PrefixIndex<K> {
        PrefixIndex {
            entries: HashMap::new(),
            tick: 0,
        }
    }

    /// Registered entries (== pages the index retains).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest verified run of indexed full pages matching `tokens` under
    /// `tag`, capped at `max_pages`. Matched entries are LRU-touched. The
    /// caller maps the returned pages (adding its own references) and
    /// prefills only the remainder.
    pub fn lookup(
        &mut self,
        tag: K,
        tokens: &[i32],
        page_positions: usize,
        max_pages: usize,
    ) -> Vec<usize> {
        let mut pages = Vec::new();
        self.tick += 1;
        for i in 0..max_pages {
            let span = (i + 1) * page_positions;
            if span > tokens.len() {
                break;
            }
            let h = chain_hash(&tag, &tokens[..span]);
            match self.entries.get_mut(&(h, tag)) {
                Some(e)
                    if e.positions == span
                        && e.tokens.len() >= span
                        && e.tokens[..span] == tokens[..span] =>
                {
                    e.tick = self.tick;
                    pages.push(e.page);
                }
                _ => break,
            }
        }
        pages
    }

    /// Register a row's full pages under its tagged window. `pages` is the
    /// row's page table; every full-page ordinal (`(i + 1) × page ≤
    /// tokens.len()`) not already indexed is inserted and reported through
    /// `on_retain` so the caller can add the index's page reference.
    /// Already-indexed spans are deduplicated in favor of the existing
    /// entry (and LRU-touched). Returns how many entries were added.
    pub fn register(
        &mut self,
        tag: K,
        tokens: &Arc<Vec<i32>>,
        page_positions: usize,
        pages: &[usize],
        mut on_retain: impl FnMut(usize),
    ) -> usize {
        self.tick += 1;
        let full = (tokens.len() / page_positions).min(pages.len());
        let mut added = 0;
        for (i, &page) in pages.iter().enumerate().take(full) {
            let span = (i + 1) * page_positions;
            let h = chain_hash(&tag, &tokens[..span]);
            use std::collections::hash_map::Entry;
            match self.entries.entry((h, tag)) {
                Entry::Occupied(mut o) => {
                    o.get_mut().tick = self.tick;
                }
                Entry::Vacant(v) => {
                    v.insert(PrefixEntry {
                        page,
                        positions: span,
                        tokens: Arc::clone(tokens),
                        tick: self.tick,
                    });
                    on_retain(page);
                    added += 1;
                }
            }
        }
        added
    }

    /// Number of entries whose page passes `evictable` (typically
    /// "refcount == 1": the index is the only holder).
    pub fn evictable(&self, evictable: impl Fn(usize) -> bool) -> usize {
        self.entries.values().filter(|e| evictable(e.page)).count()
    }

    /// Drop the least-recently-used entry whose page passes `evictable`
    /// and return its page (the caller releases the index's reference).
    /// `None` when no entry qualifies.
    pub fn evict_lru(&mut self, evictable: impl Fn(usize) -> bool) -> Option<usize> {
        let key = self
            .entries
            .iter()
            .filter(|(_, e)| evictable(e.page))
            .min_by_key(|(_, e)| e.tick)
            .map(|(k, _)| *k)?;
        self.entries.remove(&key).map(|e| e.page)
    }

    /// Remove every entry, returning the retained pages for the caller to
    /// release.
    pub fn drain_pages(&mut self) -> Vec<usize> {
        self.entries.drain().map(|(_, e)| e.page).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_accounting_round_trips() {
        let mut pool = KvPagePool::new(3, 8);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.used_pages(), 0);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.alloc(), None, "pool exhausted");
        assert_eq!(pool.used_pages(), 3);
        pool.release(b);
        assert_eq!(pool.free_pages(), 1);
        // LIFO: the page just released is the next handed out.
        assert_eq!(pool.alloc(), Some(b));
        pool.release(a);
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.pool_bytes(), 3 * 2 * 8 * 4);
    }

    #[test]
    fn released_pages_are_zeroed() {
        // The quarantine fix: contents written by one occupant must never
        // be observable after the page returns to the pool.
        let mut pool = KvPagePool::new(2, 4);
        let p = pool.alloc().unwrap();
        pool.k_mut(p).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        pool.v_mut(p).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        pool.release(p);
        let q = pool.alloc().unwrap();
        assert_eq!(q, p, "LIFO hands the same page back");
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "stale K leaked");
        assert!(pool.v(q).iter().all(|&x| x == 0.0), "stale V leaked");
    }

    #[test]
    fn refcounts_zero_only_at_last_drop() {
        // Zero-on-release is keyed to the refcount drop, not the call
        // site: intermediate releases leave content for remaining holders.
        let mut pool = KvPagePool::new(2, 4);
        let p = pool.alloc().unwrap();
        assert_eq!(pool.ref_count(p), 1);
        pool.retain(p);
        pool.retain(p);
        assert_eq!(pool.ref_count(p), 3);
        pool.k_mut(p).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        pool.v_mut(p).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(pool.shared_bytes(), 2 * pool.page_bytes());

        pool.release(p);
        assert_eq!(pool.ref_count(p), 2);
        assert_eq!(pool.free_pages(), 1, "still held, not freed");
        assert_eq!(pool.k(p)[0], 1.0, "content intact for remaining holders");
        pool.release(p);
        assert_eq!(pool.k(p)[3], 4.0, "still intact at one holder");
        assert_eq!(pool.shared_bytes(), 0);

        pool.release(p);
        assert_eq!(pool.ref_count(p), 0);
        assert_eq!(pool.free_pages(), 2, "last drop frees");
        let q = pool.alloc().unwrap();
        assert_eq!(q, p);
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "stale K leaked");
        assert!(pool.v(q).iter().all(|&x| x == 0.0), "stale V leaked");
    }

    #[test]
    fn freed_then_reshared_page_never_leaks_prior_kv() {
        // Regression for the double-zero hazard audit: a page that cycles
        // occupant → shared → fully released → re-allocated must come back
        // zeroed, and the intermediate shared drops must not zero it early.
        let mut pool = KvPagePool::new(1, 4);
        let p = pool.alloc().unwrap();
        pool.k_mut(p).copy_from_slice(&[9.0; 4]);
        pool.retain(p); // second occupant shares it
        pool.release(p); // first occupant leaves — no zero, no free
        assert_eq!(pool.k(p), &[9.0; 4], "shared content survives a release");
        pool.release(p); // last occupant leaves — zero + free
        let q = pool.alloc().unwrap();
        assert_eq!(q, p);
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "prior occupant leaked");
    }

    #[test]
    fn shrink_quarantines_free_pages_only() {
        let mut pool = KvPagePool::new(4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.shrink(10), 3, "only the free pages can go");
        assert_eq!(pool.quarantined_pages(), 3);
        assert_eq!(pool.total_pages(), 1);
        assert_eq!(pool.used_pages(), 1);
        assert_eq!(pool.free_pages(), 0);
        assert_eq!(pool.alloc(), None, "quarantined pages never come back");
        assert_eq!(pool.pool_bytes(), 2 * 2 * 4, "one page in service");
        // The mapped page still releases normally into the shrunken pool.
        pool.release(a);
        assert_eq!(pool.free_pages(), 1);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.alloc(), Some(a));
    }

    #[test]
    fn cfg_env_pin_and_builders() {
        let c = KvPageCfg::with_page(16).budget(5).share(true).retain(7);
        assert_eq!(c.page_positions, 16);
        assert_eq!(c.budget_pages, 5);
        assert!(c.prefix_share);
        assert_eq!(c.retain_pages, 7);
        assert_eq!(KvPageCfg::with_page(0).page_positions, 1, "clamped");
        assert!(!KvPageCfg::with_page(4).prefix_share, "sharing is opt-in");
    }

    #[test]
    fn memory_snapshot_ratios() {
        let m = KvMemory {
            resident_bytes: 256,
            resident_peak_bytes: 512,
            dense_equivalent_bytes: 1024,
            pool_bytes: 512,
            used_pages: 2,
            free_pages: 6,
            total_pages: 8,
            page_positions: 4,
            ..Default::default()
        };
        assert!((m.utilization() - 0.25).abs() < 1e-12);
        assert!((m.resident_over_dense() - 0.25).abs() < 1e-12);
        assert_eq!(KvMemory::default().utilization(), 0.0);
        assert_eq!(KvMemory::default().resident_over_dense(), 0.0);
    }

    #[test]
    fn ledger_claims_release_and_share_drop() {
        let ledger = Arc::new(PageLedger::new(10));
        assert!(ledger.try_claim(6));
        assert!(!ledger.try_claim(5), "only 4 left");
        assert!(ledger.try_claim(4));
        assert_eq!(ledger.available(), 0);
        ledger.release(10);
        assert_eq!(ledger.claimed(), 0);

        // A share returns whatever it still holds when dropped (the
        // worker-panic path), and clones never inherit claims.
        let mut share = LedgerShare::new(Arc::clone(&ledger));
        assert!(share.try_claim(7));
        let clone = share.clone();
        assert_eq!(clone.claimed(), 0, "clones start unclaimed");
        share.release(2);
        assert_eq!(ledger.claimed(), 5);
        drop(share);
        assert_eq!(ledger.claimed(), 0, "drop returned the remainder");
        drop(clone);
        assert_eq!(ledger.claimed(), 0);
    }

    #[test]
    fn ledger_is_safe_across_threads() {
        let ledger = Arc::new(PageLedger::new(64));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let l = Arc::clone(&ledger);
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    for _ in 0..100 {
                        if l.try_claim(2) {
                            got += 2;
                            l.release(2);
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ledger.claimed(), 0, "every claim was returned");
        assert!(ledger.try_claim(64), "full capacity claimable after churn");
    }

    #[test]
    fn prefix_index_chains_verify_and_evict() {
        let mut idx: PrefixIndex<u8> = PrefixIndex::new();
        let pp = 4usize;
        let win: Arc<Vec<i32>> = Arc::new((0..10).collect());
        let mut retained = Vec::new();
        // 10 tokens at page 4 → two full pages (ordinals 0 and 1).
        let added = idx.register(7, &win, pp, &[100, 101, 102], |p| retained.push(p));
        assert_eq!(added, 2);
        assert_eq!(retained, vec![100, 101]);
        assert_eq!(idx.len(), 2);
        // Re-registering the same content dedupes in favor of the
        // existing entries.
        assert_eq!(idx.register(7, &win, pp, &[200, 201], |_| panic!()), 0);

        // Full-chain hit, capped hit, tag miss, content miss.
        let toks: Vec<i32> = (0..9).collect();
        assert_eq!(idx.lookup(7, &toks, pp, 8), vec![100, 101]);
        assert_eq!(idx.lookup(7, &toks, pp, 1), vec![100]);
        assert!(idx.lookup(8, &toks, pp, 8).is_empty(), "tag keys content");
        let mut diverged = toks.clone();
        diverged[2] = 99;
        assert!(idx.lookup(7, &diverged, pp, 8).is_empty());
        let mut late = toks.clone();
        late[6] = 99; // second page diverges; first still matches
        assert_eq!(idx.lookup(7, &late, pp, 8), vec![100]);

        // LRU eviction respects the evictability predicate and order:
        // page 101 was touched by the chain lookups after 100? Both were
        // touched together; re-touch 100 alone via a capped lookup, then
        // evict — 101 is the LRU entry.
        assert_eq!(idx.lookup(7, &toks, pp, 1), vec![100]);
        assert_eq!(idx.evict_lru(|p| p != 101), Some(100), "predicate gates");
        assert_eq!(idx.evict_lru(|_| true), Some(101));
        assert!(idx.evict_lru(|_| true).is_none());
        assert!(idx.is_empty());

        // drain_pages returns everything for release.
        idx.register(7, &win, pp, &[100, 101], |_| {});
        let mut drained = idx.drain_pages();
        drained.sort_unstable();
        assert_eq!(drained, vec![100, 101]);
        assert!(idx.is_empty());
    }
}
