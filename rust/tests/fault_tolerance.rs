//! Fault-injection suite for the serving runtime: deterministic worker
//! panics, stalls, and KV-budget shrinks driven through
//! [`mfqat::server::FaultPlan`], plus deadline / cancellation /
//! backpressure behaviour under those faults.
//!
//! The invariants proved here are the serving robustness contract:
//!
//! * a worker panic mid-decode fails its in-flight rows fast (no hangs),
//!   leaves every surviving row **bit-identical** to an unfaulted run,
//!   returns the KV free list to baseline, and the respawned worker
//!   serves new traffic;
//! * a stalled worker trips request deadlines instead of wedging the
//!   server;
//! * a shrinking KV page budget degrades admission, never decode output;
//! * cancellation retires rows mid-flight; the bounded queue rejects with
//!   a typed retry hint;
//! * a worker that dies holding cross-worker page-ledger claims returns
//!   them through unwinding — a crash never strands the page economy, and
//!   shared-prefix pages on surviving workers stay intact.
//!
//! Runs everywhere — the native backend needs no AOT artifacts.

use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::SampleCfg;
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::server::{FaultKind, FaultPlan, Policy, Rejected, Server, ServerConfig, SubmitOpts};
use std::time::Duration;

/// Small dims so the suite stays fast; vocab 256 so the generation lane
/// can encode byte prompts.
fn test_dims() -> ModelDims {
    let mut dims = ModelDims::new("flt", 256, 32, 2, 2, 16);
    dims.train_batch = 4;
    dims
}

fn base_config() -> ServerConfig {
    ServerConfig {
        policy: Policy::Fixed(ElementFormat::int(8)),
        gather_window: Duration::from_millis(1),
        // Explicit `None` so a stray MFQAT_FAULT in the environment can
        // never leak into tests that arm their own plans.
        faults: None,
        ..ServerConfig::default()
    }
}

fn start(seed: u64, config: ServerConfig) -> (Server, mfqat::server::Client) {
    let dims = test_dims();
    let (server, client) = Server::start(
        dims.seq_len + 1,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, seed);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 64 << 20)
        },
        config,
    )
    .unwrap();
    (server, client)
}

fn sample_cfg() -> SampleCfg {
    SampleCfg {
        temperature: 0.7,
        top_k: 6,
        seed: 11,
    }
}

/// The generation workload every fault run is compared against.
const JOBS: &[(&str, usize)] = &[
    ("kova", 8),
    ("blue", 8),
    ("the color", 8),
    ("q", 8),
    ("kovaq", 8),
    ("mixed", 8),
];

/// Ground truth from an unfaulted server: per-row determinism guarantees
/// each (prompt, cfg, budget) samples identically however it is batched,
/// so solo runs are a valid reference for faulted bursts.
fn reference_texts(seed: u64) -> Vec<String> {
    let (server, client) = start(seed, base_config());
    let texts = JOBS
        .iter()
        .map(|(p, n)| client.generate(p, *n, None, sample_cfg()).unwrap().text)
        .collect();
    drop(client);
    server.shutdown();
    texts
}

#[test]
fn worker_panic_fails_fast_and_respawn_serves_identically() {
    let seed = 31;
    let reference = reference_texts(seed);
    let mut cfg = base_config();
    cfg.faults = Some(FaultPlan::single(0, 3, FaultKind::Panic));
    let (server, client) = start(seed, cfg);

    // Burst all jobs so rows are in flight when the panic fires at decode
    // step 3 (each row wants 8 steps, so the window cannot be missed).
    let rxs: Vec<_> = JOBS
        .iter()
        .map(|(p, n)| client.submit_generate(p, *n, None, sample_cfg()).unwrap())
        .collect();
    let mut failed = 0usize;
    for (rx, ((prompt, _), want)) in rxs.into_iter().zip(JOBS.iter().zip(&reference)) {
        // Every request must resolve promptly — a hang here is the bug.
        let res = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("request hung after worker panic");
        match res {
            Ok(resp) => assert_eq!(&resp.text, want, "surviving row {prompt:?} diverged"),
            Err(e) => {
                assert!(e.contains("panicked"), "row {prompt:?}: unexpected error {e:?}");
                failed += 1;
            }
        }
    }
    assert!(failed >= 1, "the injected panic must fail at least one in-flight row");

    // The respawned incarnation serves fresh traffic, bit-identically.
    let again = client.generate(JOBS[0].0, JOBS[0].1, None, sample_cfg()).unwrap();
    assert_eq!(again.text, reference[0], "post-respawn traffic diverged");

    let m = client.metrics_snapshot();
    assert_eq!(m.worker_panics, 1, "exactly the injected panic");
    assert_eq!(m.worker_restarts, 1, "supervisor respawned the worker");

    let obs = server.obs();
    drop(client);
    server.shutdown();
    let m = obs.snapshot();
    assert_eq!(m.kv.used_pages, 0, "KV pages leaked across the panic: {:?}", m.kv);
}

#[test]
fn stall_fault_trips_deadlines_without_wedging_the_server() {
    let mut cfg = base_config();
    cfg.faults = Some(FaultPlan::single(0, 1, FaultKind::Stall(Duration::from_millis(250))));
    let (server, client) = start(33, cfg);

    // The 40ms deadline expires inside the 250ms stall; the next row sweep
    // must retire the request instead of letting it ride the wedged step.
    let opts = SubmitOpts {
        deadline: Some(Duration::from_millis(40)),
        cancel: None,
    };
    let pending = client
        .submit_generate_opts("kova", 16, None, sample_cfg(), &opts)
        .unwrap();
    let err = pending
        .rx
        .recv_timeout(Duration::from_secs(10))
        .expect("request hung through the stall")
        .expect_err("deadline must trip during the stall");
    assert!(err.contains("deadline exceeded"), "unexpected error: {err:?}");

    // The stalled worker recovers and serves later traffic normally.
    let ok = client.generate("kova", 4, None, sample_cfg()).unwrap();
    assert_eq!(ok.text.chars().count(), 4);

    let m = client.metrics_snapshot();
    assert!(m.deadline_misses >= 1, "miss must be counted");
    assert_eq!(m.worker_panics, 0, "a stall is not a crash");
    drop(client);
    server.shutdown();
}

#[test]
fn shrink_fault_degrades_admission_never_decode_output() {
    let seed = 35;
    let reference = reference_texts(seed);
    let mut cfg = base_config();
    // Tiny pages so the shrink quarantine moves a meaningful fraction of
    // the pool while committed (live-row) pages stay protected.
    cfg.kv_page = mfqat::backend::KvPageCfg::with_page(4);
    cfg.faults = Some(FaultPlan::single(0, 2, FaultKind::ShrinkPages(8)));
    let (server, client) = start(seed, cfg);

    let rxs: Vec<_> = JOBS
        .iter()
        .map(|(p, n)| client.submit_generate(p, *n, None, sample_cfg()).unwrap())
        .collect();
    for (rx, ((prompt, _), want)) in rxs.into_iter().zip(JOBS.iter().zip(&reference)) {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("request hung under a shrunk pool")
            .unwrap_or_else(|e| panic!("row {prompt:?} failed under shrink: {e:?}"));
        assert_eq!(&resp.text, want, "shrink changed decode output for {prompt:?}");
    }
    let obs = server.obs();
    drop(client);
    server.shutdown();
    assert_eq!(obs.snapshot().kv.used_pages, 0, "pages leaked under shrink");
}

#[test]
fn cancellation_retires_rows_mid_flight() {
    let mut cfg = base_config();
    // Wedge the first decode step so the cancel provably lands while the
    // row is mid-flight, not before admission.
    cfg.faults = Some(FaultPlan::single(0, 1, FaultKind::Stall(Duration::from_millis(300))));
    let (server, client) = start(37, cfg);

    // Token-based cancel through the Pending handle.
    let p1 = client
        .submit_generate_opts("kova", 16, None, sample_cfg(), &SubmitOpts::default())
        .unwrap();
    std::thread::sleep(Duration::from_millis(60));
    p1.cancel.cancel();
    let err = p1
        .rx
        .recv_timeout(Duration::from_secs(10))
        .expect("cancelled request hung")
        .expect_err("cancelled request must error");
    assert!(err.contains("cancelled"), "unexpected error: {err:?}");

    // Id-based cancel through the client registry.
    let p2 = client
        .submit_generate_opts("blue", 16, None, sample_cfg(), &SubmitOpts::default())
        .unwrap();
    assert!(client.cancel(p2.id), "token must still be live");
    let err = p2
        .rx
        .recv_timeout(Duration::from_secs(10))
        .expect("cancelled request hung")
        .expect_err("cancelled request must error");
    assert!(err.contains("cancelled"), "unexpected error: {err:?}");
    assert!(!client.cancel(u64::MAX), "unknown id is a no-op");

    let m = client.metrics_snapshot();
    assert!(m.cancellations >= 2, "both cancels counted, got {}", m.cancellations);

    let obs = server.obs();
    drop(client);
    server.shutdown();
    assert_eq!(obs.snapshot().kv.used_pages, 0, "cancelled rows must return their pages");
}

#[test]
fn bounded_queue_rejects_with_typed_retry_hint() {
    let mut cfg = base_config();
    cfg.queue_cap = 2;
    cfg.faults = Some(FaultPlan::single(0, 1, FaultKind::Stall(Duration::from_millis(400))));
    let (server, client) = start(39, cfg);
    let row = vec![7i32; test_dims().seq_len + 1];

    // Wedge the worker on a generation, then flood the bounded queue: the
    // first `queue_cap` submissions park, the rest are turned away with a
    // typed [`Rejected`] carrying a clamped retry hint.
    let busy = client.submit_generate("kova", 4, None, sample_cfg()).unwrap();
    std::thread::sleep(Duration::from_millis(80));
    let mut accepted = Vec::new();
    let mut rejections = 0usize;
    let hint_bounds = Duration::from_millis(5)..=Duration::from_secs(2);
    for _ in 0..8 {
        match client.submit(&row, None) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                let r = e
                    .downcast_ref::<Rejected>()
                    .expect("backpressure error is typed");
                assert!(hint_bounds.contains(&r.retry_after), "bad hint {:?}", r.retry_after);
                rejections += 1;
            }
        }
    }
    assert!(rejections >= 1, "an 8-burst against queue_cap=2 must shed");
    assert!(!accepted.is_empty(), "the queue still admits up to its cap");

    // Shedding is load protection, not an outage: everything admitted
    // completes once the stall clears.
    for rx in accepted {
        rx.recv_timeout(Duration::from_secs(10))
            .expect("accepted request hung")
            .expect("accepted request must complete");
    }
    busy.recv_timeout(Duration::from_secs(10))
        .expect("generation hung")
        .expect("generation must complete");
    assert!(client.metrics_snapshot().rejections >= 1, "rejections counted");
    drop(client);
    server.shutdown();
}

#[test]
fn worker_panic_releases_ledger_claims_and_shared_pages() {
    // The page economy's crash contract, deterministically: a "worker"
    // (a continuous batch drawing on the shared ledger) that panics
    // mid-decode returns every outstanding claim through unwinding — the
    // survivor keeps its claim, its shared-prefix pages, and its exact
    // decode; nothing is stranded and nothing is double-released.
    use mfqat::backend::{KvPageCfg, NativeWeights, PageLedger};
    use mfqat::eval::generate::{generate_native, ContinuousBatch};
    use std::sync::Arc;

    let dims = test_dims();
    let manifest = dims.to_manifest();
    let ck = ParamSet::init(&manifest, 41)
        .to_anchor_checkpoint(&manifest, ElementFormat::int(8))
        .unwrap();
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let cfg = sample_cfg();
    let ppr = dims.seq_len.div_ceil(4);
    let ledger = Arc::new(PageLedger::new(2 * ppr));

    let kv = KvPageCfg::with_page(4).share(true);
    let mut survivor: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 2, kv);
    survivor.attach_kv_ledger(Arc::clone(&ledger));
    survivor.join(&w, "the colo", 2, &cfg).unwrap();
    assert_eq!(ledger.claimed(), ppr);

    // The doomed worker claims the rest, prefills (indexing its prefix
    // pages), then its body panics mid-decode.
    std::thread::scope(|s| {
        let h = s.spawn(|| {
            let mut cb: ContinuousBatch<&NativeWeights> =
                ContinuousBatch::with_kv(&dims, 2, KvPageCfg::with_page(4).share(true));
            cb.attach_kv_ledger(Arc::clone(&ledger));
            cb.join(&w, "kovaq blue", 8, &cfg).unwrap();
            cb.step().unwrap();
            assert_eq!(ledger.claimed(), 2 * ppr, "both workers hold claims");
            panic!("injected worker crash");
        });
        assert!(h.join().is_err(), "the worker must crash");
    });

    // Unwinding released exactly the dead worker's claims — retained
    // prefix-index pages and all — and only those.
    assert_eq!(ledger.claimed(), ppr, "a crash must not strand (or over-release) claims");

    // The survivor's rows and shared pages are untouched.
    let mut steps = 0usize;
    let mut done = Vec::new();
    while survivor.active() > 0 {
        done.extend(survivor.step().unwrap());
        steps += 1;
        assert!(steps < 1000, "decode did not converge");
    }
    assert_eq!(done.len(), 1);
    assert_eq!(
        done[0].text,
        generate_native(&w, "the colo", 2, &cfg).unwrap(),
        "the peer's crash corrupted the survivor's decode"
    );
    assert_eq!(ledger.claimed(), 0, "drained survivor must hold no claims");
    drop(survivor);
    assert_eq!(ledger.claimed(), 0, "drop must not double-release");
}

#[test]
fn panic_under_page_ledger_respawns_and_readmits() {
    // End-to-end: a 2-worker continuous server pooling its KV budgets
    // into one cross-worker ledger (with prefix sharing on) takes a
    // worker panic mid-burst. Every request resolves — survivors
    // bit-identical, victims with a typed panic error — and the respawned
    // worker re-admits a full second burst, which it could not do if the
    // crash had stranded ledger claims.
    let seed = 43;
    let reference = reference_texts(seed);
    let mut cfg = base_config();
    cfg.workers = 2;
    cfg.kv_page = mfqat::backend::KvPageCfg::with_page(4).budget(8).share(true);
    cfg.faults = Some(FaultPlan::single(0, 2, FaultKind::Panic));
    let (server, client) = start(seed, cfg);

    let rxs: Vec<_> = JOBS
        .iter()
        .map(|(p, n)| client.submit_generate(p, *n, None, sample_cfg()).unwrap())
        .collect();
    for (rx, ((prompt, _), want)) in rxs.into_iter().zip(JOBS.iter().zip(&reference)) {
        let res = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("request hung after worker panic under the ledger");
        match res {
            Ok(resp) => assert_eq!(&resp.text, want, "surviving row {prompt:?} diverged"),
            Err(e) => assert!(e.contains("panicked"), "row {prompt:?}: unexpected error {e:?}"),
        }
    }

    // A stranded ledger would leave this burst deferred forever; the
    // 30s timeout is the tripwire.
    let rxs: Vec<_> = JOBS
        .iter()
        .map(|(p, n)| client.submit_generate(p, *n, None, sample_cfg()).unwrap())
        .collect();
    for (rx, ((prompt, _), want)) in rxs.into_iter().zip(JOBS.iter().zip(&reference)) {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("post-respawn request hung: ledger claims were stranded")
            .unwrap_or_else(|e| panic!("post-respawn row {prompt:?} failed: {e:?}"));
        assert_eq!(&resp.text, want, "post-respawn row {prompt:?} diverged");
    }
    // The queue race decides whether worker 0 saw enough decode steps to
    // trip its fault; whenever it did, the supervisor must have respawned
    // it (claim release on unwind is proven deterministically above).
    let m = client.metrics_snapshot();
    assert_eq!(m.worker_restarts, m.worker_panics, "every panic must respawn its worker");

    let obs = server.obs();
    drop(client);
    server.shutdown();
    assert_eq!(obs.snapshot().kv.used_pages, 0, "pages leaked across the ledger panic");
}
