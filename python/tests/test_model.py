"""L2 model tests: shapes, causality, quantizer wiring, STE gradients."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import formats as F
from compile import model as M
from compile.kernels import ref


CFG = M.ModelConfig("unit", vocab=64, d_model=32, n_layers=2, n_heads=2,
                    seq_len=16, block_size=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_param_specs_cover_model():
    specs = M.param_specs(CFG)
    names = [s.name for s in specs]
    assert names[0] == "emb" and names[-1] == "head"
    assert len([s for s in specs if s.quantized]) == 4 * CFG.n_layers
    # lm_head and embeddings are excluded from quantization (paper 3.2).
    by_name = {s.name: s for s in specs}
    assert not by_name["head"].quantized
    assert not by_name["emb"].quantized
    assert by_name["l0.qkv"].quantized
    # Quantized last dims are block-aligned.
    for s in specs:
        if s.quantized:
            assert s.shape[-1] % CFG.block_size == 0, s


def test_forward_shapes(params):
    tokens = jnp.zeros((3, CFG.seq_len), jnp.int32)
    logits = M.forward(params, tokens, CFG)
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, CFG.vocab, size=(1, CFG.seq_len)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % CFG.vocab
    l1 = np.asarray(M.forward(params, jnp.asarray(t1), CFG))
    l2 = np.asarray(M.forward(params, jnp.asarray(t2), CFG))
    assert np.array_equal(l1[0, :-1], l2[0, :-1]), "causal mask violated"
    assert not np.array_equal(l1[0, -1], l2[0, -1])


def test_nll_close_to_uniform_at_init(params):
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, CFG.vocab, size=(4, CFG.seq_len + 1)).astype(np.int32)
    nll = float(M.nll_loss(params, jnp.asarray(tokens), CFG))
    assert abs(nll - np.log(CFG.vocab)) < 0.5


def test_quantizer_wiring_changes_output(params):
    tokens = jnp.zeros((1, CFG.seq_len), jnp.int32)
    base = np.asarray(M.forward(params, tokens, CFG))
    wq = M.make_weight_quantizer(F.mxint(2), None, CFG.block_size)
    quant = np.asarray(M.forward(params, tokens, CFG, wq=wq))
    assert not np.array_equal(base, quant), "int2 fake-quant must alter logits"
    # And the quantized forward equals manually fake-quantizing the weights.
    manual = dict(params)
    for s in M.param_specs(CFG):
        if s.quantized:
            manual[s.name] = ref.fake_quantize(params[s.name], F.mxint(2), CFG.block_size)
    want = np.asarray(M.forward(manual, tokens, CFG))
    assert np.allclose(quant, want, atol=1e-6)


def test_anchor_composition_equals_ss(params):
    """The 3.5 training transform Q_A->t(Q_A(W)) == value-level SS."""
    w = params["l0.up"]
    wq = M.make_weight_quantizer(F.mxint(3), F.mxint(8), CFG.block_size)
    got = np.asarray(wq(w))
    anchored = ref.fake_quantize(w, F.mxint(8), CFG.block_size)
    want = np.asarray(ref.ss_fake_quantize(anchored, F.mxint(8), F.mxint(3),
                                           CFG.block_size))
    assert np.array_equal(got, want)


def test_ste_gradient_is_identity(params):
    wq = M.make_weight_quantizer(F.mxint(4), None, CFG.block_size)
    w = params["l0.proj"]

    def f(w):
        return jnp.sum(wq(w) * 3.0)

    g = np.asarray(jax.grad(f)(w))
    assert np.allclose(g, 3.0), "STE must pass gradients through unchanged"


def test_grads_flow_to_quantized_weights_only_through_nll(params):
    tokens = jnp.zeros((2, CFG.seq_len + 1), jnp.int32)
    wq = M.make_weight_quantizer(F.mxint(4), None, CFG.block_size)

    def loss(qkv):
        p = dict(params)
        p["l0.qkv"] = qkv
        return M.nll_loss(p, tokens, CFG, wq=wq)

    g = np.asarray(jax.grad(loss)(params["l0.qkv"]))
    assert np.isfinite(g).all()
    assert np.abs(g).max() > 0.0


def test_flat_roundtrip(params):
    flat = M.flat_from_params(CFG, params)
    back = M.params_from_flat(CFG, flat)
    for name in params:
        assert np.array_equal(np.asarray(params[name]), np.asarray(back[name]))


def test_configs_are_block_aligned():
    for cfg in M.CONFIGS.values():
        for s in M.param_specs(cfg):
            if s.quantized:
                assert s.shape[-1] % cfg.block_size == 0, (cfg.name, s)
        assert cfg.d_model % cfg.n_heads == 0
