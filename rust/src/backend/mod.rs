//! Pluggable inference backends.
//!
//! The elastic coordinator ([`crate::coordinator::ElasticEngine`]) executes
//! batches through a [`Backend`]:
//!
//! * [`NativeBackend`] — pure-Rust CPU engine ([`kernels`], [`forward`])
//!   that computes directly on packed MX codes. Weights are held in a
//!   block-major repacked layout ([`repack::RepackedMx`], built at
//!   `FormatCache` insert time) and consumed by two pipelines: an exact
//!   f32 tile kernel, and — opt-in via [`forward::ActMode::Int8`] — an
//!   integer-MAC pipeline that quantizes activations to i8 per MX block
//!   and accumulates code×code dots in i32/i16 with one combined E8M0
//!   scale per block, its per-tile MACs dispatched to explicit AVX2/NEON
//!   kernels ([`simd`]) with a bit-identical portable fallback
//!   (`MFQAT_SIMD=off`). Generation decodes incrementally through a
//!   per-layer **paged** KV cache holding `rows ≥ 1` step-synchronized
//!   sequences ([`forward::KvCache`] over a [`kvpool::KvPagePool`]:
//!   resident memory tracks live context in fixed-size pages, admission
//!   can be budgeted in pages — [`forward::forward_cached_batch`],
//!   [`DecodeSession::kv_memory`]), exposed batched via
//!   [`Backend::generate_batch`]. Needs only an anchor checkpoint + model
//!   dims: no XLA install, no AOT artifacts.
//! * `PjrtBackend` (feature `pjrt`) — wraps the PJRT runtime and the AOT
//!   HLO artifacts exported by `python/compile/aot.py`; formats execute as
//!   dequantized-f32 weight literals through one compiled graph.
//!
//! Both cache derived per-format weight sets in a byte-bounded LRU
//! ([`crate::coordinator::FormatCache`]); the native cache holds *packed*
//! weights and `Arc`-shares the unquantized f32 parameters across entries,
//! so a cached low-bit format costs only its packed planes.

pub mod forward;
pub mod kernels;
pub mod kvpool;
pub mod native;
pub mod repack;
pub mod simd;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use forward::{ActMode, KvCache, LayerWeights, Mat, NativeWeights, RowTag, SharedParams};
pub use kvpool::{
    KvFormat, KvMemory, KvPageCfg, KvPageLayout, KvPagePool, PageLedger, PrefixIndex,
    KV_SCALE_BLOCK,
};
pub use native::{NativeBackend, NativeDecodeSession};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use repack::RepackedMx;
pub use simd::SimdLevel;

use crate::coordinator::format_cache::CacheStats;
use crate::formats::ElementFormat;
use crate::model::ModelDims;
use anyhow::Result;

/// An inference engine that can score token batches at any element format.
///
/// Implementations must be `Send + Sync`: the server's worker pool shares
/// **one** backend — weight cache included — across its worker threads via
/// `Arc`, so concurrent `score_batch`/`generate*` calls from different
/// threads must be safe (the native backend guards its `FormatCache` with
/// a mutex and computes on immutable `Arc`'d weight sets; the stubbed PJRT
/// types are plain data).
pub trait Backend: Send + Sync {
    /// Short identifier (`"native"`, `"pjrt"`) for logs and metrics.
    fn name(&self) -> &'static str;

    /// Model dimensions this backend serves.
    fn dims(&self) -> &ModelDims;

    /// Forward pass on a flat buffer of `seq_len`-wide token rows;
    /// returns flat logits `[rows, seq_len, vocab]`. The native backend
    /// accepts any row count; PJRT executes its fixed `train_batch` graph.
    fn forward_logits(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>>;

    /// Per-row mean NLL for a flat buffer of `1..=train_batch` token
    /// windows of width `seq_len + 1`; returns one NLL per window. Short
    /// batches execute at their true size on the native backend (the PJRT
    /// graph pads internally to its fixed shape).
    fn score_batch(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>>;

    /// Weight-cache counters (hits/misses/evictions/bytes).
    fn cache_stats(&self) -> CacheStats;

    /// Sampled text continuation at `fmt`. The native backend serves this
    /// through KV-cached incremental decode; backends without a generation
    /// surface return an error.
    fn generate(
        &self,
        prompt: &str,
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &crate::eval::generate::SampleCfg,
    ) -> Result<String> {
        let _ = (prompt, fmt, n_tokens, cfg);
        anyhow::bail!("backend '{}' has no generation surface", self.name())
    }

    /// Sampled continuations for several prompts at `fmt`, decoded
    /// step-synchronized through one batched KV cache. Token-identical to
    /// calling [`Backend::generate`] once per prompt on the native backend
    /// (one weight-streaming pass per step serves the whole batch);
    /// backends without a generation surface return an error.
    fn generate_batch(
        &self,
        prompts: &[&str],
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &crate::eval::generate::SampleCfg,
    ) -> Result<Vec<String>> {
        let _ = (prompts, fmt, n_tokens, cfg);
        anyhow::bail!("backend '{}' has no batched generation surface", self.name())
    }

    /// Open a continuous-batching decode session with `slots` sequence
    /// rows. The session admits prompts *per row, at any step, each with
    /// its own element format* ([`DecodeSession::join`]) and advances all
    /// live rows one token per [`DecodeSession::step`] — the serving
    /// runtime's generate lane drives one of these per worker. Backends
    /// without an incremental-decode surface return an error (the server
    /// then falls back to gather batching).
    fn decode_session(&self, slots: usize) -> Result<Box<dyn DecodeSession + '_>> {
        let _ = slots;
        anyhow::bail!("backend '{}' has no continuous-decode surface", self.name())
    }

    /// [`Backend::decode_session`] with an explicit KV page-pool sizing
    /// (page granularity + optional page budget below the dense-equivalent
    /// allocation — see [`KvPageCfg`]). The default implementation ignores
    /// the sizing and defers to [`Backend::decode_session`], so backends
    /// without paged KV storage keep working unchanged.
    fn decode_session_cfg(
        &self,
        slots: usize,
        kv: KvPageCfg,
    ) -> Result<Box<dyn DecodeSession + '_>> {
        let _ = kv;
        self.decode_session(slots)
    }
}

/// A continuously batched decode in flight: per-row sequences that join,
/// step and finish independently while sharing every step-synchronized
/// forward pass. Rows may run **different element formats** in the same
/// step; each row's tokens are identical to a solo [`Backend::generate`]
/// call at that row's format (see
/// [`crate::eval::generate::ContinuousBatch`], the native implementation).
pub trait DecodeSession {
    /// Total sequence rows (live + free).
    fn capacity(&self) -> usize;

    /// Rows currently decoding.
    fn active(&self) -> usize;

    /// Admit a prompt at `fmt` into a free row (prefill happens on the
    /// next [`Self::step`]); returns the claimed slot index, or an error
    /// when every row is live or the format cannot be derived.
    fn join(
        &mut self,
        prompt: &str,
        fmt: ElementFormat,
        n_tokens: usize,
        cfg: &crate::eval::generate::SampleCfg,
    ) -> Result<usize>;

    /// [`Self::join`] with self-speculative decoding: the row drafts up to
    /// `spec.k` tokens per step at `spec.draft_format` (same anchor
    /// parameters, cheaper format) and verifies them in one multi-position
    /// pass at `fmt`, rolling its KV back past rejected drafts — emitted
    /// tokens are unchanged under the greedy policy, only throughput
    /// improves (see [`crate::eval::generate::SpecCfg`]). The default
    /// implementation ignores `spec` and decodes plainly, so backends
    /// without a speculative surface keep working; the native session
    /// drafts for real.
    fn join_spec(
        &mut self,
        prompt: &str,
        fmt: ElementFormat,
        spec: &crate::eval::generate::SpecCfg,
        n_tokens: usize,
        cfg: &crate::eval::generate::SampleCfg,
    ) -> Result<usize> {
        let _ = spec;
        self.join(prompt, fmt, n_tokens, cfg)
    }

    /// Cancel the sequence in `slot` without a result; the row frees
    /// immediately and surviving rows are unaffected.
    fn cancel(&mut self, slot: usize) -> Result<()>;

    /// Advance every live row by one step-synchronized pass; returns the
    /// rows that completed (their slots are free for the next join).
    fn step(&mut self) -> Result<Vec<crate::eval::generate::FinishedRow>>;

    /// [`Self::step`] plus one [`crate::eval::generate::RowStepEvent`] per
    /// fed row attributing what its chunk was (prefill / decode / overflow
    /// re-prefill) — the hook behind the serving runtime's lifecycle
    /// traces. The default implementation steps without attribution (an
    /// empty event list), so backends without per-row bookkeeping keep
    /// working; the native session reports real events.
    fn step_with_events(
        &mut self,
    ) -> Result<(
        Vec<crate::eval::generate::FinishedRow>,
        Vec<crate::eval::generate::RowStepEvent>,
    )> {
        Ok((self.step()?, Vec::new()))
    }

    /// Whether [`Self::join`] can admit another sequence **right now** —
    /// a free row *and*, on paged-KV backends, enough unclaimed pool pages
    /// to fund the new row's worst-case window. The serving runtime defers
    /// queued prompts while this is false instead of failing them. Default:
    /// slot-count admission (non-paged backends).
    fn can_admit(&self) -> bool {
        self.active() < self.capacity()
    }

    /// Paged-KV accounting for this session (resident vs dense-equivalent
    /// bytes, pool utilization). Backends without paged storage report the
    /// zero snapshot.
    fn kv_memory(&self) -> KvMemory {
        KvMemory::default()
    }

    /// Shrink this session's KV page budget mid-run by up to `pages` free
    /// pages (the fault-injection harness's memory-pressure lever). Paged
    /// backends clamp the shrink so **live rows keep their guaranteed
    /// growth room** — only future admissions feel the squeeze; backends
    /// without paged storage ignore the request. Returns the pages
    /// actually removed from service.
    fn shrink_kv_budget(&mut self, pages: usize) -> usize {
        let _ = pages;
        0
    }

    /// Attach a cross-worker KV page ledger: [`Self::can_admit`] and
    /// [`Self::join`] then claim each admitted row's worst-case page count
    /// from the shared [`PageLedger`] instead of (only) the local pool
    /// budget, so admission trades memory between workers under skewed
    /// load. Claims return at retire or when the session drops — panic
    /// unwinding included. Backends without paged storage ignore the
    /// ledger.
    fn attach_kv_ledger(&mut self, ledger: std::sync::Arc<PageLedger>) {
        let _ = ledger;
    }
}
