//! Streaming statistics and fixed-bucket latency histograms.
//!
//! Used by the serving metrics ([`crate::server::metrics`]), the experiment
//! reports, and the bench harness.

/// Online mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Running {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Rebuild an accumulator from aggregate parts — the bridge from the
    /// lock-free [`crate::obs::AtomicRunning`] (which accumulates
    /// `sum`/`sumsq` atomically) back to this snapshot type. `m2` is the
    /// sum of squared deviations (`sumsq - sum²/n`).
    pub fn from_parts(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Running {
        if n == 0 {
            return Running::new();
        }
        Running {
            n,
            mean,
            m2: m2.max(0.0),
            min,
            max,
        }
    }

    /// Push one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Log-scaled latency histogram from 1µs to ~100s, plus exact quantiles over a
/// bounded reservoir.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    reservoir: Vec<f64>,
    cap: usize,
    seen: u64,
    rng_state: u64,
}

const BUCKETS_PER_DECADE: usize = 10;
const DECADES: usize = 8; // 1e-6 .. 1e2 seconds

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// Number of buckets in the fixed log-scaled layout: one underflow
    /// bucket (≤ 1µs), [`Self::N_BUCKETS`]` - 2` log buckets covering
    /// 1µs..100s at 10 per decade, and one overflow bucket. Shared with the
    /// lock-free [`crate::obs::Hist`] so atomic bucket counts round-trip
    /// through [`Self::from_bucket_counts`] losslessly.
    pub const N_BUCKETS: usize = BUCKETS_PER_DECADE * DECADES + 2;

    /// Empty histogram.
    pub fn new() -> LatencyHist {
        LatencyHist {
            buckets: vec![0; Self::N_BUCKETS],
            reservoir: Vec::new(),
            cap: 4096,
            seen: 0,
            rng_state: 0x1234_5678_9abc_def0,
        }
    }

    /// Rebuild a histogram from raw per-bucket counts (layout of
    /// [`Self::bucket_index`]). The reservoir is empty, so
    /// [`Self::quantile`] answers from bucket midpoints — exact to within
    /// one bucket width (~26% at 10 buckets/decade).
    pub fn from_bucket_counts(counts: &[u64]) -> LatencyHist {
        let mut h = LatencyHist::new();
        let n = counts.len().min(h.buckets.len());
        h.buckets[..n].copy_from_slice(&counts[..n]);
        h.seen = h.buckets.iter().sum();
        h
    }

    /// Bucket index for a latency sample (seconds) in the fixed layout.
    pub fn bucket_index(secs: f64) -> usize {
        if secs <= 1e-6 {
            return 0;
        }
        let log = (secs / 1e-6).log10(); // decades above 1µs
        let idx = 1 + (log * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(Self::N_BUCKETS - 1)
    }

    /// Upper bound (seconds) of bucket `idx`; `f64::INFINITY` for the
    /// overflow bucket. Used by the Prometheus exposition's `le` labels.
    pub fn bucket_bound(idx: usize) -> f64 {
        if idx >= Self::N_BUCKETS - 1 {
            return f64::INFINITY;
        }
        1e-6 * 10f64.powf(idx as f64 / BUCKETS_PER_DECADE as f64)
    }

    /// Representative value (seconds) for bucket `idx`: the lower edge for
    /// the underflow bucket, the geometric midpoint for log buckets, and
    /// the lower bound for the overflow bucket.
    fn bucket_value(idx: usize) -> f64 {
        if idx == 0 {
            return 1e-6;
        }
        if idx >= Self::N_BUCKETS - 1 {
            return 1e-6 * 10f64.powf((Self::N_BUCKETS - 2) as f64 / BUCKETS_PER_DECADE as f64);
        }
        1e-6 * 10f64.powf((idx as f64 - 0.5) / BUCKETS_PER_DECADE as f64)
    }

    /// Raw per-bucket counts (layout of [`Self::bucket_index`]).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_index(secs)] += 1;
        self.seen += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(secs);
        } else {
            // Reservoir sampling (xorshift64*).
            self.rng_state ^= self.rng_state >> 12;
            self.rng_state ^= self.rng_state << 25;
            self.rng_state ^= self.rng_state >> 27;
            let r = (self.rng_state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 32) as u64;
            let j = (r % self.seen) as usize;
            if j < self.cap {
                self.reservoir[j] = secs;
            }
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.seen
    }

    /// Quantile over the reservoir (exact for <= cap samples). A histogram
    /// rebuilt from bucket counts alone ([`Self::from_bucket_counts`]) has
    /// no reservoir and answers from bucket midpoints instead.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            if self.seen == 0 {
                return 0.0;
            }
            let target = ((self.seen - 1) as f64 * q).round() as u64;
            let mut cum = 0u64;
            for (i, &c) in self.buckets.iter().enumerate() {
                cum += c;
                if cum > target {
                    return Self::bucket_value(i);
                }
            }
            return Self::bucket_value(Self::N_BUCKETS - 1);
        }
        let mut v = self.reservoir.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx]
    }

    /// One-line `n`/`p50`/`p95`/`p99` summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} p50={} p95={} p99={}",
            self.seen,
            super::timer::fmt_time(self.quantile(0.5)),
            super::timer::fmt_time(self.quantile(0.95)),
            super::timer::fmt_time(self.quantile(0.99)),
        )
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn hist_quantiles_ordered() {
        let mut h = LatencyHist::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        // p50 should be near 5ms.
        assert!((h.quantile(0.5) - 5e-3).abs() < 1e-3);
    }

    #[test]
    fn hist_reservoir_overflow_is_safe() {
        let mut h = LatencyHist::new();
        for i in 0..10_000 {
            h.record((i % 100) as f64 * 1e-4);
        }
        assert_eq!(h.count(), 10_000);
        assert!(h.quantile(0.99) <= 1e-2 + 1e-9);
    }

    #[test]
    fn bucket_rebuild_quantiles_approximate_reservoir() {
        let mut h = LatencyHist::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        let rebuilt = LatencyHist::from_bucket_counts(h.bucket_counts());
        assert_eq!(rebuilt.count(), h.count());
        for q in [0.5, 0.95, 0.99] {
            let exact = h.quantile(q);
            let approx = rebuilt.quantile(q);
            // Bucket midpoints are within one log bucket (~26%) of truth.
            assert!(
                (approx / exact).log10().abs() < 0.2,
                "q{q}: exact {exact} vs bucketed {approx}"
            );
        }
    }

    #[test]
    fn bucket_layout_is_consistent() {
        assert_eq!(LatencyHist::bucket_index(0.0), 0);
        assert_eq!(LatencyHist::bucket_index(1e9), LatencyHist::N_BUCKETS - 1);
        for idx in [0usize, 1, 40, LatencyHist::N_BUCKETS - 2] {
            let bound = LatencyHist::bucket_bound(idx);
            assert_eq!(
                LatencyHist::bucket_index(bound * 0.99),
                idx,
                "sample just under the bound lands in its bucket"
            );
        }
        assert!(LatencyHist::bucket_bound(LatencyHist::N_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn running_from_parts_round_trips() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for &x in &xs {
            r.push(x);
            sum += x;
            sumsq += x * x;
        }
        let n = xs.len() as u64;
        let mean = sum / n as f64;
        let rebuilt = Running::from_parts(n, mean, sumsq - sum * sum / n as f64, 1.0, 10.0);
        assert_eq!(rebuilt.count(), r.count());
        assert!((rebuilt.mean() - r.mean()).abs() < 1e-12);
        assert!((rebuilt.var() - r.var()).abs() < 1e-9);
        assert_eq!(rebuilt.min(), 1.0);
        assert_eq!(rebuilt.max(), 10.0);
        assert_eq!(Running::from_parts(0, 0.0, 0.0, 0.0, 0.0).count(), 0);
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1.0, -3.0], &[2.0, 1.0]), 4.0);
    }
}
