//! Microbenchmarks for the native MX format layer: block quantization,
//! dequantization, and sub-byte packing throughput per format.
//!
//! Custom harness (`harness = false`; criterion is not in the offline crate
//! set). Throughput is reported in elements/s — the §Perf targets in
//! EXPERIMENTS.md reference these names.

use mfqat::formats::{pack, ElementFormat, MxFormat};
use mfqat::tensor::MxTensor;
use mfqat::util::timer::bench;
use mfqat::util::Rng;

const N: usize = 1 << 20; // 1 Mi elements per iteration

fn main() {
    let mut rng = Rng::new(1);
    let data = rng.normal_vec(N);
    let shape = [N / 1024, 1024];
    println!("== formats: quantize / dequantize / pack (N = {N}) ==");

    for fmt in [
        ElementFormat::int(2),
        ElementFormat::int(4),
        ElementFormat::int(8),
        ElementFormat::fp_from_bits(4),
        ElementFormat::fp_from_bits(8),
    ] {
        let mx = MxFormat::new(fmt, 32);
        let r = bench(&format!("quantize/{}", fmt.name()), 8, 0.4, || {
            std::hint::black_box(MxTensor::quantize(&data, &shape, mx).unwrap());
        });
        println!("{}", r.report(N as f64, "elem"));

        let q = MxTensor::quantize(&data, &shape, mx).unwrap();
        let mut out = vec![0.0f32; N];
        let r = bench(&format!("dequantize/{}", fmt.name()), 8, 0.4, || {
            q.dequantize_into(&mut out);
            std::hint::black_box(&out);
        });
        println!("{}", r.report(N as f64, "elem"));
    }

    println!("\n== bit packing ==");
    let codes: Vec<i8> = (0..N).map(|i| ((i * 37) % 15) as i8 - 8).collect();
    for w in [2u8, 3, 4, 6, 8] {
        let r = bench(&format!("pack/w{w}"), 8, 0.3, || {
            std::hint::black_box(pack::pack(&codes, w));
        });
        println!("{}", r.report(N as f64, "elem"));
        let packed = pack::pack(&codes, w);
        let mut out = vec![0i8; N];
        // §Perf before/after: scalar reference vs word-at-a-time fast path.
        let r = bench(&format!("unpack_signed/scalar/w{w}"), 8, 0.3, || {
            pack::unpack_signed_into_scalar(&packed, w, &mut out);
            std::hint::black_box(&out);
        });
        println!("{}", r.report(N as f64, "elem"));
        let r = bench(&format!("unpack_signed/fast/w{w}"), 8, 0.3, || {
            pack::unpack_signed_into(&packed, w, &mut out);
            std::hint::black_box(&out);
        });
        println!("{}", r.report(N as f64, "elem"));
    }
}
