//! The PJRT/XLA backend: AOT HLO artifacts + dequantized f32 weight
//! literals (feature `pjrt`).
//!
//! Every format executes through the same compiled graph; lower precisions
//! change weight *values* only, so this backend measures quality, not
//! speed. Use [`super::NativeBackend`] for packed-format execution.
//!
//! `Send + Sync`: the [`Backend`] trait now requires both (the server's
//! worker pool `Arc`-shares one engine). The vendored xla stub's types are
//! plain data, so this compiles as-is; when re-pointing the `xla` dep at a
//! real xla-rs checkout (ROADMAP open item), either rely on xla-rs's
//! `Send + Sync` handle wrappers or confine this backend behind a
//! dedicated executor thread + channel — do **not** silently share
//! thread-bound PJRT handles across workers.

use super::Backend;
use crate::checkpoint::Checkpoint;
use crate::coordinator::format_cache::{CacheStats, FormatCache};
use crate::eval::ParamLiterals;
use crate::formats::ElementFormat;
use crate::model::{ModelDims, ParamSet};
use crate::runtime::{self, ArtifactSet, Runtime};
use crate::util::sync::RobustMutex;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// PJRT-backed engine over one artifact directory + anchor checkpoint.
pub struct PjrtBackend {
    /// PJRT runtime (client + compiled executables).
    pub rt: Runtime,
    /// Loaded AOT artifact set.
    pub arts: ArtifactSet,
    /// Anchor checkpoint every served format derives from.
    pub anchor: Checkpoint,
    /// Precision the anchor checkpoint stores.
    pub anchor_fmt: ElementFormat,
    dims: ModelDims,
    cache: RobustMutex<FormatCache<ParamLiterals>>,
}

impl PjrtBackend {
    /// Open artifacts + anchor checkpoint.
    pub fn open(artifact_dir: &Path, checkpoint: &Path, cache_bytes: usize) -> Result<PjrtBackend> {
        let rt = Runtime::cpu()?;
        let arts = ArtifactSet::open(artifact_dir)?;
        let anchor = Checkpoint::load(checkpoint)?;
        let anchor_fmt = anchor
            .anchor_format()?
            .ok_or_else(|| anyhow!("checkpoint has no 'anchor' meta — not an anchor checkpoint"))?;
        Ok(PjrtBackend::from_parts(rt, arts, anchor, anchor_fmt, cache_bytes))
    }

    /// Build from already-loaded pieces (tests, examples).
    pub fn from_parts(
        rt: Runtime,
        arts: ArtifactSet,
        anchor: Checkpoint,
        anchor_fmt: ElementFormat,
        cache_bytes: usize,
    ) -> PjrtBackend {
        let dims = ModelDims::from_manifest(&arts.manifest);
        PjrtBackend {
            rt,
            arts,
            anchor,
            anchor_fmt,
            dims,
            cache: RobustMutex::new(FormatCache::new(cache_bytes)),
        }
    }

    /// Serving weight literals for `fmt`, derived via Slice-and-Scale from
    /// the anchor (cached). `fmt == anchor` dequantizes the anchor directly.
    pub fn weights(&self, fmt: ElementFormat) -> Result<Arc<ParamLiterals>> {
        if let Some(w) = self.cache.lock().get(fmt) {
            return Ok(w);
        }
        let t = std::time::Instant::now();
        let params = ParamSet::from_checkpoint(&self.arts.manifest, &self.anchor, Some(fmt))
            .with_context(|| format!("deriving {fmt}"))?;
        let lits = Arc::new(ParamLiterals::build(&params)?);
        let bytes = params.n_params() * 4;
        log::info!(
            "pjrt: derived {} weights from anchor {} in {:.1} ms ({:.1} MB)",
            fmt,
            self.anchor_fmt,
            t.elapsed().as_secs_f64() * 1e3,
            bytes as f64 / 1e6
        );
        self.cache.lock().put(fmt, lits.clone(), bytes);
        Ok(lits)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn forward_logits(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let weights = self.weights(fmt)?;
        let exe = self.arts.executable(&self.rt, "forward_b8")?;
        let lit = runtime::i32_literal(tokens, &[m.train_batch, m.seq_len])?;
        let mut args: Vec<&xla::Literal> = vec![&lit];
        args.extend(weights.literals.iter());
        let out = exe.run(&args)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    fn score_batch(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let b = m.train_batch;
        let t = m.seq_len;
        let vocab = m.vocab;
        let width = t + 1;
        if tokens.is_empty() || tokens.len() % width != 0 {
            return Err(anyhow!(
                "scoring wants a non-empty multiple of seq_len+1 ({width}) tokens, got {}",
                tokens.len()
            ));
        }
        let rows = tokens.len() / width;
        if rows > b {
            return Err(anyhow!("scoring wants at most {b} windows, got {rows}"));
        }
        // The AOT graph has a fixed [b, t] shape: pad short batches by
        // repeating the first window, then truncate the scores back.
        let mut padded = Vec::with_capacity(b * width);
        for r in 0..b {
            let rr = if r < rows { r } else { 0 };
            padded.extend_from_slice(&tokens[rr * width..(rr + 1) * width]);
        }
        // Forward on the first T tokens of each row; NLL against the shift.
        let mut inputs = Vec::with_capacity(b * t);
        for r in 0..b {
            inputs.extend_from_slice(&padded[r * width..r * width + t]);
        }
        let logits = self.forward_logits(&inputs, fmt)?;
        let mut nll = crate::eval::nll_from_logits(&logits, &padded, b, width, vocab)?;
        nll.truncate(rows);
        Ok(nll)
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.lock().stats()
    }
}
