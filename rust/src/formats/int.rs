//! Signed-integer element quantization (MXINT elements).
//!
//! Elements are two's-complement with `b` bits: range `[−2^(b−1), 2^(b−1)−1]`.
//! Quantization rounds to nearest (ties-to-even by default, matching the jnp
//! oracle and OCP conversion; round-half-away is available for the ablation
//! bench) and saturates to the range.

use super::mxblock::RoundMode;

/// Inclusive element range of a `b`-bit signed integer format.
#[inline]
pub const fn int_range(bits: u8) -> (i32, i32) {
    let half = 1i32 << (bits - 1);
    (-half, half - 1)
}

/// Round a finite f32 to an integer under the given mode.
#[inline]
pub fn round_f32(x: f32, mode: RoundMode) -> f32 {
    match mode {
        RoundMode::HalfEven => x.round_ties_even(),
        RoundMode::HalfAway => x.round(),
    }
}

/// Quantize a scaled value to a `b`-bit signed integer code (saturating).
/// Non-finite inputs saturate (NaN → 0).
#[inline]
pub fn quantize_int(x: f32, bits: u8, mode: RoundMode) -> i8 {
    let (lo, hi) = int_range(bits);
    if x.is_nan() {
        return 0;
    }
    let r = round_f32(x, mode);
    let clamped = r.clamp(lo as f32, hi as f32);
    clamped as i8
}

/// Round-to-nearest on an `i32` right shift by `d` bits (the SSMXINT
/// element transform, paper Eq. 4: "divide by 2^Δe ... round using the
/// dropped bits"). `HalfEven` implements unbiased RNE on the dropped bits;
/// `HalfAway` rounds the exact .5 case away from zero.
#[inline]
pub fn shift_round(v: i32, d: u32, mode: RoundMode) -> i32 {
    if d == 0 {
        return v;
    }
    let floor = v >> d; // arithmetic shift: floor division for negatives
    let rem = v - (floor << d); // in [0, 2^d)
    let half = 1i32 << (d - 1);
    match mode {
        RoundMode::HalfEven => {
            if rem > half || (rem == half && floor & 1 == 1) {
                floor + 1
            } else {
                floor
            }
        }
        RoundMode::HalfAway => {
            // Ties away from zero on the *real* value v/2^d.
            if rem > half || (rem == half && v >= 0) {
                floor + 1
            } else {
                floor
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges() {
        assert_eq!(int_range(2), (-2, 1));
        assert_eq!(int_range(4), (-8, 7));
        assert_eq!(int_range(8), (-128, 127));
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(quantize_int(1000.0, 4, RoundMode::HalfEven), 7);
        assert_eq!(quantize_int(-1000.0, 4, RoundMode::HalfEven), -8);
        assert_eq!(quantize_int(f32::INFINITY, 8, RoundMode::HalfEven), 127);
        assert_eq!(quantize_int(f32::NEG_INFINITY, 8, RoundMode::HalfEven), -128);
        assert_eq!(quantize_int(f32::NAN, 8, RoundMode::HalfEven), 0);
    }

    #[test]
    fn rne_ties() {
        assert_eq!(quantize_int(0.5, 8, RoundMode::HalfEven), 0);
        assert_eq!(quantize_int(1.5, 8, RoundMode::HalfEven), 2);
        assert_eq!(quantize_int(2.5, 8, RoundMode::HalfEven), 2);
        assert_eq!(quantize_int(-0.5, 8, RoundMode::HalfEven), 0);
        assert_eq!(quantize_int(-1.5, 8, RoundMode::HalfEven), -2);
        // Half-away mode.
        assert_eq!(quantize_int(0.5, 8, RoundMode::HalfAway), 1);
        assert_eq!(quantize_int(-0.5, 8, RoundMode::HalfAway), -1);
    }

    #[test]
    fn shift_round_matches_float_division() {
        // shift_round(v, d) must equal quantizing v / 2^d with the same mode.
        for mode in [RoundMode::HalfEven, RoundMode::HalfAway] {
            for v in -1024i32..=1024 {
                for d in 0..=6u32 {
                    let got = shift_round(v, d, mode);
                    let exact = v as f64 / (1i64 << d) as f64;
                    let want = match mode {
                        RoundMode::HalfEven => {
                            // f64 RNE
                            let r = exact.round_ties_even();
                            r as i32
                        }
                        RoundMode::HalfAway => exact.round() as i32,
                    };
                    assert_eq!(got, want, "v={v} d={d} mode={mode:?}");
                }
            }
        }
    }

    #[test]
    fn shift_round_zero_shift_is_identity() {
        for v in [-7, -1, 0, 3, 127] {
            assert_eq!(shift_round(v, 0, RoundMode::HalfEven), v);
        }
    }
}
