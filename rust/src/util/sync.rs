//! Poison-proof synchronization primitives.
//!
//! `std::sync::Mutex` poisons itself when a holder panics, and every
//! subsequent `lock().unwrap()` then panics too — so one crashed server
//! worker used to wedge the whole pool (the shared `SloState`, the request
//! queue, the trace sink and the weight cache all sat behind poisonable
//! locks). [`RobustMutex`] recovers the guard from a poisoned lock instead
//! of propagating: every state it protects in this crate is either plain
//! data that stays internally consistent under any interleaving of its
//! mutations (counters, EWMA scalars, append-only vectors, an mpsc
//! receiver) or state the worker supervisor rebuilds wholesale after a
//! panic (decode sessions), so observing a value mid-update is safe and
//! strictly better than a pool-wide hang.

use std::sync::{Mutex, MutexGuard, TryLockError};

/// A mutex whose `lock` never fails: a poisoned lock (the previous holder
/// panicked) recovers the inner guard instead of propagating the poison.
///
/// Use this for state that must outlive a panicking holder — the server's
/// worker supervisor depends on every cross-worker lock being acquirable
/// after a `catch_unwind`.
#[derive(Debug, Default)]
pub struct RobustMutex<T>(Mutex<T>);

impl<T> RobustMutex<T> {
    /// Wrap `value` in a poison-proof mutex.
    pub fn new(value: T) -> RobustMutex<T> {
        RobustMutex(Mutex::new(value))
    }

    /// Acquire the lock, recovering from poison if a previous holder
    /// panicked (the guard is returned either way).
    pub fn lock(&self) -> MutexGuard<'_, T> {
        match self.0.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Try to acquire the lock without blocking. `None` only when another
    /// thread currently holds it — poison recovers like [`Self::lock`].
    pub fn try_lock(&self) -> Option<MutexGuard<'_, T>> {
        match self.0.try_lock() {
            Ok(g) => Some(g),
            Err(TryLockError::Poisoned(poisoned)) => Some(poisoned.into_inner()),
            Err(TryLockError::WouldBlock) => None,
        }
    }

    /// Consume the mutex and return the inner value (poison recovered).
    pub fn into_inner(self) -> T {
        match self.0.into_inner() {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_survives_a_panicking_holder() {
        let m = Arc::new(RobustMutex::new(7u32));
        let m2 = m.clone();
        let result = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("holder dies with the lock held");
        })
        .join();
        assert!(result.is_err(), "the holder thread must have panicked");
        // A std Mutex would now be poisoned; RobustMutex recovers.
        assert_eq!(*m.lock(), 7);
        *m.lock() = 9;
        assert_eq!(*m.lock(), 9);
    }

    #[test]
    fn try_lock_contended_and_poisoned() {
        let m = RobustMutex::new(1u32);
        {
            let _g = m.lock();
            assert!(m.try_lock().is_none(), "held elsewhere");
        }
        assert!(m.try_lock().is_some(), "free again");
        assert_eq!(RobustMutex::new(5u32).into_inner(), 5);
    }
}
