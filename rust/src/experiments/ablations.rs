//! Ablations for the design choices the paper asserts without a figure.
//!
//! * `abl_order` — multi-format QAT bit **ordering** (§3.2): the paper
//!   trains in increasing bit order because "lower-precision weights
//!   typically require larger updates to jump out of the quantization bin;
//!   training in the opposite direction can destabilize the higher-precision
//!   quantization settings learned earlier". We train ascending (2→4→6→8)
//!   vs descending (8→6→4→2) and compare the full PTQ perplexity grid.
//!
//! * `abl_round` — SSMXINT element rounding (§3.3): the paper's "round
//!   using the most-significant dropped bit" (≈ round-half-away) vs our
//!   default unbiased round-half-even, measured as tensor MSE and as
//!   end-to-end perplexity through the anchor path.

use super::report::{ascii_plot, save_text, ResultTable, Series};
use super::Ctx;
use crate::formats::{ElementFormat, MxFormat, RoundMode};
use crate::tensor::MxTensor;
use crate::util::stats::mse;
use crate::util::Rng;
use anyhow::Result;

/// Bit-order ablation: ascending vs descending multi-format QAT.
pub fn abl_order(ctx: &Ctx) -> Result<()> {
    let mut table = ResultTable::new(&["plan", "eval_bits", "ppl"]);
    let mut series = Vec::new();
    for plan in ["mf_int", "mf_int_desc"] {
        let params = ctx.ensure_variant_best(plan)?;
        let mut pts = Vec::new();
        for fmt in ElementFormat::all_int() {
            let ppl = ctx.val_ppl(&params.ptq(&ctx.arts.manifest, fmt)?)?;
            table.push(vec![plan.into(), fmt.bits().to_string(), format!("{ppl:.4}")]);
            pts.push((fmt.bits() as f64, ppl));
            log::info!("[abl_order] {plan} @ {}: {ppl:.3}", fmt);
        }
        series.push(Series {
            name: plan.to_string(),
            points: pts,
        });
    }
    table.save_csv(&ctx.result_path("abl_order.csv"))?;
    let plot = ascii_plot(
        "Ablation: multi-format QAT bit order (ascending 2→8 vs descending 8→2)",
        "eval bitwidth",
        "perplexity",
        &series,
        true,
    );
    save_text(&ctx.result_path("abl_order.txt"), &format!("{plot}\n{}", table.to_text()))?;
    Ok(())
}

/// Rounding-mode ablation for SSMXINT.
pub fn abl_round(ctx: &Ctx) -> Result<()> {
    let mut table = ResultTable::new(&["metric", "target_bits", "half_even", "half_away"]);

    // Tensor-level MSE (paper App. C protocol).
    let mut rng = Rng::new(0xAB1);
    let tensors: Vec<Vec<f32>> = (0..100).map(|_| rng.normal_vec(1024)).collect();
    for bits in [2u8, 3, 4, 5, 6, 7] {
        let t = ElementFormat::int(bits);
        let mut m = [0.0f64; 2];
        for data in &tensors {
            let anchor = MxTensor::quantize(data, &[1, 1024], MxFormat::mxint(8, 64))?;
            for (j, mode) in [RoundMode::HalfEven, RoundMode::HalfAway].iter().enumerate() {
                let ss = anchor.slice_and_scale_mode(t, *mode)?;
                m[j] += mse(data, &ss.dequantize()) / tensors.len() as f64;
            }
        }
        table.push(vec![
            "tensor_mse".into(),
            bits.to_string(),
            format!("{:.4e}", m[0]),
            format!("{:.4e}", m[1]),
        ]);
    }

    // End-to-end perplexity through the anchor path.
    let params = ctx.ensure_pretrained()?;
    let manifest = &ctx.arts.manifest;
    for bits in [2u8, 4, 6] {
        let t = ElementFormat::int(bits);
        let mut ppl = [0.0f64; 2];
        for (j, mode) in [RoundMode::HalfEven, RoundMode::HalfAway].iter().enumerate() {
            let mut served = params.clone();
            for i in manifest.quant_indices() {
                let w = &params.tensors[i];
                let anchor = MxTensor::quantize_mode(
                    &w.data,
                    &w.shape,
                    MxFormat::mxint(8, manifest.block_size),
                    RoundMode::HalfEven, // anchor quantization fixed; SS mode varies
                )?;
                let q = anchor.slice_and_scale_mode(t, *mode)?;
                served.tensors[i] = crate::tensor::Tensor::new(&w.shape, q.dequantize())?;
            }
            ppl[j] = ctx.val_ppl(&served)?;
        }
        log::info!("[abl_round] int{bits}: even {:.4} away {:.4}", ppl[0], ppl[1]);
        table.push(vec![
            "val_ppl".into(),
            bits.to_string(),
            format!("{:.4}", ppl[0]),
            format!("{:.4}", ppl[1]),
        ]);
    }

    table.save_csv(&ctx.result_path("abl_round.csv"))?;
    save_text(
        &ctx.result_path("abl_round.txt"),
        &format!(
            "Ablation: SSMXINT rounding — unbiased RNE (default) vs round-half-away\n(paper §3.3 describes the MSB-of-dropped-bits variant)\n\n{}",
            table.to_text()
        ),
    )?;
    Ok(())
}
