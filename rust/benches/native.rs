//! Native backend benchmarks — the packed-MX execution story, end to end.
//!
//! Sections:
//!   gemm/<fmt>           packed GEMM throughput per format and kernel
//!                        generation: `ref` = original fused-scale scalar
//!                        f32 kernel, `tile` = block-major f32 tile kernel,
//!                        `int` = integer-MAC pipeline (i8 activations,
//!                        i32/i16 dots, explicit AVX2/NEON tile MACs),
//!                        `int-portable` = the same pipeline pinned to the
//!                        autovectorized scalar loop (the PR 2 baseline the
//!                        SIMD kernels must beat) — all against the
//!                        dequantized dense-f32 baseline
//!   score/<fmt>          full decoder scoring batches through the
//!                        NativeBackend per serving format (warm cache) —
//!                        lower-bit formats stream less weight memory and
//!                        must not be slower than 8-bit
//!   generate/<ctx>       per-token decode latency: full-window recompute
//!                        vs KV-cached incremental decode, per context len
//!   derive/<fmt>         format-switch cost: anchor → packed target
//!                        (Slice-and-Scale + block-major repack), cold
//!
//! Writes a machine-readable summary to `BENCH_native.json` (CI archives
//! it; the acceptance numbers — int-MAC speedup over the scalar f32
//! kernel, MXINT4 vs MXINT8, KV-vs-full decode — live there).
//!
//! Runs with no AOT artifacts and no XLA. Pin `MFQAT_THREADS=1` for
//! stable single-core numbers.

use mfqat::backend::forward::{forward_cached, forward_logits, ActMode, KvCache};
use mfqat::backend::{kernels, NativeWeights, RepackedMx};
use mfqat::coordinator::ElasticEngine;
use mfqat::formats::{ElementFormat, MxFormat};
use mfqat::model::{ModelDims, ParamSet};
use mfqat::tensor::MxTensor;
use mfqat::util::json::Json;
use mfqat::util::timer::bench;
use mfqat::util::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let mut summary = Json::obj();
    summary.set("threads", Json::from(kernels::num_threads()));

    // ---------------------------------------------------------- raw GEMM
    let (rows, in_f, out_f) = (256usize, 512usize, 512usize);
    let x: Vec<f32> = (0..rows * in_f).map(|_| rng.normal()).collect();
    let wdata: Vec<f32> = (0..in_f * out_f).map(|_| rng.normal()).collect();
    let flops = (rows * in_f * out_f) as f64;
    println!("== packed GEMM [{rows}x{in_f}] @ [{in_f}x{out_f}] per format ==");
    let mut y = vec![0.0f32; rows * out_f];
    let dense = bench("gemm/dense-f32(baseline)", 8, 0.5, || {
        kernels::gemm_dense(&x, rows, &wdata, in_f, out_f, &mut y);
        std::hint::black_box(&y);
    });
    println!("{}", dense.report(flops, "mac"));
    let mut gemm_json = Json::obj();
    gemm_json.set(
        "shape",
        Json::from(vec![rows, in_f, out_f]),
    );
    gemm_json.set("dense_f32_s", Json::from(dense.mean_s));
    for fmt in [
        ElementFormat::int(8),
        ElementFormat::int(6),
        ElementFormat::int(4),
        ElementFormat::int(2),
        ElementFormat::fp_from_bits(8),
        ElementFormat::fp_from_bits(6),
        ElementFormat::fp_from_bits(4),
    ] {
        let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::new(fmt, 32)).unwrap();
        let rp = RepackedMx::from_mx(&w);
        let mut fmt_json = Json::obj();
        let r_ref = bench(&format!("gemm/ref/{}", fmt.name()), 6, 0.4, || {
            kernels::gemm_packed(&x, rows, &w, &mut y);
            std::hint::black_box(&y);
        });
        println!("{}", r_ref.report(flops, "mac"));
        fmt_json.set("ref_s", Json::from(r_ref.mean_s));
        let r_tile = bench(&format!("gemm/tile/{}", fmt.name()), 6, 0.4, || {
            kernels::gemm_repacked(&x, rows, &rp, &mut y);
            std::hint::black_box(&y);
        });
        println!("{}", r_tile.report(flops, "mac"));
        fmt_json.set("tile_s", Json::from(r_tile.mean_s));
        fmt_json.set("tile_speedup_vs_ref", Json::from(r_ref.mean_s / r_tile.mean_s));
        if fmt.is_int() {
            let r_int = bench(&format!("gemm/int/{}", fmt.name()), 6, 0.4, || {
                kernels::gemm_repacked_int(&x, rows, &rp, &mut y);
                std::hint::black_box(&y);
            });
            println!("{}", r_int.report(flops, "mac"));
            fmt_json.set("int_s", Json::from(r_int.mean_s));
            fmt_json.set("int_speedup_vs_ref", Json::from(r_ref.mean_s / r_int.mean_s));
            fmt_json.set(
                "int_mac_per_s",
                Json::from(flops / r_int.mean_s),
            );
            // The PR 2 autovectorized pipeline (scalar tile MACs) — the
            // baseline the explicit SIMD kernels must beat.
            let r_port = bench(&format!("gemm/int-portable/{}", fmt.name()), 6, 0.4, || {
                kernels::gemm_repacked_int_portable(&x, rows, &rp, &mut y);
                std::hint::black_box(&y);
            });
            println!("{}", r_port.report(flops, "mac"));
            fmt_json.set("int_portable_s", Json::from(r_port.mean_s));
            fmt_json.set(
                "int_simd_speedup_vs_portable",
                Json::from(r_port.mean_s / r_int.mean_s),
            );
        }
        gemm_json.set(&fmt.name(), fmt_json);
    }
    summary.set("simd_level", Json::from(mfqat::backend::simd::level().name()));
    summary.set("gemm", gemm_json);

    // ------------------------------------------------- end-to-end scoring
    let dims = ModelDims::by_name("tiny").unwrap();
    let manifest = dims.to_manifest();
    let params = ParamSet::init(&manifest, 3);
    let tokens_per_batch = (dims.train_batch * dims.seq_len) as f64;
    let batch: Vec<i32> = (0..dims.train_batch * (dims.seq_len + 1))
        .map(|i| ((i * 31 + 7) % dims.vocab) as i32)
        .collect();

    let mut score_json = Json::obj();
    for (anchor, bits_list) in [
        (ElementFormat::int(8), [8u8, 6, 4, 2]),
        (ElementFormat::fp_from_bits(8), [8u8, 7, 6, 4]),
    ] {
        let ck = params.to_anchor_checkpoint(&manifest, anchor).unwrap();
        let engine = ElasticEngine::native(dims.clone(), ck.clone(), 256 << 20).unwrap();
        let engine_int =
            ElasticEngine::native_with_act(dims.clone(), ck, 256 << 20, ActMode::Int8).unwrap();
        println!(
            "\n== native scoring, anchor {} (batch = {}) ==",
            anchor.long_name(),
            dims.train_batch
        );
        for bits in bits_list {
            let fmt = match anchor {
                ElementFormat::Int { .. } => ElementFormat::int(bits),
                ElementFormat::Fp { .. } => ElementFormat::fp_from_bits(bits),
            };
            engine.score_batch(&batch, fmt).unwrap(); // warm the format cache
            let r = bench(&format!("score/{}", fmt.name()), 6, 0.6, || {
                std::hint::black_box(engine.score_batch(&batch, fmt).unwrap());
            });
            println!("{}", r.report(tokens_per_batch, "tok"));
            let mut e = Json::obj();
            e.set("f32_s", Json::from(r.mean_s));
            if fmt.is_int() {
                engine_int.score_batch(&batch, fmt).unwrap();
                let ri = bench(&format!("score/{}+int8act", fmt.name()), 6, 0.6, || {
                    std::hint::black_box(engine_int.score_batch(&batch, fmt).unwrap());
                });
                println!("{}", ri.report(tokens_per_batch, "tok"));
                e.set("int8act_s", Json::from(ri.mean_s));
                e.set("int8act_speedup", Json::from(r.mean_s / ri.mean_s));
            }
            score_json.set(&fmt.name(), e);
        }
    }
    summary.set("score", score_json);

    // -------------------------------------- generation: full vs KV decode
    println!("\n== per-token decode: full-window recompute vs KV cache ==");
    let ck = params
        .to_anchor_checkpoint(&manifest, ElementFormat::int(8))
        .unwrap();
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(4)).unwrap();
    let window: Vec<i32> = (0..dims.seq_len)
        .map(|i| ((i * 13 + 5) % dims.vocab) as i32)
        .collect();
    let ctx_max = dims.seq_len - 1;
    let mut cache = KvCache::new(&dims);
    let mut gen_json = Json::obj();
    for ctx in [dims.seq_len / 8, dims.seq_len / 2, ctx_max] {
        let r_full = bench(&format!("generate/full/ctx{ctx}"), 4, 0.3, || {
            std::hint::black_box(forward_logits(&w, &window[..ctx + 1], 1).unwrap());
        });
        println!("{}", r_full.report(1.0, "tok"));
        // Prefill once; each timed iteration rolls the cache back to `ctx`
        // filled positions and decodes one token incrementally.
        cache.reset();
        forward_cached(&w, &mut cache, &window[..ctx]).unwrap();
        let r_kv = bench(&format!("generate/kv/ctx{ctx}"), 4, 0.3, || {
            cache.truncate(ctx);
            std::hint::black_box(forward_cached(&w, &mut cache, &window[ctx..ctx + 1]).unwrap());
        });
        println!("{}", r_kv.report(1.0, "tok"));
        let mut e = Json::obj();
        e.set("full_ms_per_tok", Json::from(r_full.mean_s * 1e3));
        e.set("kv_ms_per_tok", Json::from(r_kv.mean_s * 1e3));
        e.set("kv_speedup", Json::from(r_full.mean_s / r_kv.mean_s));
        gen_json.set(&format!("ctx{ctx}"), e);
    }
    summary.set("generate", gen_json);

    // ------------------------------------- self-speculative decoding
    // Draft k tokens at a cheap format of the *same* anchor parameters,
    // verify them in one multi-position pass at the serving format, roll
    // the KV back past rejected drafts. Greedy policy: the output is
    // asserted token-identical to the plain decode it is racing.
    println!("\n== self-speculative decode: cheap drafts, anchor verify, KV rollback ==");
    use mfqat::eval::generate::{ContinuousBatch, SampleCfg, SpecPolicy};
    fn median(mut xs: Vec<f64>) -> f64 {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    }
    let verify8 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let shared = verify8.shared.clone();
    let verify_fp8 = NativeWeights::packed_with_shared(
        &dims,
        &ck,
        ElementFormat::fp_from_bits(8),
        shared.clone(),
        ActMode::F32,
    )
    .unwrap();
    let draft4 = NativeWeights::packed_with_shared(
        &dims,
        &ck,
        ElementFormat::int(4),
        shared.clone(),
        ActMode::F32,
    )
    .unwrap();
    let draft6 =
        NativeWeights::packed_with_shared(&dims, &ck, ElementFormat::int(6), shared, ActMode::F32)
            .unwrap();
    let greedy = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 11,
    };
    let spec_prompt = "the color of kova is";
    let spec_tokens = 48usize;
    let reps = 5usize;
    let mut spec_json = Json::obj();
    for (dname, draft, vname, verify) in [
        ("int4", &draft4, "int8", &verify8),
        ("int4", &draft4, "fp8", &verify_fp8),
        ("int6", &draft6, "int8", &verify8),
    ] {
        // Plain decode at the verify format — the baseline being raced.
        let mut plain_times = Vec::with_capacity(reps);
        let mut plain_text = String::new();
        for _ in 0..reps {
            let t = std::time::Instant::now();
            let mut b: ContinuousBatch<&NativeWeights> = ContinuousBatch::new(&dims, 1);
            b.join(verify, spec_prompt, spec_tokens, &greedy).unwrap();
            let mut out = Vec::new();
            while b.active() > 0 {
                out.extend(b.step().unwrap());
            }
            plain_times.push(t.elapsed().as_secs_f64());
            plain_text = out.pop().expect("one finished row").text;
        }
        let p50_plain = median(plain_times);
        for k in [2usize, 4, 8] {
            let mut times = Vec::with_capacity(reps);
            let (mut drafted, mut accepted) = (0u64, 0u64);
            let mut decode_steps = 0usize;
            let mut text = String::new();
            for _ in 0..reps {
                let t = std::time::Instant::now();
                let mut b: ContinuousBatch<&NativeWeights> = ContinuousBatch::new(&dims, 1);
                b.join_spec(
                    verify,
                    draft,
                    spec_prompt,
                    spec_tokens,
                    &greedy,
                    k,
                    SpecPolicy::Greedy,
                )
                .unwrap();
                let mut out = Vec::new();
                let mut steps = 0usize;
                while b.active() > 0 {
                    out.extend(b.step().unwrap());
                    steps += 1;
                }
                times.push(t.elapsed().as_secs_f64());
                decode_steps = steps.saturating_sub(1); // first step prefills
                let f = out.pop().expect("one finished row");
                drafted = f.spec_drafted;
                accepted = f.spec_accepted;
                text = f.text;
            }
            assert_eq!(
                text, plain_text,
                "speculative {dname}->{vname} k={k} diverged from plain decode"
            );
            let p50_spec = median(times);
            let accept_rate = if drafted > 0 {
                accepted as f64 / drafted as f64
            } else {
                0.0
            };
            let per_step = accepted as f64 / decode_steps.max(1) as f64;
            let tok_step = spec_tokens as f64 / decode_steps.max(1) as f64;
            println!(
                "speculative/{dname}->{vname}/k{k}  accept {:.2}  tok/step {tok_step:.2}  \
                 p50 {:.2}ms vs {:.2}ms  speedup {:.2}x",
                accept_rate,
                p50_spec * 1e3,
                p50_plain * 1e3,
                p50_plain / p50_spec,
            );
            let mut e = Json::obj();
            e.set("accept_rate", Json::from(accept_rate));
            e.set("accepted_per_step", Json::from(per_step));
            e.set("tokens_per_step", Json::from(tok_step));
            e.set("p50_plain_ms", Json::from(p50_plain * 1e3));
            e.set("p50_spec_ms", Json::from(p50_spec * 1e3));
            e.set("p50_speedup_x", Json::from(p50_plain / p50_spec));
            spec_json.set(&format!("{dname}_to_{vname}_k{k}"), e);
        }
    }
    summary.set("speculative", spec_json);

    // ---------------------------------------------- format-switch (cold)
    println!("\n== format-switch cost: anchor -> packed target (SS + repack), cold ==");
    let mut derive_json = Json::obj();
    for bits in [6u8, 4, 3, 2] {
        let fmt = ElementFormat::int(bits);
        let r = bench(&format!("derive/int{bits}"), 4, 0.4, || {
            std::hint::black_box(
                NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap(),
            );
        });
        println!("{}", r.report(manifest.n_params as f64, "param"));
        derive_json.set(&format!("int{bits}_s"), Json::from(r.mean_s));
    }
    summary.set("derive", derive_json);

    // ------------------------------------------------------------ summary
    let path = "BENCH_native.json";
    std::fs::write(path, summary.pretty()).expect("write BENCH_native.json");
    println!("\nwrote {path}");
}
