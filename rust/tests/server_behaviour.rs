//! Elastic server integration over the native backend: batching,
//! policy-driven format selection, pinned formats (including mixed pins in
//! one gather window), the generation lane (continuous batching by
//! default, with per-row formats/budgets and mid-flight joins; legacy
//! gather batching behind [`GenBatching::Gather`]), multi-worker pools
//! sharing one engine, metrics/cache counters, and graceful shutdown.
//!
//! Runs everywhere — the native backend needs no AOT artifacts and no XLA.

use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::SampleCfg;
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::server::{CancelToken, GenBatching, Policy, Server, ServerConfig, SubmitOpts};
use std::time::Duration;

/// Small dims so the whole suite stays fast on one core. Vocab 256 so the
/// generation lane can encode byte prompts.
fn test_dims() -> ModelDims {
    let mut dims = ModelDims::new("srv", 256, 32, 2, 2, 16);
    dims.train_batch = 4;
    dims
}

fn test_corpus(width: usize, seed: u64, vocab: usize) -> Vec<Vec<i32>> {
    // Deterministic token rows within the test vocab.
    (0..64u64)
        .map(|r| {
            (0..width)
                .map(|i| (((r * 31 + seed * 7 + i as u64 * 13) % vocab as u64) as i32))
                .collect()
        })
        .collect()
}

fn start_pool_mode(
    policy: Policy,
    seed: u64,
    workers: usize,
    batching: GenBatching,
) -> (Server, mfqat::server::Client, usize) {
    let dims = test_dims();
    let width = dims.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, seed);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 64 << 20)
        },
        ServerConfig {
            policy,
            gather_window: Duration::from_millis(1),
            workers,
            batching,
            ..Default::default()
        },
    )
    .unwrap();
    (server, client, width)
}

fn start_pool(policy: Policy, seed: u64, workers: usize) -> (Server, mfqat::server::Client, usize) {
    start_pool_mode(policy, seed, workers, GenBatching::Continuous)
}

fn start_server(policy: Policy, seed: u64) -> (Server, mfqat::server::Client, usize) {
    start_pool(policy, seed, 1)
}

#[test]
fn requests_are_scored_and_batched() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 11);
    let rows = test_corpus(width, 9, 64);

    // Fire a burst; all must come back finite with the fixed format.
    let rxs: Vec<_> = (0..16)
        .map(|i| client.submit(&rows[i % rows.len()], None).unwrap())
        .collect();
    let mut max_batch = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.nll.is_finite() && resp.nll > 0.0);
        assert_eq!(resp.format, ElementFormat::int(8));
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "burst must be batched (got {max_batch})");
    let m = server.metrics();
    assert_eq!(m.requests, 16);
    assert!(m.cache.misses >= 1, "int8 derivation is a cache miss");
    assert_eq!(m.cache.entries, 1, "one format resident after a fixed-format run");
    drop(client);
    server.shutdown();
}

#[test]
fn pinned_format_wins_over_policy() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 12);
    let rows = test_corpus(width, 10, 64);
    let resp = client
        .score(&rows[0], Some(ElementFormat::int(3)))
        .unwrap();
    assert_eq!(resp.format, ElementFormat::int(3), "pin honoured");
    drop(client);
    server.shutdown();
}

#[test]
fn mixed_pins_in_one_window_each_get_their_format() {
    // Regression for the mixed-pin batching bug: when requests pinned to
    // *different* formats land in the same gather window, each must be
    // served at its own pin (the old code let the first pin win for all).
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 13);
    let rows = test_corpus(width, 11, 64);
    let pins = [
        Some(ElementFormat::int(4)),
        Some(ElementFormat::int(6)),
        Some(ElementFormat::int(4)),
        None, // policy pick
        Some(ElementFormat::int(2)),
        Some(ElementFormat::int(6)),
    ];
    // Submit the whole burst back-to-back so several pins share a window.
    let rxs: Vec<_> = pins
        .iter()
        .enumerate()
        .map(|(i, pin)| client.submit(&rows[i % rows.len()], *pin).unwrap())
        .collect();
    for (rx, pin) in rxs.into_iter().zip(pins) {
        let resp = rx.recv().unwrap().unwrap();
        let want = pin.unwrap_or(ElementFormat::int(8));
        assert_eq!(resp.format, want, "response served at the wrong precision");
        assert!(resp.nll.is_finite());
    }
    drop(client);
    server.shutdown();
}

#[test]
fn ladder_policy_degrades_under_load() {
    // Aggressive ladder so a modest burst crosses thresholds.
    let ladder = Policy::Ladder(vec![
        (2, ElementFormat::int(8)),
        (10, ElementFormat::int(6)),
        (usize::MAX, ElementFormat::int(4)),
    ]);
    let (server, client, width) = start_server(ladder, 14);
    let rows = test_corpus(width, 12, 64);

    // Single request under no load → highest precision.
    let solo = client.score(&rows[0], None).unwrap();
    assert_eq!(solo.format, ElementFormat::int(8));

    // Big burst → later batches must see depth > 10 and degrade.
    let rxs: Vec<_> = (0..48)
        .map(|i| client.submit(&rows[i % rows.len()], None).unwrap())
        .collect();
    let mut formats = std::collections::BTreeSet::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        formats.insert(resp.format.bits());
    }
    assert!(
        formats.iter().any(|&b| b < 8),
        "burst must trigger lower precisions, saw {formats:?}"
    );
    let metrics = server.metrics();
    assert!(metrics.conversions() >= formats.len() as u64);
    let s = metrics.summary();
    assert!(s.contains("cache["), "summary surfaces cache counters: {s}");
    drop(client);
    server.shutdown();
}

#[test]
fn shutdown_rejects_new_requests() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 15);
    let tokens = vec![33i32; width];
    client.score(&tokens, None).unwrap();
    server.shutdown();
    assert!(client.score(&tokens, None).is_err(), "post-shutdown submit fails");
}

#[test]
fn generate_lane_serves_batched_continuations() {
    let (server, client, _width) = start_server(Policy::Fixed(ElementFormat::int(8)), 16);
    let cfg = SampleCfg {
        temperature: 0.7,
        top_k: 6,
        seed: 9,
    };
    // A burst of identical-cfg prompts: must come back with the right
    // lengths, and the same prompt must sample the same continuation
    // (per-row RNGs make the batch deterministic per request).
    let prompts = ["kova", "blue", "kova", "the color"];
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| client.submit_generate(p, 8, None, cfg.clone()).unwrap())
        .collect();
    let mut texts = Vec::new();
    let mut max_batch = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.text.chars().count(), 8, "one char per token");
        assert_eq!(resp.format, ElementFormat::int(8));
        max_batch = max_batch.max(resp.batch_size);
        texts.push(resp.text);
    }
    assert_eq!(texts[0], texts[2], "same prompt + cfg ⇒ same continuation");
    // Batched-vs-solo token identity through the serving path.
    let solo = client.generate("kova", 8, None, cfg.clone()).unwrap();
    assert_eq!(solo.text, texts[0], "batched decode diverged from solo");
    let m = server.metrics();
    assert_eq!(m.gen_requests, 5);
    assert_eq!(m.gen_tokens, 5 * 8);
    assert!(m.summary().contains("gen["), "{}", m.summary());
    drop(client);
    server.shutdown();
}

#[test]
fn continuous_lane_serves_mixed_formats_and_budgets_in_flight() {
    // The continuous generate lane (the default) must serve a burst of
    // requests pinned to *different* formats with *different* token
    // budgets — impossible to group under gather batching — with every
    // response at its own pin, its own length, and text identical to a
    // solo request at the same pin (token-identity through the serving
    // path, whatever joined or finished around it).
    let (server, client, _width) = start_server(Policy::Fixed(ElementFormat::int(8)), 21);
    let cfg = SampleCfg {
        temperature: 0.7,
        top_k: 6,
        seed: 13,
    };
    let jobs = [
        ("kova", Some(ElementFormat::int(8)), 6usize),
        ("blue", Some(ElementFormat::int(4)), 11),
        ("the color", Some(ElementFormat::fp_from_bits(8)), 8),
        ("q", Some(ElementFormat::int(4)), 15),
        ("kova", None, 6), // policy pick rides along
    ];
    let rxs: Vec<_> = jobs
        .iter()
        .map(|(p, pin, n)| client.submit_generate(p, *n, *pin, cfg.clone()).unwrap())
        .collect();
    let mut texts = Vec::new();
    for (rx, (_, pin, n)) in rxs.into_iter().zip(&jobs) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.format, pin.unwrap_or(ElementFormat::int(8)), "served at its pin");
        assert_eq!(resp.text.chars().count(), *n, "one char per token");
        texts.push(resp.text);
    }
    // Per-row token identity through the server: a solo request at the
    // same pin/budget must reproduce each burst row exactly.
    for ((p, pin, n), text) in jobs.iter().zip(&texts) {
        let solo = client.generate(p, *n, *pin, cfg.clone()).unwrap();
        assert_eq!(&solo.text, text, "{p:?} at {pin:?} diverged from solo");
    }
    let m = server.metrics();
    assert_eq!(m.gen_requests, 10, "burst + solo checks");
    assert_eq!(
        m.gen_tokens,
        2 * jobs.iter().map(|(_, _, n)| *n as u64).sum::<u64>()
    );
    drop(client);
    server.shutdown();
}

#[test]
fn gather_mode_still_serves_grouped_batches() {
    // The legacy lane stays alive behind GenBatching::Gather (comparison
    // benchmarks; backends without an incremental-decode surface).
    let (server, client, width) =
        start_pool_mode(Policy::Fixed(ElementFormat::int(8)), 22, 1, GenBatching::Gather);
    let rows = test_corpus(width, 15, 64);
    let cfg = SampleCfg {
        temperature: 0.5,
        top_k: 4,
        seed: 2,
    };
    let score = client.score(&rows[0], None).unwrap();
    assert!(score.nll.is_finite());
    let rxs: Vec<_> = ["kova", "blue", "kova"]
        .iter()
        .map(|p| client.submit_generate(p, 7, None, cfg.clone()).unwrap())
        .collect();
    let mut texts = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.text.chars().count(), 7);
        texts.push(resp.text);
    }
    assert_eq!(texts[0], texts[2], "same prompt + cfg ⇒ same continuation");
    // Both batching modes run the same row-independent decode, so gather
    // mode's text matches a (continuous-mode-independent) solo request.
    let solo = client.generate("kova", 7, None, cfg).unwrap();
    assert_eq!(solo.text, texts[0]);
    drop(client);
    server.shutdown();
}

#[test]
fn mixed_score_and_generate_in_one_window() {
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 17);
    let rows = test_corpus(width, 13, 64);
    let cfg = SampleCfg {
        temperature: 0.5,
        top_k: 4,
        seed: 3,
    };
    let score_rx = client.submit(&rows[0], None).unwrap();
    let gen_rx = client.submit_generate("mixed", 6, Some(ElementFormat::int(4)), cfg).unwrap();
    let score_rx2 = client.submit(&rows[1], Some(ElementFormat::int(6))).unwrap();
    let s1 = score_rx.recv().unwrap().unwrap();
    let g = gen_rx.recv().unwrap().unwrap();
    let s2 = score_rx2.recv().unwrap().unwrap();
    assert!(s1.nll.is_finite());
    assert_eq!(s1.format, ElementFormat::int(8));
    assert_eq!(g.format, ElementFormat::int(4), "generate pin honoured");
    assert_eq!(g.text.chars().count(), 6);
    assert_eq!(s2.format, ElementFormat::int(6), "score pin honoured");
    drop(client);
    server.shutdown();
}

#[test]
fn worker_pool_serves_concurrent_load_from_one_engine() {
    // Four workers share one engine/metrics/cache. Fire a burst from
    // several client threads; every request must come back finite, the
    // aggregate request count must be exact, and the shared format cache
    // must have derived each format exactly once (no per-worker caches).
    let (server, client, width) = start_pool(Policy::Fixed(ElementFormat::int(8)), 18, 4);
    let rows = test_corpus(width, 14, 64);
    let n_threads = 4;
    let per_thread = 12;
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let client = client.clone();
            let rows = &rows;
            s.spawn(move || {
                for i in 0..per_thread {
                    let pin = match (t + i) % 3 {
                        0 => None,
                        1 => Some(ElementFormat::int(6)),
                        _ => Some(ElementFormat::int(4)),
                    };
                    let resp = client.score(&rows[(t * per_thread + i) % rows.len()], pin).unwrap();
                    assert!(resp.nll.is_finite() && resp.nll > 0.0);
                    if let Some(f) = pin {
                        assert_eq!(resp.format, f, "pin honoured under concurrency");
                    }
                }
            });
        }
    });
    let m = server.metrics();
    assert_eq!(m.requests, (n_threads * per_thread) as u64);
    assert_eq!(m.workers, 4);
    // One shared cache: 3 distinct formats ⇒ at most a derivation or two
    // per format even under racing workers (a concurrent miss may derive
    // twice before the first insert lands), and entries converge to 3.
    assert_eq!(m.cache.entries, 3, "shared cache holds each format once");
    assert!(
        m.cache.misses <= (3 * 4) as u64,
        "shared cache: at worst one racing derivation per format per worker, got {}",
        m.cache.misses
    );
    assert!(m.cache.hits > 0, "steady state must hit the shared cache");
    drop(client);
    server.shutdown();
}

#[test]
fn gather_mode_enforces_deadlines_and_cancellation_at_admission() {
    // Gather batches have fixed membership, so deadline / cancellation are
    // checked when the batch forms: a dead request never costs a forward.
    let (server, client, width) =
        start_pool_mode(Policy::Fixed(ElementFormat::int(8)), 41, 1, GenBatching::Gather);
    let rows = test_corpus(width, 40, 64);

    // Pre-cancelled token → the score dies at gather time.
    let token = CancelToken::new();
    token.cancel();
    let opts = SubmitOpts {
        deadline: None,
        cancel: Some(token),
    };
    let p = client.submit_opts(&rows[0], None, &opts).unwrap();
    let err = p
        .rx
        .recv_timeout(Duration::from_secs(10))
        .expect("cancelled score hung")
        .expect_err("cancelled score must error");
    assert!(err.contains("cancelled"), "unexpected error: {err:?}");

    // Zero deadline → the generation is expired before its batch forms.
    let cfg = SampleCfg {
        temperature: 0.5,
        top_k: 4,
        seed: 2,
    };
    let opts = SubmitOpts {
        deadline: Some(Duration::ZERO),
        cancel: None,
    };
    let p = client.submit_generate_opts("kova", 6, None, cfg, &opts).unwrap();
    let err = p
        .rx
        .recv_timeout(Duration::from_secs(10))
        .expect("expired generation hung")
        .expect_err("expired generation must error");
    assert!(err.contains("deadline exceeded"), "unexpected error: {err:?}");

    // Untouched requests keep serving around the retired ones.
    assert!(client.score(&rows[1], None).unwrap().nll.is_finite());
    let m = client.metrics_snapshot();
    assert!(m.cancellations >= 1, "cancel counted");
    assert!(m.deadline_misses >= 1, "miss counted");
    drop(client);
    server.shutdown();
}

#[test]
fn clients_racing_shutdown_never_hang() {
    // Submitting threads race `Server::shutdown`: every submission must
    // resolve — a response, an in-flight shutdown error, or a refusal at
    // the door — and every thread must return. A hang is the failure.
    let (server, client, width) = start_server(Policy::Fixed(ElementFormat::int(8)), 42);
    let rows = test_corpus(width, 41, 64);
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..3usize {
            let client = client.clone();
            let (rows, stop) = (&rows, &stop);
            s.spawn(move || {
                let mut i = t;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    match client.submit(&rows[i % rows.len()], None) {
                        Ok(rx) => match rx.recv_timeout(Duration::from_secs(30)) {
                            Ok(Ok(resp)) => assert!(resp.nll.is_finite()),
                            Ok(Err(e)) => {
                                assert!(e.contains("shut"), "unexpected in-flight error: {e:?}")
                            }
                            Err(_) => panic!("response channel hung or died with no error"),
                        },
                        Err(_) => {} // refused at the door during/after shutdown
                    }
                    i += 1;
                }
            });
        }
        // Let the load ramp, then yank the server out from under it.
        std::thread::sleep(Duration::from_millis(50));
        server.shutdown();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
}

#[test]
fn worker_pool_generate_lane_is_deterministic_under_concurrency() {
    let (server, client, _width) = start_pool(Policy::Fixed(ElementFormat::int(8)), 19, 2);
    let cfg = SampleCfg {
        temperature: 0.9,
        top_k: 8,
        seed: 5,
    };
    // The same (prompt, cfg) must sample identically no matter which
    // worker, batch, or neighbour set serves it.
    let reference = client.generate("kovaq", 10, None, cfg.clone()).unwrap().text;
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let p = if i % 2 == 0 { "kovaq" } else { "other" };
            client.submit_generate(p, 10, None, cfg.clone()).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        if i % 2 == 0 {
            assert_eq!(resp.text, reference, "request {i} diverged");
        }
    }
    drop(client);
    server.shutdown();
}

#[test]
fn shared_page_ledger_funds_skewed_load_across_workers() {
    // The cross-worker page economy: two workers' worth of KV budget pool
    // into one ledger, so a worker under skewed load admits rows from
    // pages its idle peer is not using — rows the old per-worker budget
    // would have deferred — while the pool-wide bound still holds (the
    // idle worker's admission defers until a claim returns).
    use mfqat::backend::{KvPageCfg, NativeWeights, PageLedger};
    use mfqat::eval::generate::ContinuousBatch;
    use std::sync::Arc;

    let dims = test_dims();
    let manifest = dims.to_manifest();
    let ck = ParamSet::init(&manifest, 23)
        .to_anchor_checkpoint(&manifest, ElementFormat::int(8))
        .unwrap();
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 5,
        seed: 7,
    };
    let ppr = dims.seq_len.div_ceil(4); // worst-case pages per row

    // Baseline (the old regime): a per-worker budget of one row defers
    // the worker's own second join even though a slot is free.
    let mut solo: ContinuousBatch<&NativeWeights> =
        ContinuousBatch::with_kv(&dims, 2, KvPageCfg::with_page(4).budget(ppr));
    solo.join(&w, "kova", 3, &cfg).unwrap();
    assert!(solo.has_free_slot() && !solo.can_admit(), "per-worker budget caps at one row");

    // The economy: the same two-row budget, pooled across two workers.
    let ledger = Arc::new(PageLedger::new(2 * ppr));
    let mut busy: ContinuousBatch<&NativeWeights> =
        ContinuousBatch::with_kv(&dims, 3, KvPageCfg::with_page(4));
    busy.attach_kv_ledger(Arc::clone(&ledger));
    let mut idle: ContinuousBatch<&NativeWeights> =
        ContinuousBatch::with_kv(&dims, 3, KvPageCfg::with_page(4));
    idle.attach_kv_ledger(Arc::clone(&ledger));

    // Skewed load: both rows land on one worker — the ledger funds what
    // a per-worker split would have deferred.
    let s0 = busy.join(&w, "kova", 3, &cfg).unwrap();
    assert_eq!(ledger.claimed(), ppr);
    assert!(busy.can_admit(), "the peer's idle share funds this worker");
    busy.join(&w, "kovaq blue", 3, &cfg).unwrap();
    assert_eq!(ledger.claimed(), 2 * ppr);

    // The pool-wide bound holds: the other worker now defers.
    assert!(!idle.can_admit(), "an exhausted ledger must defer admission");
    let err = idle.join(&w, "q", 3, &cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("defer the join"),
        "ledger exhaustion must read as a deferral, got: {err:#}"
    );

    // A retirement returns its claim and reopens admission pool-wide.
    busy.retire(s0).unwrap();
    assert_eq!(ledger.claimed(), ppr);
    assert!(idle.can_admit(), "released claims re-fund the peer");
    idle.join(&w, "q", 3, &cfg).unwrap();
    assert_eq!(ledger.claimed(), 2 * ppr);

    // Drain both workers: every claim goes home, none double-released.
    for cb in [&mut busy, &mut idle] {
        let mut steps = 0usize;
        while cb.active() > 0 {
            cb.step().unwrap();
            steps += 1;
            assert!(steps < 1000, "decode did not converge");
        }
    }
    assert_eq!(ledger.claimed(), 0, "drained workers must hold no claims");
    drop(busy);
    drop(idle);
    assert_eq!(ledger.claimed(), 0, "drop released claims twice");
}
