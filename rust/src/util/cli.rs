//! Minimal CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch lives in `main.rs`.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --steps 100 --lr=1e-4 tiny --verbose");
        assert_eq!(a.positional, vec!["train", "tiny"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("lr"), Some("1e-4"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 5 --x 2.5");
        assert_eq!(a.usize("n", 0).unwrap(), 5);
        assert_eq!(a.f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!(parse("--n abc").usize("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --quiet");
        assert!(a.flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn list_option() {
        let a = parse("--formats int2,int4, int8");
        // note: whitespace split puts "int8" as positional; emulate real argv
        let a2 = Args::parse(vec!["--formats".into(), "int2, int4,int8".into()]);
        assert_eq!(a2.list("formats").unwrap(), vec!["int2", "int4", "int8"]);
        assert!(a.list("missing").is_none());
    }
}
