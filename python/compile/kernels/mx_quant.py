"""L1 Pallas kernel: blockwise MX fake-quantization.

This is the compute hot-spot of QAT training: every forward pass
fake-quantizes each decoder weight matrix (paper Eq. 1-3, and the anchor
composition of section 3.5). The kernel tiles the weight matrix into
(TILE_R, C) slabs — one slab per grid step — so on a real TPU each slab's
HBM->VMEM transfer is expressed by the BlockSpec index map and the
quantization arithmetic (abs-max reduce, exponent extraction, RNE) runs on
the VPU over VMEM-resident data.

Hardware adaptation note (DESIGN.md section 5): the paper's accelerator
performs block quantization in dedicated datapath; on TPU-shaped Pallas we
express the same block schedule with BlockSpec instead of threadblocks.
``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute, while interpret mode
lowers to plain HLO ops with identical numerics.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats as F
from . import ref


def _fq_kernel(v_ref, o_ref, *, fmt: F.ElementFormat, block_size: int):
    """Fake-quantize one (TILE_R, C) slab resident in VMEM."""
    v = v_ref[...]
    tile_r, c = v.shape
    vb = v.reshape(tile_r, c // block_size, block_size)
    se = ref.shared_exponent(vb, fmt)
    u = vb * ref.exp2i(-se)[..., None]
    p = ref.quantize_elem(u, fmt)
    o_ref[...] = (p * ref.exp2i(se)[..., None]).reshape(tile_r, c)


def _pick_tile(rows: int, max_tile: int) -> int:
    """Largest divisor of ``rows`` not exceeding ``max_tile`` (VMEM budget)."""
    for t in range(min(max_tile, rows), 0, -1):
        if rows % t == 0:
            return t
    return 1


@partial(jax.jit, static_argnames=("fmt", "block_size", "max_tile"))
def fake_quantize_pallas(v, fmt: F.ElementFormat, block_size: int,
                         max_tile: int = 64):
    """Blockwise fake-quantize ``v`` ([..., C], C % block_size == 0)."""
    orig_shape = v.shape
    c = orig_shape[-1]
    assert c % block_size == 0, (orig_shape, block_size)
    v2 = jnp.asarray(v, jnp.float32).reshape(-1, c)
    rows = v2.shape[0]
    tile_r = _pick_tile(rows, max_tile)
    out = pl.pallas_call(
        partial(_fq_kernel, fmt=fmt, block_size=block_size),
        grid=(rows // tile_r,),
        in_specs=[pl.BlockSpec((tile_r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(v2)
    return out.reshape(orig_shape)
