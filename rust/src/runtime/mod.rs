//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API). The interchange format is HLO *text*
//! produced by `python/compile/aot.py` — jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! All exported computations are lowered with `return_tuple=True`, so every
//! execution returns one tuple buffer which we decompose into per-output
//! literals.
//!
//! Everything that touches PJRT is gated behind the default-off `pjrt`
//! feature; the artifact *manifest* ([`Manifest`], [`ParamInfo`]) stays
//! available unconditionally because the native backend and the parameter
//! spec table ride on it.

pub mod artifacts;

#[cfg(feature = "pjrt")]
pub use artifacts::ArtifactSet;
pub use artifacts::{Manifest, ParamInfo};

#[cfg(feature = "pjrt")]
use crate::tensor::Tensor;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
/// A PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// PJRT platform name (`cpu`, ...).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        log::debug!("compiled {} in {:.2}s", name, t.elapsed().as_secs_f64());
        Ok(Executable { exe, name })
    }
}

#[cfg(feature = "pjrt")]
/// A compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Graph name (file stem of the HLO artifact).
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with literal arguments (owned or borrowed); returns the
    /// decomposed output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let res = self
            .exe
            .execute(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let buf = res
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("execute {}: no outputs", self.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output of {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
    }
}

// ---------------------------------------------------------------- literals

#[cfg(feature = "pjrt")]
/// f32 tensor → literal.
pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e:?}"))
}

#[cfg(feature = "pjrt")]
/// i32 data → literal of the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("i32 literal: shape {:?} wants {n}, got {}", shape, data.len());
    }
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e:?}"))
}

#[cfg(feature = "pjrt")]
/// f32 scalar literal.
pub fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(feature = "pjrt")]
/// i32 scalar literal.
pub fn i32_scalar(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(feature = "pjrt")]
/// literal → f32 tensor (shape recovered from the literal).
pub fn literal_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to f32 vec: {e:?}"))?;
    Tensor::new(&dims, data).context("literal tensor")
}

#[cfg(feature = "pjrt")]
/// literal → f32 scalar.
pub fn literal_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal scalar: {e:?}"))
}
