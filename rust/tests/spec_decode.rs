//! Self-speculative decoding: under the **greedy** acceptance policy the
//! whole draft/verify/rollback machinery must be *token-invisible* — a row
//! drafting k tokens at a cheap format and verifying at its serving format
//! emits exactly the tokens a plain decode at the serving format would
//! have, for every draft×verify pair, both activation pipelines, any KV
//! page size, and any token budget (including ones smaller than k). The
//! rollback path must also return every KV page it maps: the draft mirror
//! and the truncated verify pages all flow back to their pools when rows
//! finish or retire.

use mfqat::backend::{ActMode, KvPageCfg, NativeWeights, SharedParams};
use mfqat::eval::generate::{ContinuousBatch, FinishedRow, SampleCfg, SpecPolicy};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use std::sync::Arc;

/// Byte-level prompts need the full 256-token vocab; tiny window so spec
/// rounds cross page boundaries and overflow re-prefills quickly.
fn gen_dims() -> ModelDims {
    let mut dims = ModelDims::new("specdec", 256, 32, 1, 2, 12);
    dims.train_batch = 4;
    dims
}

fn anchor(dims: &ModelDims, seed: u64, fmt: ElementFormat) -> mfqat::checkpoint::Checkpoint {
    let m = dims.to_manifest();
    ParamSet::init(&m, seed).to_anchor_checkpoint(&m, fmt).unwrap()
}

/// One weight set per format over a single `Arc`'d f32 parameter set —
/// `join_spec` demands draft and verify share their anchor parameters.
fn shared_weight_sets(
    dims: &ModelDims,
    ck: &mfqat::checkpoint::Checkpoint,
    formats: &[ElementFormat],
    act: ActMode,
) -> Vec<NativeWeights> {
    let shared = Arc::new(SharedParams::from_checkpoint(dims, ck).unwrap());
    formats
        .iter()
        .map(|&fmt| NativeWeights::packed_with_shared(dims, ck, fmt, shared.clone(), act).unwrap())
        .collect()
}

/// Step a batch until every row finishes, asserting convergence.
fn drain(cb: &mut ContinuousBatch<&NativeWeights>) -> Vec<FinishedRow> {
    let mut done = Vec::new();
    let mut steps = 0usize;
    while cb.active() > 0 {
        done.extend(cb.step().unwrap());
        steps += 1;
        assert!(steps < 1000, "decode did not converge");
    }
    done
}

/// Plain single-row decode through the continuous-batch path.
fn run_plain(
    dims: &ModelDims,
    w: &NativeWeights,
    prompt: &str,
    kv: KvPageCfg,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> String {
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(dims, 1, kv);
    cb.join(w, prompt, n_tokens, cfg).unwrap();
    let mut done = drain(&mut cb);
    assert_eq!(done.len(), 1);
    done.pop().unwrap().text
}

/// Speculative single-row decode; returns the finished row (text +
/// lifetime draft counters).
#[allow(clippy::too_many_arguments)]
fn run_spec(
    dims: &ModelDims,
    verify: &NativeWeights,
    draft: &NativeWeights,
    prompt: &str,
    kv: KvPageCfg,
    n_tokens: usize,
    cfg: &SampleCfg,
    k: usize,
    policy: SpecPolicy,
) -> FinishedRow {
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(dims, 1, kv);
    cb.join_spec(verify, draft, prompt, n_tokens, cfg, k, policy)
        .unwrap();
    let mut done = drain(&mut cb);
    assert_eq!(done.len(), 1);
    done.pop().unwrap()
}

#[test]
fn greedy_spec_token_identical_across_pairs_acts_and_pages() {
    // The acceptance criterion: speculative decode under the greedy policy
    // is bit-for-bit the plain verify-format decode — across MXINT8/MXFP8
    // verify anchors, MXINT4/MXINT6 drafts, both activation pipelines and
    // KV page sizes from degenerate (1 position) to dense (whole window),
    // through overflow re-prefills (`n_tokens` is twice the window).
    let dims = gen_dims();
    let ck = anchor(&dims, 71, ElementFormat::int(8));
    let cfg = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 7,
    };
    let prompt = "the color of kova is";
    let n_tokens = 2 * dims.seq_len;
    for act in [ActMode::F32, ActMode::Int8] {
        let ws = shared_weight_sets(
            &dims,
            &ck,
            &[
                ElementFormat::int(8),
                ElementFormat::fp_from_bits(8),
                ElementFormat::int(4),
                ElementFormat::int(6),
            ],
            act,
        );
        for (vi, vname) in [(0usize, "int8"), (1, "fp8")] {
            for pp in [1usize, 3, dims.seq_len] {
                let kv = KvPageCfg::with_page(pp);
                let plain = run_plain(&dims, &ws[vi], prompt, kv, n_tokens, &cfg);
                for (di, dname) in [(2usize, "int4"), (3, "int6")] {
                    let f = run_spec(
                        &dims,
                        &ws[vi],
                        &ws[di],
                        prompt,
                        kv,
                        n_tokens,
                        &cfg,
                        4,
                        SpecPolicy::Greedy,
                    );
                    assert_eq!(
                        f.text,
                        plain,
                        "{dname}->{vname} act={} page={pp}: speculative decode diverged",
                        act.name()
                    );
                    assert!(
                        f.spec_drafted > 0,
                        "{dname}->{vname} act={} page={pp}: row never drafted",
                        act.name()
                    );
                    assert!(
                        f.spec_accepted <= f.spec_drafted,
                        "accepted {} cannot exceed drafted {}",
                        f.spec_accepted,
                        f.spec_drafted
                    );
                }
            }
        }
    }
}

#[test]
fn greedy_policy_preserves_sampled_decode_exactly() {
    // Lazy target matching means the identity is not a greedy-argmax
    // special case: with temperature sampling the verify pass draws the
    // row's *actual* next token from its own RNG — one draw per emitted
    // token, exactly like plain decode — so the sampled trajectory is
    // reproduced token for token.
    let dims = gen_dims();
    let ck = anchor(&dims, 72, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let cfg = SampleCfg {
        temperature: 0.9,
        top_k: 5,
        seed: 13,
    };
    let n_tokens = 2 * dims.seq_len;
    for prompt in ["kova", "the color of kova is violet", "q"] {
        for pp in [2usize, dims.seq_len] {
            let kv = KvPageCfg::with_page(pp);
            let plain = run_plain(&dims, &ws[0], prompt, kv, n_tokens, &cfg);
            let f = run_spec(
                &dims,
                &ws[0],
                &ws[1],
                prompt,
                kv,
                n_tokens,
                &cfg,
                4,
                SpecPolicy::Greedy,
            );
            assert_eq!(
                f.text, plain,
                "sampled decode diverged under speculation (prompt {prompt:?}, page {pp})"
            );
        }
    }
}

#[test]
fn spec_k_caps_to_token_budget() {
    // k far above the remaining budget must cap, never overshoot: the row
    // still emits exactly its plain-decode text (same length, same
    // tokens), even for budgets of 1-2 tokens where drafting is pointless.
    let dims = gen_dims();
    let ck = anchor(&dims, 73, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let cfg = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 3,
    };
    let kv = KvPageCfg::with_page(3);
    for n_tokens in [1usize, 2, 3, 7] {
        let plain = run_plain(&dims, &ws[0], "kova", kv, n_tokens, &cfg);
        let f = run_spec(
            &dims,
            &ws[0],
            &ws[1],
            "kova",
            kv,
            n_tokens,
            &cfg,
            8,
            SpecPolicy::Greedy,
        );
        assert_eq!(f.text, plain, "n_tokens={n_tokens}: capped-k decode diverged");
    }
}

#[test]
fn mixed_spec_and_plain_rows_coexist() {
    // Speculative and plain rows share one continuous batch: each row's
    // output equals its solo run, the plain row reports zero draft
    // activity, and the spec rows' counters are live mid-flight via
    // `spec_stats`.
    let dims = gen_dims();
    let ck = anchor(&dims, 74, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[
            ElementFormat::int(8),
            ElementFormat::fp_from_bits(8),
            ElementFormat::int(4),
            ElementFormat::int(6),
        ],
        ActMode::F32,
    );
    let cfg = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 5,
    };
    let kv = KvPageCfg::with_page(3);
    let n_tokens = dims.seq_len;
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 3, kv);
    cb.set_spec_pressure(3); // keep drafting on with every slot live
    let s0 = cb
        .join_spec(&ws[0], &ws[2], "the color of kova", n_tokens, &cfg, 4, SpecPolicy::Greedy)
        .unwrap();
    let s1 = cb.join(&ws[0], "kova blue", n_tokens, &cfg).unwrap();
    let s2 = cb
        .join_spec(&ws[1], &ws[3], "q", n_tokens, &cfg, 2, SpecPolicy::Greedy)
        .unwrap();
    // A couple of steps in, the spec rows have live counters.
    for _ in 0..3 {
        assert!(cb.step().unwrap().is_empty(), "rows finished too early");
    }
    let (d0, a0) = cb.spec_stats(s0).expect("row 0 is speculative");
    assert!(d0 > 0 && a0 <= d0);
    assert!(cb.spec_stats(s1).is_none(), "plain row has no spec state");
    let mut texts = vec![String::new(); 3];
    let finished = drain(&mut cb);
    assert_eq!(finished.len(), 3);
    for f in finished {
        if f.slot == s1 {
            assert_eq!(f.spec_drafted, 0, "plain row must not draft");
        } else {
            assert!(f.spec_drafted > 0, "spec row {} never drafted", f.slot);
        }
        texts[f.slot] = f.text;
    }
    assert_eq!(texts[s0], run_plain(&dims, &ws[0], "the color of kova", kv, n_tokens, &cfg));
    assert_eq!(texts[s1], run_plain(&dims, &ws[0], "kova blue", kv, n_tokens, &cfg));
    assert_eq!(texts[s2], run_plain(&dims, &ws[1], "q", kv, n_tokens, &cfg));
}

#[test]
fn batch_pressure_disables_drafting_without_changing_output() {
    // Default pressure threshold for a 3-slot batch is 1 live row: with
    // all three slots full, speculative rows fall back to plain stepping
    // (drafted stays 0) and still emit their exact plain-decode text.
    let dims = gen_dims();
    let ck = anchor(&dims, 75, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let cfg = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 17,
    };
    let kv = KvPageCfg::with_page(4);
    let n_tokens = dims.seq_len;
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 3, kv);
    let prompts = ["kova", "the color of kova", "kova blue"];
    let mut slots = Vec::new();
    for p in prompts {
        slots.push(
            cb.join_spec(&ws[0], &ws[1], p, n_tokens, &cfg, 4, SpecPolicy::Greedy)
                .unwrap(),
        );
    }
    let finished = drain(&mut cb);
    assert_eq!(finished.len(), 3);
    for f in finished {
        assert_eq!(
            f.spec_drafted, 0,
            "slot {}: drafting must pause above the pressure threshold",
            f.slot
        );
        let i = slots.iter().position(|&s| s == f.slot).unwrap();
        assert_eq!(f.text, run_plain(&dims, &ws[0], prompts[i], kv, n_tokens, &cfg));
    }
}

#[test]
fn spec_rollback_and_retire_leak_no_pages() {
    // Every page the speculative machinery maps — verify pages rolled back
    // past rejected drafts, and the draft mirror's own pool — must return:
    // page accounting stays consistent on every step (the snapshot sums
    // live mirrors into used/free/total), and once rows finish or retire
    // the pool is back to its fresh baseline with zero resident bytes.
    let dims = gen_dims();
    let ck = anchor(&dims, 76, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 4,
        seed: 29,
    };
    let kv = KvPageCfg::with_page(1); // 1 position/page: every rollback frees pages
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 2, kv);
    cb.set_spec_pressure(2);
    let base = cb.kv_memory();
    assert_eq!(base.used_pages, 0);
    assert_eq!(base.free_pages, base.total_pages);
    cb.join_spec(&ws[0], &ws[1], "the color of kova", 2 * dims.seq_len, &cfg, 4, SpecPolicy::Greedy)
        .unwrap();
    cb.join(&ws[0], "kova", dims.seq_len, &cfg).unwrap();
    let mut steps = 0usize;
    while cb.active() > 0 {
        cb.step().unwrap();
        let m = cb.kv_memory();
        assert_eq!(
            m.used_pages + m.free_pages,
            m.total_pages,
            "page accounting broke mid-decode at step {steps}"
        );
        steps += 1;
        assert!(steps < 1000, "decode did not converge");
    }
    let m = cb.kv_memory();
    assert_eq!(m.used_pages, 0, "pages leaked after rows finished");
    assert_eq!(m.free_pages, base.total_pages);
    assert_eq!(m.total_pages, base.total_pages, "draft mirror pool outlived its row");
    assert_eq!(m.resident_bytes, 0);

    // Retiring a live speculative row mid-flight drops its mirror too.
    let s = cb
        .join_spec(&ws[0], &ws[1], "kova blue", dims.seq_len, &cfg, 4, SpecPolicy::Greedy)
        .unwrap();
    cb.step().unwrap();
    cb.step().unwrap();
    assert!(cb.kv_memory().total_pages > base.total_pages, "live mirror adds its pool");
    cb.retire(s).unwrap();
    let m = cb.kv_memory();
    assert_eq!((m.used_pages, m.free_pages, m.total_pages), (0, base.total_pages, base.total_pages));
}

#[test]
fn stochastic_policy_decodes_cleanly() {
    // The stochastic policy is distribution-preserving, not
    // token-identical — but it must still run to completion with sane
    // counters, and a deterministic sampling config (argmax target ==
    // point-mass draft distribution) collapses it back to exact identity.
    let dims = gen_dims();
    let ck = anchor(&dims, 77, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let kv = KvPageCfg::with_page(3);
    let n_tokens = 2 * dims.seq_len;
    let sampled = SampleCfg {
        temperature: 0.9,
        top_k: 6,
        seed: 41,
    };
    let f = run_spec(
        &dims,
        &ws[0],
        &ws[1],
        "the color of kova is",
        kv,
        n_tokens,
        &sampled,
        4,
        SpecPolicy::Stochastic,
    );
    assert!(f.spec_drafted > 0);
    assert!(f.spec_accepted <= f.spec_drafted);
    assert!(!f.text.is_empty());

    let greedy = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        seed: 41,
    };
    let plain = run_plain(&dims, &ws[0], "kova", kv, n_tokens, &greedy);
    let f = run_spec(
        &dims,
        &ws[0],
        &ws[1],
        "kova",
        kv,
        n_tokens,
        &greedy,
        4,
        SpecPolicy::Stochastic,
    );
    assert_eq!(
        f.text, plain,
        "deterministic stochastic-policy decode must equal plain argmax decode"
    );
}
