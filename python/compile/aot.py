"""AOT lowering: JAX -> HLO text artifacts + manifest + golden vectors.

Run once at build time (``make artifacts``); the rust runtime loads the HLO
text via ``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU
client. HLO **text** (not ``.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README).

Emitted per model config:
  forward_b{1,8}.hlo.txt   (tokens i32[B,T], *params)            -> (logits,)
  nll_b8.hlo.txt           (tokens i32[8,T+1], *params)          -> (loss,)
  train_<variant>.hlo.txt  (lr f32[], step i32[], tokens, *train,
                            *frozen, *m, *v) -> (loss, *train', *m', *v')
  manifest.json            param table + artifact table
  golden/*.json            oracle vectors for rust bit-parity tests

Usage: python -m compile.aot --config tiny --out ../artifacts
"""

import argparse
import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import formats as F
from . import model as M
from . import train as T
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_arg_specs(cfg):
    return [spec(s.shape) for s in M.param_specs(cfg)]


def lower_forward(cfg, batch):
    fn = M.forward_flat(cfg)
    args = [spec((batch, cfg.seq_len), jnp.int32)] + param_arg_specs(cfg)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_nll(cfg, batch):
    fn = M.nll_flat(cfg)
    args = [spec((batch, cfg.seq_len + 1), jnp.int32)] + param_arg_specs(cfg)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_train(cfg, variant, batch):
    step_fn, t_idx, f_idx = T.make_train_step(cfg, variant)
    specs = M.param_specs(cfg)
    t_specs = [spec(specs[i].shape) for i in t_idx]
    f_specs = [spec(specs[i].shape) for i in f_idx]
    args = (
        [spec((), jnp.float32), spec((), jnp.int32),
         spec((batch, cfg.seq_len + 1), jnp.int32)]
        + t_specs + f_specs + t_specs + t_specs  # train, frozen, m, v
    )
    return to_hlo_text(jax.jit(step_fn).lower(*args))


# --------------------------------------------------------------------------
# golden vectors (rust <-> python bit parity)
# --------------------------------------------------------------------------

def write_goldens(out_dir: str, seed: int = 20260710):
    """Oracle vectors: fake-quant and SS outputs on wild-valued inputs.

    The rust test ``rust/tests/golden_parity.rs`` loads these and requires
    exact f32 bit equality against its native implementation.
    """
    rng = np.random.default_rng(seed)
    n = 256
    bs = 32
    base = rng.normal(size=n).astype(np.float32)
    # Inject edge cases: zeros, powers of two, tiny, big, negatives.
    base[::17] = 0.0
    base[5] = 2.0 ** -20
    base[6] = -(2.0 ** 15)
    base[7] = 6.0
    base[8] = -448.0
    base[9] = 1e-38
    base[10] = 3.4e38 / 4
    cases = {"input": base.tolist(), "block_size": bs, "fq": {}, "ss": {}}

    all_fmts = F.ALL_INT + F.ALL_FP
    for fmt in all_fmts:
        fq = np.asarray(ref.fake_quantize(base.reshape(1, n), fmt, bs)).reshape(-1)
        cases["fq"][fmt.name] = fq.tolist()

    for anchor, targets in ((F.mxint(8), F.ALL_INT[:-1]), (F.mxfp(8), F.ALL_FP[:-1])):
        v_anchor = np.asarray(ref.fake_quantize(base.reshape(1, n), anchor, bs))
        for t in targets:
            ss = np.asarray(
                ref.ss_fake_quantize(v_anchor, anchor, t, bs)
            ).reshape(-1)
            cases["ss"][f"{anchor.name}->{t.name}"] = ss.tolist()

    # Code/scale planes for one format (checks the packed representation).
    se, p = ref.quantize_blocks(base.reshape(1, n), F.mxint(8), bs)
    cases["int8_scales"] = np.asarray(se).reshape(-1).astype(int).tolist()
    cases["int8_codes"] = np.asarray(p).reshape(-1).astype(int).tolist()

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "quant_golden.json"), "w") as f:
        json.dump(cases, f)
    print(f"  golden/quant_golden.json ({len(all_fmts)} formats)")


def write_forward_golden(out_dir: str, cfg, seed: int = 7):
    """A tiny end-to-end forward fixture: params + tokens + expected logits
    (used by the rust runtime integration test)."""
    os.makedirs(out_dir, exist_ok=True)
    params = M.init_params(cfg, seed=seed)
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(1, cfg.seq_len)).astype(np.int32)
    logits = np.asarray(M.forward_jit(params, jnp.asarray(tokens), cfg))
    flat = M.flat_from_params(cfg, params)
    fixture = {
        "config": cfg.name,
        "tokens": tokens.reshape(-1).tolist(),
        # Logits for the first 4 positions only (file size); full-precision
        # comparison happens at 1e-4 tolerance (XLA CPU fusion reordering).
        "logits_prefix": logits[0, :4].reshape(-1).tolist(),
        "param_checksums": [float(np.asarray(a, np.float64).sum()) for a in flat],
        "seed": seed,
    }
    with open(os.path.join(out_dir, f"forward_{cfg.name}.json"), "w") as f:
        json.dump(fixture, f)
    # The params themselves, raw f32 little-endian, for the runtime test.
    with open(os.path.join(out_dir, f"params_{cfg.name}.bin"), "wb") as f:
        for a in flat:
            f.write(np.asarray(a, np.float32).tobytes())
    print(f"  golden/forward_{cfg.name}.json + params_{cfg.name}.bin")


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------

def build(cfg_name: str, out: str, train_variants=None, batches=(1, 8)):
    cfg = M.CONFIGS[cfg_name]
    out_dir = os.path.join(out, cfg_name)
    os.makedirs(out_dir, exist_ok=True)
    specs = M.param_specs(cfg)
    artifacts = {}

    def emit(name, text):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {"file": f"{name}.hlo.txt", "bytes": len(text)}
        print(f"  {name}.hlo.txt ({len(text) / 1e6:.2f} MB)")

    for b in batches:
        emit(f"forward_b{b}", lower_forward(cfg, b))
    emit("nll_b8", lower_nll(cfg, 8))

    variants = train_variants if train_variants is not None else T.all_variants()
    for v in variants:
        t_idx = T.variant_trainable(cfg, v)
        emit(f"train_{v}", lower_train(cfg, v, 8))
        artifacts[f"train_{v}"]["trainable"] = t_idx

    manifest = {
        "config": cfg.to_json(),
        "n_params": M.n_params(cfg),
        "params": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "quantized": s.quantized,
                "init": s.init,
            }
            for s in specs
        ],
        "train_batch": 8,
        "artifacts": artifacts,
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest.json ({len(specs)} params, {M.n_params(cfg)/1e6:.2f}M)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny", help="comma-separated configs")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", default=None,
                    help="comma-separated train variants (default: all)")
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()

    variants = args.variants.split(",") if args.variants else None
    for cfg_name in args.config.split(","):
        print(f"[aot] lowering config '{cfg_name}'")
        build(cfg_name, args.out, train_variants=variants)
        if not args.skip_goldens:
            golden_dir = os.path.join(args.out, "golden")
            write_forward_golden(golden_dir, M.CONFIGS[cfg_name])
    if not args.skip_goldens:
        print("[aot] writing golden vectors")
        write_goldens(os.path.join(args.out, "golden"))
    print("[aot] done")


if __name__ == "__main__":
    sys.exit(main())
