//! Sub-byte bit packing of element codes.
//!
//! MX tensors store element codes contiguously at their native width
//! (2..=8 bits) in little-endian bit order: code `i` occupies bits
//! `[i·w, (i+1)·w)` of the byte stream, low bits first. This is the wire
//! and checkpoint layout; the hot path unpacks a whole block at a time.
//!
//! Codes are masked to `w` bits on pack; integer codes are sign-extended on
//! unpack (`unpack_signed`), minifloat codes are returned raw
//! (`unpack_unsigned`).

/// Number of bytes needed for `n` codes of `w` bits.
#[inline]
pub const fn packed_len(n: usize, w: u8) -> usize {
    (n * w as usize + 7) / 8
}

/// Pack `codes` (low `w` bits significant) into a byte vector.
pub fn pack(codes: &[i8], w: u8) -> Vec<u8> {
    assert!((1..=8).contains(&w));
    let mut out = vec![0u8; packed_len(codes.len(), w)];
    pack_into(codes, w, &mut out);
    out
}

/// Pack into a caller-provided buffer of exactly `packed_len` bytes.
pub fn pack_into(codes: &[i8], w: u8, out: &mut [u8]) {
    assert_eq!(out.len(), packed_len(codes.len(), w));
    out.fill(0);
    let mask = if w == 8 { 0xffu16 } else { (1u16 << w) - 1 };
    let w = w as usize;
    let mut bitpos = 0usize;
    for &c in codes {
        let v = (c as u8 as u16) & mask;
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        out[byte] |= (v << off) as u8;
        if off + w > 8 {
            out[byte + 1] |= (v >> (8 - off)) as u8;
        }
        bitpos += w;
    }
}

/// Unpack `n` unsigned codes of width `w` (minifloat code planes).
pub fn unpack_unsigned(bytes: &[u8], w: u8, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_unsigned_into(bytes, w, &mut out);
    out
}

/// Unpack into a caller-provided buffer (hot path).
///
/// §Perf: word-at-a-time — each iteration loads one `u64` window covering 8
/// consecutive codes (w·8 ≤ 64 bits always lands inside one aligned-enough
/// read via the byte offset) and extracts them with shifts, replacing the
/// byte-straddling branch of the scalar path. See
/// [`unpack_unsigned_into_scalar`] for the reference implementation (kept
/// for the bench baseline and differential tests).
pub fn unpack_unsigned_into(bytes: &[u8], w: u8, out: &mut [u8]) {
    assert!((1..=8).contains(&w));
    assert!(bytes.len() >= packed_len(out.len(), w), "packed buffer too short");
    if w == 8 {
        out.copy_from_slice(&bytes[..out.len()]);
        return;
    }
    let mask = ((1u16 << w) - 1) as u64;
    let wu = w as usize;
    let n = out.len();
    // Main loop: 8 codes per iteration consume exactly `wu` bytes (8·w bits),
    // so every group starts byte-aligned; fall to the scalar tail when fewer
    // than 8 readable bytes remain.
    let mut i = 0usize;
    while i + 8 <= n && i * wu / 8 + 8 <= bytes.len() {
        let byte = i * wu / 8;
        let word = u64::from_le_bytes(bytes[byte..byte + 8].try_into().unwrap());
        let base = &mut out[i..i + 8];
        for (j, o) in base.iter_mut().enumerate() {
            *o = ((word >> (j * wu)) & mask) as u8;
        }
        i += 8;
    }
    // Scalar tail.
    unpack_unsigned_tail(bytes, w, out, i);
}

#[inline]
fn unpack_unsigned_tail(bytes: &[u8], w: u8, out: &mut [u8], start: usize) {
    let mask = if w == 8 { 0xffu16 } else { (1u16 << w) - 1 };
    let wu = w as usize;
    let mut bitpos = start * wu;
    for o in out[start..].iter_mut() {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut v = (bytes[byte] as u16) >> off;
        if off + wu > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        *o = (v & mask) as u8;
        bitpos += wu;
    }
}

/// Reference scalar implementation (bench baseline + differential tests).
pub fn unpack_unsigned_into_scalar(bytes: &[u8], w: u8, out: &mut [u8]) {
    assert!((1..=8).contains(&w));
    assert!(bytes.len() >= packed_len(out.len(), w), "packed buffer too short");
    unpack_unsigned_tail(bytes, w, out, 0);
}

/// Unpack `n` signed (two's complement, width `w`) codes with sign extension.
pub fn unpack_signed(bytes: &[u8], w: u8, n: usize) -> Vec<i8> {
    let mut out = vec![0i8; n];
    unpack_signed_into(bytes, w, &mut out);
    out
}

/// Signed unpack into a caller-provided buffer (hot path).
///
/// §Perf: same word-at-a-time structure as [`unpack_unsigned_into`], with a
/// shift-based sign extension (`<< (8−w) >> (8−w)` on `i8`).
pub fn unpack_signed_into(bytes: &[u8], w: u8, out: &mut [i8]) {
    assert!((1..=8).contains(&w));
    let n = out.len();
    assert!(bytes.len() >= packed_len(n, w), "packed buffer too short");
    if w == 8 {
        for (o, &b) in out.iter_mut().zip(bytes) {
            *o = b as i8;
        }
        return;
    }
    let mask = ((1u16 << w) - 1) as u64;
    let wu = w as usize;
    let shift = 8 - w as u32;
    let mut i = 0usize;
    while i + 8 <= n && i * wu / 8 + 8 <= bytes.len() {
        let byte = i * wu / 8; // 8 codes = wu whole bytes: aligned stride
        let word = u64::from_le_bytes(bytes[byte..byte + 8].try_into().unwrap());
        let base = &mut out[i..i + 8];
        for (j, o) in base.iter_mut().enumerate() {
            let v = ((word >> (j * wu)) & mask) as u8;
            *o = ((v << shift) as i8) >> shift; // sign-extend
        }
        i += 8;
    }
    let mut bitpos = i * wu;
    let mask16 = (1u16 << w) - 1;
    let sign = 1u16 << (w - 1);
    for o in out[i..].iter_mut() {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut v = (bytes[byte] as u16) >> off;
        if off + wu > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        v &= mask16;
        *o = if v & sign != 0 {
            (v | !mask16) as u8 as i8
        } else {
            v as u8 as i8
        };
        bitpos += wu;
    }
}

/// Pack `codes` as consecutive **byte-aligned rows** of `row_codes` codes
/// each: row `r` occupies bytes `[r·row_bytes, (r+1)·row_bytes)` with
/// `row_bytes = packed_len(row_codes, w)`, so any row can be unpacked with a
/// plain [`unpack_signed_into`]/[`unpack_unsigned_into`] on its byte slice —
/// no bit-offset arithmetic. This is the block-major serving layout the
/// native GEMM streams (`backend::repack`); the wire/checkpoint layout stays
/// the fully-contiguous [`pack`] stream.
///
/// ```
/// use mfqat::formats::pack::{pack_rows, unpack_rows_signed};
///
/// // Two rows of five 4-bit codes; every row starts byte-aligned, so each
/// // packs to ceil(5·4/8) = 3 bytes and rows can be sliced independently.
/// let codes: Vec<i8> = vec![-3, 7, 0, -8, 5, 1, -1, 2, -4, 6];
/// let packed = pack_rows(&codes, 4, 5);
/// assert_eq!(packed.len(), 2 * 3);
/// assert_eq!(unpack_rows_signed(&packed, 4, 5, 2), codes);
/// ```
pub fn pack_rows(codes: &[i8], w: u8, row_codes: usize) -> Vec<u8> {
    assert!((1..=8).contains(&w));
    assert!(row_codes > 0 && codes.len() % row_codes == 0);
    let rows = codes.len() / row_codes;
    let row_bytes = packed_len(row_codes, w);
    let mut out = vec![0u8; rows * row_bytes];
    for (r, row) in codes.chunks_exact(row_codes).enumerate() {
        pack_into(row, w, &mut out[r * row_bytes..(r + 1) * row_bytes]);
    }
    out
}

/// Inverse of [`pack_rows`]: unpack `rows × row_codes` signed codes from a
/// byte-aligned-row stream.
pub fn unpack_rows_signed(bytes: &[u8], w: u8, row_codes: usize, rows: usize) -> Vec<i8> {
    let row_bytes = packed_len(row_codes, w);
    assert!(bytes.len() >= rows * row_bytes, "packed buffer too short");
    let mut out = vec![0i8; rows * row_codes];
    for r in 0..rows {
        unpack_signed_into(
            &bytes[r * row_bytes..(r + 1) * row_bytes],
            w,
            &mut out[r * row_codes..(r + 1) * row_codes],
        );
    }
    out
}

/// Scalar walk over `n` codes starting at absolute bit `bit`, feeding each
/// masked code to `emit` (shared core of the `*_at` random-access paths).
#[inline]
fn unpack_walk_at(bytes: &[u8], w: u8, bit: usize, n: usize, mut emit: impl FnMut(usize, u16)) {
    assert!(
        bytes.len() * 8 >= bit + n * w as usize,
        "packed buffer too short"
    );
    let mask = (1u16 << w) - 1;
    let wu = w as usize;
    let mut bitpos = bit;
    for i in 0..n {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut v = (bytes[byte] as u16) >> off;
        if off + wu > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        emit(i, v & mask);
        bitpos += wu;
    }
}

/// Unpack `out.len()` unsigned codes starting at code index `start` of a
/// packed stream (random access into a code plane, e.g. one weight row).
/// Falls to a bit-offset scalar walk only when the start bit is unaligned.
pub fn unpack_unsigned_at(bytes: &[u8], w: u8, start: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&w));
    let bit = start * w as usize;
    if bit % 8 == 0 {
        unpack_unsigned_into(&bytes[bit / 8..], w, out);
        return;
    }
    unpack_walk_at(bytes, w, bit, out.len(), |i, v| out[i] = v as u8);
}

/// Signed variant of [`unpack_unsigned_at`] (sign-extends to `i8`).
pub fn unpack_signed_at(bytes: &[u8], w: u8, start: usize, out: &mut [i8]) {
    assert!((1..=8).contains(&w));
    let bit = start * w as usize;
    if bit % 8 == 0 {
        unpack_signed_into(&bytes[bit / 8..], w, out);
        return;
    }
    let mask = (1u16 << w) - 1;
    let sign = 1u16 << (w - 1);
    unpack_walk_at(bytes, w, bit, out.len(), |i, v| {
        out[i] = if v & sign != 0 {
            (v | !mask) as u8 as i8
        } else {
            v as u8 as i8
        };
    });
}

/// Reference scalar implementation (bench baseline + differential tests).
pub fn unpack_signed_into_scalar(bytes: &[u8], w: u8, out: &mut [i8]) {
    let n = out.len();
    let mask = if w == 8 { 0xffu16 } else { (1u16 << w) - 1 };
    let sign = 1u16 << (w - 1);
    let wide = w as usize;
    let mut bitpos = 0usize;
    assert!(bytes.len() >= packed_len(n, w), "packed buffer too short");
    for o in out.iter_mut() {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut v = (bytes[byte] as u16) >> off;
        if off + wide > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        v &= mask;
        *o = if v & sign != 0 {
            (v | !mask) as u8 as i8
        } else {
            v as u8 as i8
        };
        bitpos += wide;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::props::{run_cases, Gen};

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(0, 4), 0);
        assert_eq!(packed_len(32, 4), 16);
        assert_eq!(packed_len(32, 3), 12);
        assert_eq!(packed_len(33, 3), 13);
        assert_eq!(packed_len(5, 8), 5);
        assert_eq!(packed_len(1, 2), 1);
    }

    #[test]
    fn roundtrip_signed_all_widths() {
        for w in 2..=8u8 {
            let lo = -(1i16 << (w - 1));
            let hi = (1i16 << (w - 1)) - 1;
            let codes: Vec<i8> = (lo..=hi).map(|v| v as i8).collect();
            let packed = pack(&codes, w);
            assert_eq!(packed.len(), packed_len(codes.len(), w));
            let un = unpack_signed(&packed, w, codes.len());
            assert_eq!(codes, un, "w={w}");
        }
    }

    #[test]
    fn roundtrip_unsigned_all_widths() {
        for w in 1..=8u8 {
            let max = if w == 8 { 255u16 } else { (1 << w) - 1 };
            let codes: Vec<i8> = (0..=max).map(|v| v as u8 as i8).collect();
            let packed = pack(&codes, w);
            let un = unpack_unsigned(&packed, w, codes.len());
            let want: Vec<u8> = (0..=max).map(|v| v as u8).collect();
            assert_eq!(un, want, "w={w}");
        }
    }

    #[test]
    fn upper_bits_are_masked_on_pack() {
        // A stray high bit in the i8 code must not corrupt neighbours.
        let codes = [0b0111_1111u8 as i8, 0]; // only low 2 bits should persist at w=2
        let packed = pack(&codes, 2);
        let un = unpack_unsigned(&packed, 2, 2);
        assert_eq!(un, vec![0b11, 0]);
    }

    #[test]
    fn cross_byte_boundaries() {
        // Width 3, 8 codes → 3 bytes; values straddle byte edges.
        let codes: Vec<i8> = vec![1, 2, 3, -1, -4, 0, 3, -2];
        let packed = pack(&codes, 3);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_signed(&packed, 3, 8), codes);
    }

    #[test]
    fn prop_roundtrip_random() {
        run_cases("pack/unpack roundtrip", 64, |g: &mut Gen| {
            let n = g.len(0, 257);
            for w in 2..=8u8 {
                let lo = -(1i32 << (w - 1));
                let hi = (1i32 << (w - 1)) - 1;
                let codes: Vec<i8> =
                    (0..n).map(|_| g.rng.range(0, (hi - lo + 1) as usize) as i32 + lo)
                        .map(|v| v as i8)
                        .collect();
                let packed = pack(&codes, w);
                if packed.len() != packed_len(n, w) {
                    return Err(format!("w={w}: wrong packed len"));
                }
                let un = unpack_signed(&packed, w, n);
                if un != codes {
                    return Err(format!("w={w} n={n}: signed roundtrip mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unpack_at_matches_full_unpack() {
        // Random access into a packed plane (the per-row GEMM path) must
        // agree with unpacking the whole stream, at every width and start
        // offset — aligned and unaligned alike.
        run_cases("unpack_at == full unpack slice", 32, |g: &mut Gen| {
            let n = g.len(16, 200);
            for w in 2..=8u8 {
                let lo = -(1i32 << (w - 1));
                let hi = (1i32 << (w - 1)) - 1;
                let codes: Vec<i8> = (0..n)
                    .map(|_| (g.rng.range(0, (hi - lo + 1) as usize) as i32 + lo) as i8)
                    .collect();
                let packed = pack(&codes, w);
                let full_s = unpack_signed(&packed, w, n);
                let full_u = unpack_unsigned(&packed, w, n);
                let start = g.rng.range(0, n);
                let len = g.rng.range(0, n - start + 1);
                let mut got_s = vec![0i8; len];
                unpack_signed_at(&packed, w, start, &mut got_s);
                if got_s != full_s[start..start + len] {
                    return Err(format!("signed w={w} start={start} len={len}"));
                }
                let mut got_u = vec![0u8; len];
                unpack_unsigned_at(&packed, w, start, &mut got_u);
                if got_u != full_u[start..start + len] {
                    return Err(format!("unsigned w={w} start={start} len={len}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_all_element_formats() {
        // Every format the paper evaluates (MXINT2..8, MXFP4..8): the full
        // code space must survive pack → unpack at the format's native
        // width, through the same signed/unsigned paths MxTensor uses.
        use crate::formats::int::int_range;
        use crate::formats::ElementFormat;
        for fmt in ElementFormat::all_int()
            .into_iter()
            .chain(ElementFormat::all_fp())
        {
            let w = fmt.bits();
            if fmt.is_int() {
                let (lo, hi) = int_range(w);
                let codes: Vec<i8> = (lo..=hi).map(|v| v as i8).collect();
                let packed = pack(&codes, w);
                assert_eq!(packed.len(), packed_len(codes.len(), w));
                assert_eq!(unpack_signed(&packed, w, codes.len()), codes, "{fmt}");
            } else {
                // Minifloat codes are raw sign-magnitude bit patterns.
                let n = 1usize << w;
                let codes: Vec<i8> = (0..n).map(|c| c as u8 as i8).collect();
                let packed = pack(&codes, w);
                assert_eq!(packed.len(), packed_len(n, w));
                let got = unpack_unsigned(&packed, w, n);
                let want: Vec<u8> = (0..n).map(|c| c as u8).collect();
                assert_eq!(got, want, "{fmt}");
            }
        }
    }

    #[test]
    fn prop_pack_rows_roundtrip_and_alignment() {
        // Byte-aligned row packing must round-trip at every width and row
        // length (ragged bit counts included), and each row must start
        // exactly at `r * packed_len(row_codes, w)`.
        run_cases("pack_rows roundtrip", 32, |g: &mut Gen| {
            let row_codes = g.len(1, 70);
            let rows = g.len(1, 9);
            for w in 2..=8u8 {
                let lo = -(1i32 << (w - 1));
                let hi = (1i32 << (w - 1)) - 1;
                let codes: Vec<i8> = (0..rows * row_codes)
                    .map(|_| (g.rng.range(0, (hi - lo + 1) as usize) as i32 + lo) as i8)
                    .collect();
                let packed = pack_rows(&codes, w, row_codes);
                let row_bytes = packed_len(row_codes, w);
                if packed.len() != rows * row_bytes {
                    return Err(format!("w={w}: wrong packed_rows len"));
                }
                if unpack_rows_signed(&packed, w, row_codes, rows) != codes {
                    return Err(format!("w={w} rows={rows} rc={row_codes}: roundtrip"));
                }
                // Per-row slices decode independently (the streaming GEMM path).
                for r in 0..rows {
                    let mut got = vec![0i8; row_codes];
                    unpack_signed_into(&packed[r * row_bytes..], w, &mut got);
                    if got != codes[r * row_codes..(r + 1) * row_codes] {
                        return Err(format!("w={w} row {r}: unaligned row start"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "packed buffer too short")]
    fn unpack_checks_bounds() {
        let packed = pack(&[1, 2, 3], 4); // 2 bytes
        let _ = unpack_signed(&packed, 4, 100);
    }

    #[test]
    fn prop_fast_unpack_matches_scalar_reference() {
        // §Perf differential test: the word-at-a-time paths must be
        // bit-identical to the retained scalar reference at every width,
        // length (incl. non-multiples of 8) and alignment.
        run_cases("fast unpack == scalar", 48, |g: &mut Gen| {
            let n = g.len(0, 300);
            for w in 2..=8u8 {
                let lo = -(1i32 << (w - 1));
                let hi = (1i32 << (w - 1)) - 1;
                let codes: Vec<i8> = (0..n)
                    .map(|_| (g.rng.range(0, (hi - lo + 1) as usize) as i32 + lo) as i8)
                    .collect();
                let packed = pack(&codes, w);
                let mut fast = vec![0i8; n];
                let mut slow = vec![0i8; n];
                unpack_signed_into(&packed, w, &mut fast);
                unpack_signed_into_scalar(&packed, w, &mut slow);
                if fast != slow {
                    return Err(format!("signed w={w} n={n}"));
                }
                let mut fast_u = vec![0u8; n];
                let mut slow_u = vec![0u8; n];
                unpack_unsigned_into(&packed, w, &mut fast_u);
                unpack_unsigned_into_scalar(&packed, w, &mut slow_u);
                if fast_u != slow_u {
                    return Err(format!("unsigned w={w} n={n}"));
                }
            }
            Ok(())
        });
    }
}
