//! LRU cache of per-format serving weight sets.
//!
//! Elastic serving switches formats with load; re-deriving weights on every
//! batch would waste the Slice-and-Scale work, while caching every format
//! forever costs memory. The cache bounds total bytes and evicts the least
//! recently used format.
//!
//! The value type is generic so each backend caches its own weight
//! representation: the native backend stores *packed* per-format weight sets
//! (`backend::NativeWeights` — block-major codes + scales, 2–8 bits/element),
//! the PJRT backend stores f32 parameter literals. Byte accounting uses the
//! caller-reported **marginal** resident size: the native backend charges
//! only `NativeWeights::packed_bytes()` per entry because the unquantized
//! f32 parameters (embeddings/norms/head) are `Arc`-shared across every
//! entry and paid for once by the backend, not per format — so a packed
//! MXINT4 entry costs ~8× less budget than an f32 set and the budget is not
//! inflated by duplicated f32 planes.

use crate::formats::ElementFormat;
use std::collections::HashMap;
use std::sync::Arc;

/// Counters exposed by a [`FormatCache`] (surfaced through the server
/// metrics and the engine API).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that required deriving a new weight set.
    pub misses: u64,
    /// Entries evicted to fit the byte budget.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Bytes currently charged against the budget.
    pub used_bytes: usize,
}

/// Byte-bounded LRU over derived weight sets.
pub struct FormatCache<T> {
    budget: usize,
    used: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    entries: HashMap<ElementFormat, Entry<T>>,
}

struct Entry<T> {
    weights: Arc<T>,
    bytes: usize,
    last_used: u64,
}

impl<T> FormatCache<T> {
    /// Empty cache with the given byte budget.
    pub fn new(budget_bytes: usize) -> FormatCache<T> {
        FormatCache {
            budget: budget_bytes,
            used: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            entries: HashMap::new(),
        }
    }

    /// Look up the cached weight set for `fmt` (counted as a hit or miss).
    pub fn get(&mut self, fmt: ElementFormat) -> Option<Arc<T>> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(&fmt) {
            Some(e) => {
                e.last_used = clock;
                self.hits += 1;
                Some(e.weights.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a weight set for `fmt`, charged at `bytes`; evicts least-recently-used entries until the budget fits.
    pub fn put(&mut self, fmt: ElementFormat, weights: Arc<T>, bytes: usize) {
        self.clock += 1;
        if let Some(old) = self.entries.remove(&fmt) {
            self.used -= old.bytes;
        }
        // Evict LRU entries until the new set fits (but always admit it —
        // an over-budget single entry is still better than re-deriving
        // every batch).
        while self.used + bytes > self.budget && !self.entries.is_empty() {
            let lru = *self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
                .unwrap();
            let e = self.entries.remove(&lru).unwrap();
            self.used -= e.bytes;
            self.evictions += 1;
            log::debug!("format cache: evicted {lru} ({} bytes)", e.bytes);
        }
        self.used += bytes;
        self.entries.insert(
            fmt,
            Entry {
                weights,
                bytes,
                last_used: self.clock,
            },
        );
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Cumulative cache hits.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cumulative cache misses (= derivations performed).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Cumulative evictions.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.entries.len(),
            used_bytes: self.used,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(bytes: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0u8; bytes.min(8)])
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = FormatCache::new(1000);
        assert!(c.get(ElementFormat::int(4)).is_none());
        c.put(ElementFormat::int(4), dummy(100), 100);
        assert!(c.get(ElementFormat::int(4)).is_some());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.used_bytes(), 100);
        assert_eq!(
            c.stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                evictions: 0,
                entries: 1,
                used_bytes: 100
            }
        );
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = FormatCache::new(250);
        c.put(ElementFormat::int(2), dummy(100), 100);
        c.put(ElementFormat::int(4), dummy(100), 100);
        // Touch int2 so int4 becomes LRU.
        c.get(ElementFormat::int(2));
        c.put(ElementFormat::int(6), dummy(100), 100);
        assert!(c.get(ElementFormat::int(2)).is_some());
        assert!(c.get(ElementFormat::int(4)).is_none(), "int4 evicted");
        assert!(c.get(ElementFormat::int(6)).is_some());
        assert!(c.used_bytes() <= 250);
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn eviction_cascade_counts_and_rebalances_bytes() {
        let mut c = FormatCache::new(350);
        c.put(ElementFormat::int(2), dummy(100), 100);
        c.put(ElementFormat::int(3), dummy(100), 100);
        c.put(ElementFormat::int(4), dummy(100), 100);
        // A 250-byte entry must push out the two least recently used.
        c.put(ElementFormat::int(8), dummy(250), 250);
        assert_eq!(c.evictions(), 2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.used_bytes(), 350, "int4 (100) + int8 (250)");
        assert!(c.get(ElementFormat::int(4)).is_some(), "most recent survives");
        assert!(c.get(ElementFormat::int(2)).is_none());
        assert!(c.get(ElementFormat::int(3)).is_none());
    }

    #[test]
    fn oversized_entry_still_admitted() {
        let mut c = FormatCache::new(50);
        c.put(ElementFormat::int(8), dummy(500), 500);
        assert_eq!(c.len(), 1);
        assert!(c.get(ElementFormat::int(8)).is_some());
        assert_eq!(c.used_bytes(), 500);
    }

    #[test]
    fn replace_same_format_updates_bytes() {
        let mut c = FormatCache::new(1000);
        c.put(ElementFormat::int(4), dummy(100), 100);
        c.put(ElementFormat::int(4), dummy(300), 300);
        assert_eq!(c.used_bytes(), 300);
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions(), 0, "replacement is not an eviction");
    }
}
