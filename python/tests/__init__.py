"""MF-QAT python test suite."""
