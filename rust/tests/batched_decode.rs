//! Batched KV-cached decode: `generate_batch` with ragged prompt lengths
//! must be **token-identical** to N independent single-sequence `generate`
//! calls — for every `ElementFormat` the paper evaluates, in both
//! activation modes. Exactness assertions, not tolerances: every per-row
//! computation in the batched forward is row-independent, so the outputs
//! must agree bit for bit.

use mfqat::backend::forward::{forward_cached, forward_cached_batch, KvCache};
use mfqat::backend::{ActMode, NativeWeights};
use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::{generate_native, generate_native_batch, SampleCfg};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};

/// Byte-level prompts need the full 256-token vocab; keep everything else
/// tiny so the full format × act-mode matrix stays fast.
fn gen_dims() -> ModelDims {
    let mut dims = ModelDims::new("batchgen", 256, 32, 1, 2, 10);
    dims.train_batch = 4;
    dims
}

fn anchor(dims: &ModelDims, seed: u64, fmt: ElementFormat) -> mfqat::checkpoint::Checkpoint {
    let m = dims.to_manifest();
    ParamSet::init(&m, seed).to_anchor_checkpoint(&m, fmt).unwrap()
}

#[test]
fn generate_batch_token_identical_all_formats_and_act_modes() {
    let dims = gen_dims();
    // Ragged prompts: shorter than, equal to, and longer than the window,
    // plus empty (PAD-seeded) — rows hit the re-prefill path at different
    // steps, so decode batches go ragged mid-run.
    let prompts = ["k", "kova query", "the color of kova is violet", ""];
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 33,
    };
    let n_tokens = 2 * dims.seq_len; // well past the window: forced overflow
    for (anchor_fmt, targets) in [
        (ElementFormat::int(8), ElementFormat::all_int()),
        (ElementFormat::fp_from_bits(8), ElementFormat::all_fp()),
    ] {
        let ck = anchor(&dims, 41, anchor_fmt);
        for fmt in targets {
            for act in [ActMode::F32, ActMode::Int8] {
                let mut w =
                    NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
                w.act = act;
                let batch = generate_native_batch(&w, &prompts, n_tokens, &cfg).unwrap();
                assert_eq!(batch.len(), prompts.len());
                for (r, p) in prompts.iter().enumerate() {
                    let solo = generate_native(&w, p, n_tokens, &cfg).unwrap();
                    assert_eq!(solo.chars().count(), n_tokens, "one char per token");
                    assert_eq!(
                        batch[r],
                        solo,
                        "{} act={} row {r} (prompt {p:?}): batched decode diverged",
                        fmt.long_name(),
                        act.name()
                    );
                }
            }
        }
    }
}

#[test]
fn engine_generate_batch_matches_engine_generate() {
    // The Backend/engine surface routes through the same batched decode.
    let dims = gen_dims();
    let ck = anchor(&dims, 42, ElementFormat::int(8));
    let engine = ElasticEngine::native(dims.clone(), ck, 64 << 20).unwrap();
    let cfg = SampleCfg {
        temperature: 0.6,
        top_k: 4,
        seed: 7,
    };
    let prompts = ["ab", "kova", "q"];
    let batch = engine
        .generate_batch(&prompts, ElementFormat::int(4), 12, &cfg)
        .unwrap();
    for (r, p) in prompts.iter().enumerate() {
        let solo = engine.generate(p, ElementFormat::int(4), 12, &cfg).unwrap();
        assert_eq!(batch[r], solo, "row {r}");
    }
    // Batched generation at a new format is one cache derivation.
    assert_eq!(engine.cached_formats(), 1);
}

#[test]
fn batched_prefill_logits_match_single_sequence_prefill() {
    // Scoring-shaped check on the batched cache itself: a ragged batched
    // prefill reproduces each row's single-sequence prefill logits exactly
    // (the decode exactness above builds on this).
    let dims = gen_dims();
    let ck = anchor(&dims, 43, ElementFormat::int(8));
    let vocab = dims.vocab;
    let rows_tok: Vec<Vec<i32>> = vec![
        (0..3).map(|i| (i * 31 + 5) as i32 % 256).collect(),
        (0..9).map(|i| (i * 17 + 2) as i32 % 256).collect(),
        (0..6).map(|i| (i * 7 + 11) as i32 % 256).collect(),
    ];
    for fmt in [ElementFormat::int(8), ElementFormat::fp_from_bits(6)] {
        let w = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
        let mut cache = KvCache::with_rows(&dims, rows_tok.len());
        let step: Vec<&[i32]> = rows_tok.iter().map(|t| t.as_slice()).collect();
        let batched = forward_cached_batch(&w, &mut cache, &step).unwrap();
        let mut off = 0usize;
        for (r, row) in rows_tok.iter().enumerate() {
            let mut solo_cache = KvCache::new(&dims);
            let solo = forward_cached(&w, &mut solo_cache, row).unwrap();
            assert_eq!(
                &batched[off * vocab..(off + row.len()) * vocab],
                solo.as_slice(),
                "{}: row {r}",
                fmt.long_name()
            );
            off += row.len();
        }
    }
}
