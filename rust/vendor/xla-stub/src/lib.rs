//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! This crate mirrors exactly the API surface `mfqat` uses so that the
//! `pjrt` feature *compiles* in environments without the PJRT C library.
//! Every constructor that would touch PJRT returns [`Error::Stub`]; the
//! engine then reports a clear "rebuild against real xla-rs" message instead
//! of a link failure. To execute AOT artifacts for real, repoint the `xla`
//! dependency in `rust/Cargo.toml` at an xla-rs checkout.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: the real implementation is not linked in.
#[derive(Debug, Clone)]
pub enum Error {
    /// Raised by every stubbed entry point.
    Stub,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: PJRT is not available in this build — point the `xla` \
             dependency at a real xla-rs checkout to execute AOT artifacts"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Stub)
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

/// Array shape (stub).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar<T: Copy>(_x: T) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Stub)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::Stub)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Stub)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Stub)
    }
}
