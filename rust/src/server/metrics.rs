//! Serving metrics: request counts per format, latency distribution,
//! batch-size and execution-time statistics.

use crate::formats::ElementFormat;
use crate::util::stats::{LatencyHist, Running};
use std::collections::BTreeMap;

/// Aggregated server metrics (guarded by a mutex in the server).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    per_format: BTreeMap<String, u64>,
    pub latency: LatencyHist,
    pub batch_size: Running,
    pub exec_time: Running,
    /// Anchor→target weight derivations performed (format-cache misses).
    pub conversions: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            latency: LatencyHist::new(),
            ..Default::default()
        }
    }

    pub fn record(&mut self, fmt: ElementFormat, latency_s: f64, batch: usize, exec_s: f64) {
        self.requests += 1;
        *self.per_format.entry(fmt.name()).or_insert(0) += 1;
        self.latency.record(latency_s);
        self.batch_size.push(batch as f64);
        self.exec_time.push(exec_s);
    }

    pub fn format_counts(&self) -> &BTreeMap<String, u64> {
        &self.per_format
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mix: Vec<String> = self
            .per_format
            .iter()
            .map(|(f, n)| format!("{f}:{n}"))
            .collect();
        format!(
            "requests={} latency[{}] mean_batch={:.2} mix=[{}]",
            self.requests,
            self.latency.summary(),
            self.batch_size.mean(),
            mix.join(" ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = Metrics::new();
        m.record(ElementFormat::int(8), 0.010, 4, 0.008);
        m.record(ElementFormat::int(8), 0.020, 8, 0.015);
        m.record(ElementFormat::int(4), 0.005, 8, 0.004);
        assert_eq!(m.requests, 3);
        assert_eq!(m.format_counts()["int8"], 2);
        assert_eq!(m.format_counts()["int4"], 1);
        assert!((m.batch_size.mean() - 20.0 / 3.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("int8:2"));
    }
}
