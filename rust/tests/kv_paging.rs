//! Paged KV-cache behaviour: paging must be **bit-invisible** to decode
//! output (any page size reproduces the dense single-page layout token for
//! token), page accounting must never leak (every join/decode/overflow/
//! retire churn returns the free list to baseline), retired rows must leave
//! no observable state for the next occupant (the zero-on-release
//! quarantine), and a page budget below the dense-equivalent pool must turn
//! admission memory-aware (joins defer, never fail mid-decode).

use mfqat::backend::forward::{forward_cached, forward_cached_batch_mixed, KvCache, RowTag};
use mfqat::backend::{ActMode, KvPageCfg, NativeWeights, SharedParams};
use mfqat::eval::generate::{generate_native, ContinuousBatch, SampleCfg};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use std::sync::Arc;

/// Byte-level prompts need the full 256-token vocab; tiny window so page
/// boundaries and overflow re-prefills land fast.
fn gen_dims() -> ModelDims {
    let mut dims = ModelDims::new("kvpage", 256, 32, 1, 2, 10);
    dims.train_batch = 4;
    dims
}

/// Small forward-level model (no text decode, vocab can stay tiny).
fn fwd_dims() -> ModelDims {
    let mut dims = ModelDims::new("kvfwd", 64, 32, 2, 2, 12);
    dims.train_batch = 2;
    dims
}

fn anchor(dims: &ModelDims, seed: u64, fmt: ElementFormat) -> mfqat::checkpoint::Checkpoint {
    let m = dims.to_manifest();
    ParamSet::init(&m, seed).to_anchor_checkpoint(&m, fmt).unwrap()
}

/// One weight set per format over a single `Arc`'d f32 parameter set.
fn shared_weight_sets(
    dims: &ModelDims,
    ck: &mfqat::checkpoint::Checkpoint,
    formats: &[ElementFormat],
    act: ActMode,
) -> Vec<NativeWeights> {
    let shared = Arc::new(SharedParams::from_checkpoint(dims, ck).unwrap());
    formats
        .iter()
        .map(|&fmt| NativeWeights::packed_with_shared(dims, ck, fmt, shared.clone(), act).unwrap())
        .collect()
}

/// Decode every prompt to completion through a `ContinuousBatch` over the
/// given KV paging, returning the continuations in prompt order.
fn run_batch(
    dims: &ModelDims,
    w: &NativeWeights,
    prompts: &[&str],
    kv: KvPageCfg,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Vec<String> {
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(dims, prompts.len(), kv);
    let mut slot_of = Vec::new();
    for p in prompts {
        slot_of.push(cb.join(w, p, n_tokens, cfg).unwrap());
    }
    let mut out: Vec<Option<String>> = vec![None; prompts.len()];
    let mut steps = 0usize;
    while cb.active() > 0 {
        for f in cb.step().unwrap() {
            let i = slot_of.iter().position(|&s| s == f.slot).unwrap();
            out[i] = Some(f.text);
        }
        steps += 1;
        assert!(steps < 1000, "decode did not converge");
    }
    out.into_iter().map(|t| t.unwrap()).collect()
}

#[test]
fn paged_decode_token_identical_across_page_sizes() {
    // The paged-vs-dense oracle: a single page spanning the whole window
    // IS the dense layout, so decoding with 1-, 3- and 4-position pages
    // must emit exactly the same tokens — across MXINT8/MXINT4/MXFP8 and
    // both activation pipelines, through overflow re-prefills.
    let dims = gen_dims();
    let ck = anchor(&dims, 51, ElementFormat::int(8));
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 9,
    };
    let prompts = ["kova", "the color of kova is violet", "q"];
    let n_tokens = 2 * dims.seq_len; // past the window: forced overflow
    for fmt in [
        ElementFormat::int(8),
        ElementFormat::int(4),
        ElementFormat::fp_from_bits(8),
    ] {
        for act in [ActMode::F32, ActMode::Int8] {
            let mut w = NativeWeights::packed_from_checkpoint(&dims, &ck, fmt).unwrap();
            w.act = act;
            let dense = run_batch(
                &dims,
                &w,
                &prompts,
                KvPageCfg::with_page(dims.seq_len),
                n_tokens,
                &cfg,
            );
            for pp in [1usize, 3, 4] {
                let paged =
                    run_batch(&dims, &w, &prompts, KvPageCfg::with_page(pp), n_tokens, &cfg);
                assert_eq!(
                    paged,
                    dense,
                    "{} act={}: page size {pp} changed decode output",
                    fmt.long_name(),
                    act.name()
                );
            }
            // And the dense-page run equals the solo decode path.
            for (r, p) in prompts.iter().enumerate() {
                let solo = generate_native(&w, p, n_tokens, &cfg).unwrap();
                assert_eq!(dense[r], solo, "{} act={} row {r}", fmt.long_name(), act.name());
            }
        }
    }
}

#[test]
fn prop_kv_churn_never_leaks_pages() {
    // Property: arbitrary join/decode/overflow/retire churn keeps
    // `used + free == total` at every step and returns the free list to
    // baseline once every sequence finishes — no page is ever leaked or
    // double-freed, whatever the membership history.
    let dims = gen_dims();
    let ck = anchor(&dims, 52, ElementFormat::int(8));
    let formats = [
        ElementFormat::int(8),
        ElementFormat::int(4),
        ElementFormat::fp_from_bits(8),
    ];
    let weights = shared_weight_sets(&dims, &ck, &formats, ActMode::F32);
    let prompts = ["k", "kova blue", "the color of kova", ""];
    let cfg = SampleCfg {
        temperature: 0.9,
        top_k: 5,
        seed: 27,
    };
    mfqat::util::props::run_cases("kv_page_leak", 8, |g| {
        let pp = 1 + g.rng.below(4); // 1..=4 positions per page
        let mut cb: ContinuousBatch<&NativeWeights> =
            ContinuousBatch::with_kv(&dims, 3, KvPageCfg::with_page(pp));
        let total = cb.kv_memory().total_pages;
        if cb.kv_memory().free_pages != total {
            return Err("fresh pool must be all-free".into());
        }
        let mut live: Vec<usize> = Vec::new();
        for _ in 0..g.rng.range(4, 12) {
            if cb.can_admit() && g.rng.chance(0.6) {
                let w = &weights[g.rng.below(weights.len())];
                let p = prompts[g.rng.below(prompts.len())];
                let n = g.rng.range(1, 2 * dims.seq_len);
                live.push(cb.join(w, p, n, &cfg).map_err(|e| e.to_string())?);
            }
            if cb.active() > 0 {
                for f in cb.step().map_err(|e| e.to_string())? {
                    live.retain(|&s| s != f.slot);
                }
            }
            if !live.is_empty() && g.rng.chance(0.3) {
                let victim = live[g.rng.below(live.len())];
                cb.retire(victim).map_err(|e| e.to_string())?;
                live.retain(|&s| s != victim);
            }
            let m = cb.kv_memory();
            if m.used_pages + m.free_pages != total {
                return Err(format!(
                    "page accounting broke mid-churn: {} used + {} free != {total}",
                    m.used_pages, m.free_pages
                ));
            }
        }
        // Drain and check the pool returned to baseline.
        let mut steps = 0usize;
        while cb.active() > 0 {
            cb.step().map_err(|e| e.to_string())?;
            steps += 1;
            if steps > 1000 {
                return Err("decode did not converge".into());
            }
        }
        let m = cb.kv_memory();
        if m.used_pages != 0 || m.free_pages != total || m.resident_bytes != 0 {
            return Err(format!(
                "pages leaked after all rows finished: {} used, {} free of {total}",
                m.used_pages, m.free_pages
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_truncate_row_rollback_is_exact_and_leak_free() {
    // Property over the speculative-rollback primitive: `truncate_row`
    // at **any** row count keeps the free list exactly consistent with
    // the per-row lengths (`used == Σ ceil(len/page)`), truncate-to-zero
    // returns the pool to baseline, and a rolled-back row re-decodes
    // bit-identically to a cache that never held the discarded positions.
    let dims = fwd_dims();
    let ck = anchor(&dims, 56, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    mfqat::util::props::run_cases("truncate_row_rollback", 8, |g| {
        let pp = 1 + g.rng.below(4); // 1..=4 positions per page
        let rows = 2 + g.rng.below(3); // 2..=4 rows — never the 1-row special case
        let mut cache = KvCache::with_rows_cfg(&dims, rows, KvPageCfg::with_page(pp));
        let total = cache.kv_memory().total_pages;
        // Row r runs in format r mod 2 — truncation must respect mixed
        // formats exactly like uniform ones.
        let wrefs: Vec<&NativeWeights> = (0..rows).map(|r| &ws[r % ws.len()]).collect();
        // Per-row token history mirroring what the cache should hold.
        let mut hist: Vec<Vec<i32>> = Vec::new();
        let mut feeds: Vec<Vec<i32>> = Vec::new();
        for _ in 0..rows {
            let n = 1 + g.rng.below(4);
            let t: Vec<i32> = (0..n).map(|_| g.rng.below(dims.vocab) as i32).collect();
            hist.push(t.clone());
            feeds.push(t);
        }
        let slices: Vec<&[i32]> = feeds.iter().map(|t| t.as_slice()).collect();
        forward_cached_batch_mixed(&wrefs, &mut cache, &slices).map_err(|e| e.to_string())?;
        for _ in 0..g.rng.range(4, 10) {
            let r = g.rng.below(rows);
            if g.rng.chance(0.5) && hist[r].len() + 1 < dims.seq_len {
                // Append one token to row r alone (other rows idle).
                let t = g.rng.below(dims.vocab) as i32;
                hist[r].push(t);
                let one = [t];
                let mut slices: Vec<&[i32]> = vec![&[]; rows];
                slices[r] = &one;
                forward_cached_batch_mixed(&wrefs, &mut cache, &slices)
                    .map_err(|e| e.to_string())?;
            } else {
                // Roll row r back to an arbitrary kept prefix.
                let keep = g.rng.below(hist[r].len() + 1);
                cache.truncate_row(r, keep);
                hist[r].truncate(keep);
            }
            let m = cache.kv_memory();
            let mapped: usize = hist.iter().map(|h| h.len().div_ceil(pp)).sum();
            if m.used_pages != mapped || m.used_pages + m.free_pages != total {
                return Err(format!(
                    "free list drifted: {} used (want {mapped}), {} free of {total}",
                    m.used_pages, m.free_pages
                ));
            }
            for (i, h) in hist.iter().enumerate() {
                if cache.len_of(i) != h.len() {
                    return Err(format!(
                        "row {i} length {} != mirrored history {}",
                        cache.len_of(i),
                        h.len()
                    ));
                }
            }
        }
        // Truncate-to-zero on every row returns the pool to baseline…
        for r in 0..rows {
            cache.truncate_row(r, 0);
        }
        let m = cache.kv_memory();
        if m.used_pages != 0 || m.free_pages != total {
            return Err(format!(
                "truncate-to-zero leaked: {} used, {} free of {total}",
                m.used_pages, m.free_pages
            ));
        }
        // …and a re-fed row is bit-identical to a fresh never-truncated
        // cache — the discarded positions left no trace.
        let probe: Vec<i32> = (0..5).map(|i| ((i * 13 + 2) % dims.vocab) as i32).collect();
        let r = g.rng.below(rows);
        let mut slices: Vec<&[i32]> = vec![&[]; rows];
        slices[r] = &probe;
        let replay =
            forward_cached_batch_mixed(&wrefs, &mut cache, &slices).map_err(|e| e.to_string())?;
        let mut fresh = KvCache::with_rows_cfg(&dims, 1, KvPageCfg::with_page(pp));
        let solo = forward_cached(wrefs[r], &mut fresh, &probe).map_err(|e| e.to_string())?;
        if replay != solo {
            return Err("post-truncate decode diverged from a fresh cache".into());
        }
        Ok(())
    });
}

#[test]
fn retired_row_leaves_no_stale_kv_or_tag() {
    // Regression for the retire-row audit: after a row retires, its slot
    // must expose nothing of the previous occupant — not its RowTag (a new
    // join re-tags) and not its K/V contents (pages are zeroed on release,
    // and the new occupant's logits equal a fresh solo decode).
    let dims = fwd_dims();
    let ck = anchor(&dims, 53, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let (w8, w4) = (&ws[0], &ws[1]);
    let mut cache = KvCache::with_slots_cfg(&dims, 1, KvPageCfg::with_page(4));
    let r = cache.join_row(RowTag::of(w8)).unwrap();
    assert_eq!(r, 0);
    let toks_a: Vec<i32> = (0..7).map(|i| (i * 5 + 3) % 64).collect();
    forward_cached_batch_mixed(&[w8], &mut cache, &[toks_a.as_slice()]).unwrap();
    assert!(cache.kv_memory().used_pages > 0, "occupant A mapped pages");
    cache.retire_row(0);
    assert_eq!(cache.row_tag(0), None, "stale RowTag survived retire");
    assert_eq!(cache.kv_memory().used_pages, 0, "pages not returned");

    // New occupant in a different format reuses the same slot.
    let r = cache.join_row(RowTag::of(w4)).unwrap();
    assert_eq!(r, 0, "freed slot is reused");
    assert_eq!(cache.row_tag(0), Some(RowTag::of(w4)));
    let toks_b: Vec<i32> = (0..9).map(|i| (i * 11 + 1) % 64).collect();
    let paged = forward_cached_batch_mixed(&[w4], &mut cache, &[toks_b.as_slice()]).unwrap();
    let mut fresh = KvCache::with_rows_cfg(&dims, 1, KvPageCfg::with_page(4));
    let solo = forward_cached(w4, &mut fresh, &toks_b).unwrap();
    assert_eq!(paged, solo, "previous occupant's state leaked into the reused slot");

    // Decoding the reused slot with the *retired* occupant's weights is a
    // tag error, not silent corruption.
    let one = [1i32];
    assert!(
        forward_cached_batch_mixed(&[w8], &mut cache, &[&one[..]]).is_err(),
        "stale-format decode must be rejected by the RowTag"
    );
}

#[test]
fn kv_admission_defers_until_pages_return() {
    // Pool funds exactly one worst-case row but the batch has two slots:
    // admission must become memory-aware (defer), not fail mid-decode.
    let dims = gen_dims();
    let ck = anchor(&dims, 54, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let cfg = SampleCfg {
        temperature: 0.7,
        top_k: 4,
        seed: 3,
    };
    let pages_per_row = dims.seq_len.div_ceil(4);
    let kv = KvPageCfg::with_page(4).budget(pages_per_row);
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 2, kv);
    assert!(cb.can_admit(), "an idle constrained pool can fund one row");
    let s0 = cb.join(&w, "kova", 4, &cfg).unwrap();
    assert!(cb.has_free_slot(), "a slot is free…");
    assert!(!cb.can_admit(), "…but the pool cannot fund it");
    assert!(
        cb.join(&w, "q", 4, &cfg).is_err(),
        "join must defer while unfundable"
    );
    // The funded row decodes to completion untouched by the pressure.
    let mut finished = Vec::new();
    let mut steps = 0usize;
    while cb.active() > 0 {
        finished.extend(cb.step().unwrap());
        steps += 1;
        assert!(steps < 200, "decode did not converge");
    }
    assert_eq!(finished.len(), 1);
    assert_eq!(finished[0].slot, s0);
    assert_eq!(finished[0].text, generate_native(&w, "kova", 4, &cfg).unwrap());
    // Pages returned ⇒ admission reopens.
    assert!(cb.can_admit(), "retired pages must re-fund admission");
    cb.join(&w, "q", 3, &cfg).unwrap();

    // Budgets below one worst-case row are clamped up so a pool can always
    // serve one sequence.
    let tiny = KvCache::with_slots_cfg(&dims, 2, KvPageCfg::with_page(4).budget(1));
    assert_eq!(tiny.total_pages(), pages_per_row);
}

#[test]
fn kv_resident_bytes_track_live_context() {
    // Residency grows page by page with appended context and shrinks on
    // truncate/reset — the memory story the refactor exists for.
    let dims = fwd_dims();
    let ck = anchor(&dims, 55, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let mut cache = KvCache::with_rows_cfg(&dims, 1, KvPageCfg::with_page(4));
    assert_eq!(cache.kv_memory().used_pages, 0);
    let toks: Vec<i32> = (0..6).map(|i| (i * 7 + 2) % 64).collect();
    let first = forward_cached(&w, &mut cache, &toks).unwrap();
    let m = cache.kv_memory();
    assert_eq!(m.used_pages, 2, "6 positions at 4/page map 2 pages");
    let page_bytes = 2 * dims.n_layers * 4 * dims.d_model * std::mem::size_of::<f32>();
    assert_eq!(m.resident_bytes, 2 * page_bytes);
    assert!(
        m.resident_bytes < m.dense_equivalent_bytes,
        "resident {} must undercut dense {}",
        m.resident_bytes,
        m.dense_equivalent_bytes
    );
    // Two more tokens stay inside page 2 (positions 7 and 8)…
    forward_cached(&w, &mut cache, &[9]).unwrap();
    forward_cached(&w, &mut cache, &[9]).unwrap();
    assert_eq!(cache.kv_memory().used_pages, 2);
    // …the 9th position maps page 3.
    forward_cached(&w, &mut cache, &[9]).unwrap();
    assert_eq!(cache.kv_memory().used_pages, 3);
    // Truncation returns pages past the cut.
    cache.truncate(4);
    assert_eq!(cache.kv_memory().used_pages, 1);
    cache.truncate(0);
    assert_eq!(cache.kv_memory().used_pages, 0);
    // A fresh prefill after full truncation reproduces the first one.
    let again = forward_cached(&w, &mut cache, &toks).unwrap();
    assert_eq!(first, again, "truncate-to-zero must behave like a fresh cache");
    cache.reset();
    let m = cache.kv_memory();
    assert_eq!((m.used_pages, m.free_pages), (0, m.total_pages));
    // The allocation-time high-water mark survives truncation and reset:
    // 3 pages were simultaneously mapped at the widest point.
    assert_eq!(m.resident_peak_bytes, 3 * page_bytes);
}
