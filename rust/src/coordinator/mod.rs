//! The elastic-inference coordinator — the L3 glue of paper §3.5.
//!
//! [`ElasticEngine`] owns the PJRT runtime, the AOT artifacts, and ONE
//! anchor checkpoint (MXINT8/MXFP8). For any requested target format it
//! derives serving weights on demand:
//!
//! ```text
//! anchor .mfq ──Slice-and-Scale──▶ target MxTensors ──dequant──▶ f32
//!        weight literals ──▶ forward/nll executables (one HLO, all formats)
//! ```
//!
//! Derived weight sets are cached per format with LRU eviction
//! ([`FormatCache`]), so steady-state serving pays zero conversion cost and
//! a format switch costs one SS pass (benchmarked in `benches/serving.rs`).

pub mod format_cache;

pub use format_cache::FormatCache;

use crate::checkpoint::Checkpoint;
use crate::eval::ParamLiterals;
use crate::formats::ElementFormat;
use crate::model::ParamSet;
use crate::runtime::{self, ArtifactSet, Runtime};
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Elastic inference engine: anchor checkpoint + on-demand format derivation.
pub struct ElasticEngine {
    pub rt: Runtime,
    pub arts: ArtifactSet,
    pub anchor: Checkpoint,
    pub anchor_fmt: ElementFormat,
    cache: Mutex<FormatCache>,
}

impl ElasticEngine {
    /// Open artifacts + anchor checkpoint.
    pub fn open(artifact_dir: &Path, checkpoint: &Path, cache_bytes: usize) -> Result<ElasticEngine> {
        let rt = Runtime::cpu()?;
        let arts = ArtifactSet::open(artifact_dir)?;
        let anchor = Checkpoint::load(checkpoint)?;
        let anchor_fmt = anchor
            .meta
            .get("anchor")
            .and_then(|j| j.as_str())
            .map(ElementFormat::parse)
            .transpose()?
            .ok_or_else(|| anyhow!("checkpoint has no 'anchor' meta — not an anchor checkpoint"))?;
        Ok(ElasticEngine {
            rt,
            arts,
            anchor,
            anchor_fmt,
            cache: Mutex::new(FormatCache::new(cache_bytes)),
        })
    }

    /// Build an engine from already-loaded pieces (tests, examples).
    pub fn from_parts(
        rt: Runtime,
        arts: ArtifactSet,
        anchor: Checkpoint,
        anchor_fmt: ElementFormat,
        cache_bytes: usize,
    ) -> ElasticEngine {
        ElasticEngine {
            rt,
            arts,
            anchor,
            anchor_fmt,
            cache: Mutex::new(FormatCache::new(cache_bytes)),
        }
    }

    /// Serving weights for `fmt`, derived via Slice-and-Scale from the
    /// anchor (cached). `fmt == anchor` dequantizes the anchor directly.
    pub fn weights(&self, fmt: ElementFormat) -> Result<Arc<ParamLiterals>> {
        if let Some(w) = self.cache.lock().unwrap().get(fmt) {
            return Ok(w);
        }
        let t = std::time::Instant::now();
        let params = ParamSet::from_checkpoint(&self.arts.manifest, &self.anchor, Some(fmt))
            .with_context(|| format!("deriving {fmt}"))?;
        let lits = Arc::new(ParamLiterals::build(&params)?);
        let bytes = params.n_params() * 4;
        log::info!(
            "derived {} weights from anchor {} in {:.1} ms ({:.1} MB)",
            fmt,
            self.anchor_fmt,
            t.elapsed().as_secs_f64() * 1e3,
            bytes as f64 / 1e6
        );
        self.cache.lock().unwrap().put(fmt, lits.clone(), bytes);
        Ok(lits)
    }

    /// Number of format weight-sets currently cached.
    pub fn cached_formats(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Conversions performed so far (cache misses).
    pub fn conversions(&self) -> u64 {
        self.cache.lock().unwrap().misses()
    }

    /// Run the batch-8 forward at `fmt`: `tokens` is a flat `[8 * seq_len]`
    /// buffer; returns flat logits `[8, seq_len, vocab]`.
    pub fn forward_b8(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let weights = self.weights(fmt)?;
        let exe = self.arts.executable(&self.rt, "forward_b8")?;
        let lit = runtime::i32_literal(tokens, &[m.train_batch, m.seq_len])?;
        let mut args: Vec<&xla::Literal> = vec![&lit];
        args.extend(weights.literals.iter());
        let out = exe.run(&args)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Per-row mean NLL for a batch of `[8 * (seq_len+1)]` token windows.
    pub fn score_b8(&self, tokens: &[i32], fmt: ElementFormat) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let b = m.train_batch;
        let t = m.seq_len;
        let vocab = m.vocab;
        assert_eq!(tokens.len(), b * (t + 1));
        // forward on the first T tokens of each row; NLL against the shift.
        let mut inputs = Vec::with_capacity(b * t);
        for r in 0..b {
            inputs.extend_from_slice(&tokens[r * (t + 1)..r * (t + 1) + t]);
        }
        let logits = self.forward_b8(&inputs, fmt)?;
        let mut out = Vec::with_capacity(b);
        for r in 0..b {
            let mut nll = 0.0f64;
            for pos in 0..t {
                let target = tokens[r * (t + 1) + pos + 1] as usize;
                let off = (r * t + pos) * vocab;
                nll -= crate::eval::log_softmax_pick(&logits[off..off + vocab], target);
            }
            out.push((nll / t as f64) as f32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Engine behaviour over real artifacts is covered by
    // `rust/tests/e2e_pipeline.rs`; cache mechanics in `format_cache`.
}
