//! Lock-free metric primitives and the named [`Registry`] behind the
//! serving telemetry surface.
//!
//! Every primitive updates with plain atomics — no mutex on any record
//! path — so the server's worker threads (and every decode step) can feed
//! metrics without serializing on a shared lock:
//!
//! * [`Counter`] — monotonic `u64` (`fetch_add`).
//! * [`Gauge`] — last-written `u64` value plus a `fetch_max` peak helper.
//! * [`AtomicRunning`] — mean/variance/min/max over `f64` samples via
//!   CAS-accumulated `sum`/`sumsq` (bridged back to
//!   [`crate::util::stats::Running`] snapshots).
//! * [`Hist`] — sharded bucketed latency histogram sharing the fixed
//!   log-bucket layout of [`LatencyHist`]; each thread lands on its own
//!   shard, shards merge at read time.
//!
//! The [`Registry`] maps `name{labels}` ids to shared handles. Its map is
//! behind an `RwLock`, but that lock is touched only at
//! registration/lookup — callers cache the returned `Arc` handles, so the
//! hot path never sees it. Exporters walk the registry to render a JSON
//! snapshot or Prometheus text exposition.

use crate::util::json::Json;
use crate::util::stats::{LatencyHist, Running};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

// ---------------------------------------------------------------- helpers

/// CAS-accumulate `x` into an `f64` stored as bits in an `AtomicU64`.
fn f64_add(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + x).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// CAS-minimize an `f64` stored as bits in an `AtomicU64`.
fn f64_min(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while x < f64::from_bits(cur) {
        match cell.compare_exchange_weak(cur, x.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// CAS-maximize an `f64` stored as bits in an `AtomicU64`.
fn f64_max(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while x > f64::from_bits(cur) {
        match cell.compare_exchange_weak(cur, x.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Stable per-thread shard index (a thread-local ticket from a global
/// counter — cheaper and more portable than hashing `ThreadId`).
fn shard_id() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static ID: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    ID.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v
    })
}

// ----------------------------------------------------------------- Counter

/// Monotonic lock-free counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ------------------------------------------------------------------- Gauge

/// Last-written value gauge (also usable as a running peak via
/// [`Gauge::set_max`]).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Zeroed gauge.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the value to at least `v` (running peak).
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ----------------------------------------------------------- AtomicRunning

/// Lock-free mean/variance/min/max accumulator over `f64` samples.
///
/// Accumulates `sum` and `sumsq` by CAS (exact for integer-valued samples
/// below 2^53; ordinary floating-point addition-order noise otherwise) and
/// snapshots back into [`Running`] for display.
#[derive(Debug)]
pub struct AtomicRunning {
    n: AtomicU64,
    sum: AtomicU64,
    sumsq: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicRunning {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicRunning {
    /// Empty accumulator.
    pub fn new() -> AtomicRunning {
        AtomicRunning {
            n: AtomicU64::new(0),
            sum: AtomicU64::new(0f64.to_bits()),
            sumsq: AtomicU64::new(0f64.to_bits()),
            min: AtomicU64::new(f64::INFINITY.to_bits()),
            max: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// Push one sample.
    pub fn push(&self, x: f64) {
        self.n.fetch_add(1, Ordering::Relaxed);
        f64_add(&self.sum, x);
        f64_add(&self.sumsq, x * x);
        f64_min(&self.min, x);
        f64_max(&self.max, x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Sum of the samples.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum.load(Ordering::Relaxed))
    }

    /// Snapshot into the display accumulator type.
    pub fn snapshot(&self) -> Running {
        let n = self.count();
        if n == 0 {
            return Running::new();
        }
        let sum = self.sum();
        let sumsq = f64::from_bits(self.sumsq.load(Ordering::Relaxed));
        let mean = sum / n as f64;
        Running::from_parts(
            n,
            mean,
            sumsq - sum * sum / n as f64,
            f64::from_bits(self.min.load(Ordering::Relaxed)),
            f64::from_bits(self.max.load(Ordering::Relaxed)),
        )
    }
}

// -------------------------------------------------------------------- Hist

/// Number of shards per histogram (threads spread across shards; merged at
/// read time).
const HIST_SHARDS: usize = 8;

#[derive(Debug)]
struct HistShard {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl HistShard {
    fn new() -> HistShard {
        HistShard {
            buckets: (0..LatencyHist::N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0f64.to_bits()),
        }
    }
}

/// Sharded lock-free latency histogram over [`LatencyHist`]'s fixed
/// log-bucket layout (1µs..100s, 10 buckets/decade). Recording touches one
/// shard's atomics; reads merge shards and can rebuild a [`LatencyHist`]
/// for quantile display.
#[derive(Debug)]
pub struct Hist {
    shards: Vec<HistShard>,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    /// Empty histogram.
    pub fn new() -> Hist {
        Hist {
            shards: (0..HIST_SHARDS).map(|_| HistShard::new()).collect(),
        }
    }

    /// Record one latency sample (seconds).
    pub fn record(&self, secs: f64) {
        let shard = &self.shards[shard_id() % HIST_SHARDS];
        shard.buckets[LatencyHist::bucket_index(secs)].fetch_add(1, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Relaxed);
        f64_add(&shard.sum, secs);
    }

    /// Samples recorded (all shards).
    pub fn count(&self) -> u64 {
        self.shards.iter().map(|s| s.count.load(Ordering::Relaxed)).sum()
    }

    /// Sum of recorded seconds (all shards).
    pub fn sum(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| f64::from_bits(s.sum.load(Ordering::Relaxed)))
            .sum()
    }

    /// Merged per-bucket counts in [`LatencyHist`] layout.
    pub fn bucket_counts(&self) -> Vec<u64> {
        let mut out = vec![0u64; LatencyHist::N_BUCKETS];
        for shard in &self.shards {
            for (o, b) in out.iter_mut().zip(&shard.buckets) {
                *o += b.load(Ordering::Relaxed);
            }
        }
        out
    }

    /// Snapshot into a [`LatencyHist`] (bucket-resolution quantiles).
    pub fn snapshot(&self) -> LatencyHist {
        LatencyHist::from_bucket_counts(&self.bucket_counts())
    }

    /// Bucket-resolution quantile over the merged shards.
    pub fn quantile(&self, q: f64) -> f64 {
        self.snapshot().quantile(q)
    }
}

// ---------------------------------------------------------------- Registry

/// One registered metric handle.
#[derive(Debug, Clone)]
pub enum Metric {
    /// Monotonic counter.
    Counter(Arc<Counter>),
    /// Last-value gauge.
    Gauge(Arc<Gauge>),
    /// Bucketed latency histogram.
    Hist(Arc<Hist>),
    /// Mean/var/min/max accumulator.
    Running(Arc<AtomicRunning>),
}

#[derive(Debug, Clone)]
struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// Named metric registry: `name{labels}` → lock-free handle.
///
/// The map lives behind an `RwLock`, but only registration/lookup touches
/// it; updates go straight through the returned `Arc` handles. Get-or-
/// create is idempotent — asking for the same id returns the same handle.
#[derive(Debug, Default)]
pub struct Registry {
    entries: RwLock<BTreeMap<String, Entry>>,
}

/// Canonical id for a metric name plus label set.
fn metric_id(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> = labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", body.join(","))
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn get_or_insert<T, F, G>(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        wrap: F,
        unwrap: G,
    ) -> Arc<T>
    where
        F: FnOnce() -> Metric,
        G: Fn(&Metric) -> Option<Arc<T>>,
    {
        let id = metric_id(name, labels);
        if let Some(e) = self.entries.read().unwrap().get(&id) {
            return unwrap(&e.metric)
                .unwrap_or_else(|| panic!("metric '{id}' registered with a different kind"));
        }
        let mut w = self.entries.write().unwrap();
        let e = w.entry(id.clone()).or_insert_with(|| Entry {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            metric: wrap(),
        });
        unwrap(&e.metric)
            .unwrap_or_else(|| panic!("metric '{id}' registered with a different kind"))
    }

    /// Get-or-create a labelless counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_with(name, &[])
    }

    /// Get-or-create a labelled counter.
    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.get_or_insert(
            name,
            labels,
            || Metric::Counter(Arc::new(Counter::new())),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    /// Get-or-create a labelless gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauge_with(name, &[])
    }

    /// Get-or-create a labelled gauge.
    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.get_or_insert(
            name,
            labels,
            || Metric::Gauge(Arc::new(Gauge::new())),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    /// Get-or-create a labelless histogram.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        self.hist_with(name, &[])
    }

    /// Get-or-create a labelled histogram.
    pub fn hist_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Hist> {
        self.get_or_insert(
            name,
            labels,
            || Metric::Hist(Arc::new(Hist::new())),
            |m| match m {
                Metric::Hist(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Get-or-create a labelless running accumulator.
    pub fn running(&self, name: &str) -> Arc<AtomicRunning> {
        self.running_with(name, &[])
    }

    /// Get-or-create a labelled running accumulator.
    pub fn running_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<AtomicRunning> {
        self.get_or_insert(
            name,
            labels,
            || Metric::Running(Arc::new(AtomicRunning::new())),
            |m| match m {
                Metric::Running(r) => Some(r.clone()),
                _ => None,
            },
        )
    }

    /// Visit every registered metric as `(id, name, labels, metric)` in id
    /// order.
    pub fn visit<F: FnMut(&str, &str, &[(String, String)], &Metric)>(&self, mut f: F) {
        for (id, e) in self.entries.read().unwrap().iter() {
            f(id, &e.name, &e.labels, &e.metric);
        }
    }

    /// JSON snapshot: one key per metric id. Counters/gauges render as
    /// numbers, running accumulators as `{count, mean, std, min, max}`,
    /// histograms as `{count, sum_s, mean_s, p50_s, p90_s, p99_s}`.
    pub fn snapshot_json(&self) -> Json {
        let mut out = Json::obj();
        self.visit(|id, _, _, m| {
            let v = match m {
                Metric::Counter(c) => Json::from(c.get()),
                Metric::Gauge(g) => Json::from(g.get()),
                Metric::Running(r) => {
                    let s = r.snapshot();
                    let mut o = Json::obj();
                    o.set("count", Json::from(s.count()));
                    if s.count() > 0 {
                        o.set("mean", Json::from(s.mean()));
                        o.set("std", Json::from(s.std()));
                        o.set("min", Json::from(s.min()));
                        o.set("max", Json::from(s.max()));
                    }
                    o
                }
                Metric::Hist(h) => {
                    let n = h.count();
                    let snap = h.snapshot();
                    let mut o = Json::obj();
                    o.set("count", Json::from(n));
                    o.set("sum_s", Json::from(h.sum()));
                    if n > 0 {
                        o.set("mean_s", Json::from(h.sum() / n as f64));
                        o.set("p50_s", Json::from(snap.quantile(0.5)));
                        o.set("p90_s", Json::from(snap.quantile(0.9)));
                        o.set("p99_s", Json::from(snap.quantile(0.99)));
                    }
                    o
                }
            };
            out.set(id, v);
        });
        out
    }

    /// Prometheus text exposition with metric names prefixed `prefix_`.
    /// Counters get a `_total` suffix; histograms render cumulative
    /// `_bucket{le=...}` lines (zero-delta buckets are skipped; `+Inf` is
    /// always present) plus `_sum`/`_count`; running accumulators render as
    /// `_count`/`_mean`/`_min`/`_max` gauges.
    pub fn prometheus(&self, prefix: &str) -> String {
        fn sanitize(s: &str) -> String {
            s.chars()
                .map(|c| {
                    if c.is_ascii_alphanumeric() || c == '_' {
                        c
                    } else {
                        '_'
                    }
                })
                .collect()
        }
        fn labels_text(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
            let mut parts: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            if let Some((k, v)) = extra {
                parts.push(format!("{k}=\"{v}\""));
            }
            if parts.is_empty() {
                String::new()
            } else {
                format!("{{{}}}", parts.join(","))
            }
        }
        let mut out = String::new();
        let mut last_type_line = String::new();
        self.visit(|_, name, labels, m| {
            let base = format!("{}_{}", sanitize(prefix), sanitize(name));
            let (full, kind) = match m {
                Metric::Counter(_) => (format!("{base}_total"), "counter"),
                Metric::Gauge(_) => (base.clone(), "gauge"),
                Metric::Hist(_) => (base.clone(), "histogram"),
                Metric::Running(_) => (base.clone(), "gauge"),
            };
            // One TYPE line per metric family (same-name label variants
            // are adjacent in id order).
            if !matches!(m, Metric::Running(_)) {
                let type_line = format!("# TYPE {full} {kind}\n");
                if type_line != last_type_line {
                    out.push_str(&type_line);
                    last_type_line = type_line;
                }
            }
            match m {
                Metric::Counter(c) => {
                    out.push_str(&format!("{full}{} {}\n", labels_text(labels, None), c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{full}{} {}\n", labels_text(labels, None), g.get()));
                }
                Metric::Running(r) => {
                    let s = r.snapshot();
                    let lt = labels_text(labels, None);
                    out.push_str(&format!("{full}_count{lt} {}\n", s.count()));
                    if s.count() > 0 {
                        out.push_str(&format!("{full}_mean{lt} {}\n", s.mean()));
                        out.push_str(&format!("{full}_min{lt} {}\n", s.min()));
                        out.push_str(&format!("{full}_max{lt} {}\n", s.max()));
                    }
                }
                Metric::Hist(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, &c) in counts.iter().enumerate() {
                        cum += c;
                        if c == 0 && i != LatencyHist::N_BUCKETS - 1 {
                            continue; // cumulative value carries over
                        }
                        let bound = LatencyHist::bucket_bound(i);
                        let le = if bound.is_infinite() {
                            "+Inf".to_string()
                        } else {
                            format!("{bound:e}")
                        };
                        out.push_str(&format!(
                            "{full}_bucket{} {cum}\n",
                            labels_text(labels, Some(("le", &le)))
                        ));
                    }
                    let lt = labels_text(labels, None);
                    out.push_str(&format!("{full}_sum{lt} {}\n", h.sum()));
                    out.push_str(&format!("{full}_count{lt} {}\n", h.count()));
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.set_max(3);
        assert_eq!(g.get(), 7);
        g.set_max(11);
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn atomic_running_matches_sequential() {
        let a = AtomicRunning::new();
        let mut r = Running::new();
        for i in 1..=100 {
            let x = i as f64;
            a.push(x);
            r.push(x);
        }
        let s = a.snapshot();
        assert_eq!(s.count(), r.count());
        assert!((s.mean() - r.mean()).abs() < 1e-9);
        assert!((s.var() - r.var()).abs() < 1e-6);
        assert_eq!(s.min(), r.min());
        assert_eq!(s.max(), r.max());
    }

    #[test]
    fn hist_matches_latency_hist_buckets() {
        let h = Hist::new();
        let mut oracle = LatencyHist::new();
        for i in 1..=500 {
            let x = i as f64 * 2e-5;
            h.record(x);
            oracle.record(x);
        }
        assert_eq!(h.count(), 500);
        assert_eq!(h.bucket_counts(), oracle.bucket_counts());
        assert!((h.sum() - 500.0 * 501.0 / 2.0 * 2e-5).abs() < 1e-9);
    }

    #[test]
    fn registry_is_idempotent_and_renders() {
        let reg = Registry::new();
        reg.counter("requests").add(3);
        reg.counter("requests").add(2); // same handle
        reg.counter_with("by_format", &[("format", "int8")]).inc();
        reg.gauge("depth").set(4);
        reg.hist("lat").record(1e-3);
        reg.running("batch").push(2.0);
        assert_eq!(reg.counter("requests").get(), 5);

        let json = reg.snapshot_json();
        assert_eq!(json.get("requests").and_then(|j| j.as_f64()), Some(5.0));
        assert!(json.get("by_format{format=\"int8\"}").is_some());

        let prom = reg.prometheus("mfqat");
        assert!(prom.contains("# TYPE mfqat_requests_total counter"), "{prom}");
        assert!(prom.contains("mfqat_requests_total 5"), "{prom}");
        assert!(prom.contains("mfqat_by_format_total{format=\"int8\"} 1"), "{prom}");
        assert!(prom.contains("mfqat_lat_bucket"), "{prom}");
        assert!(prom.contains("le=\"+Inf\"} 1"), "{prom}");
        assert!(prom.contains("mfqat_lat_count 1"), "{prom}");
        assert!(prom.contains("mfqat_batch_mean 2"), "{prom}");
    }
}
