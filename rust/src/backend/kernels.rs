//! Native CPU compute kernels over packed MX tensors.
//!
//! The centerpiece is [`gemm_packed`]: `y = x @ W` where `W` stays in its
//! packed microscaling form — sub-byte integer or minifloat element codes
//! plus one E8M0 scale exponent per block. The per-block scale is fused into
//! the dot product (`y += (x_k · 2^{s_{k,j}}) · P_{k,n}`), so no f32 weight
//! buffer is ever materialized: the working set is the packed codes (2–8
//! bits/element), which is why lower-precision formats stream less memory
//! per batch — the elastic-serving speed knob the paper motivates (§1).
//!
//! Mirrors the pure-`jnp` oracle in `python/compile/kernels/ref.py`
//! (`mx_matmul_ref` = dequantize-then-f32-matmul); parity is enforced by
//! unit tests here and end-to-end by `rust/tests/native_backend.rs`.
//!
//! Threading: std scoped threads over contiguous row tiles
//! ([`par_chunks_mut`]); `MFQAT_THREADS` pins the worker count (benches,
//! reproducibility).

use crate::formats::{exp2i, pack};
use crate::tensor::MxTensor;

/// Worker threads for the native kernels (`MFQAT_THREADS` overrides the
/// detected core count; decided once per process).
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("MFQAT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// Below this many elements the fan-out cost exceeds the win; run serial.
const PAR_MIN_LEN: usize = 1 << 15;

/// Rows of `y` processed per tile in the GEMM kernels (amortizes the
/// per-`k` code-row and scale-row setup across the tile).
const ROW_TILE: usize = 32;

/// Apply `f(chunk_index, chunk)` to consecutive `chunk`-sized pieces of
/// `data`, fanned out over scoped threads (serial for small inputs). Chunks
/// are disjoint, so the closure may freely mutate its piece.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk);
    let nt = num_threads().min(n_chunks);
    if nt <= 1 || data.len() < PAR_MIN_LEN {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let per = n_chunks.div_ceil(nt);
    std::thread::scope(|s| {
        for (g, group) in data.chunks_mut(per * chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, c) in group.chunks_mut(chunk).enumerate() {
                    f(g * per + i, c);
                }
            });
        }
    });
}

/// `y[r, :] = x[r, :] @ W` with `W` a packed 2-D [`MxTensor`] of shape
/// `[in_features, out_features]` (scaling blocks along the out dimension,
/// the layout `MxTensor::quantize` produces for the model's `[in, out]`
/// weight matrices).
///
/// Weights are consumed directly from the packed stream: each row tile
/// unpacks one `out_features`-code weight row at a time into a small
/// L1-resident scratch (amortized over [`ROW_TILE`] batch rows), so the
/// memory traffic per batch is the *packed* plane — `bits(f)`/element —
/// and no full decoded plane is ever allocated.
pub fn gemm_packed(x: &[f32], rows: usize, w: &MxTensor, y: &mut [f32]) {
    assert_eq!(w.shape.len(), 2, "packed GEMM wants a 2-D weight");
    let in_f = w.shape[0];
    let out_f = w.shape[1];
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 || in_f == 0 || out_f == 0 {
        if out_f > 0 {
            y.fill(0.0);
        }
        return;
    }
    let bs = w.format.block_size;
    let bpr = out_f.div_ceil(bs);
    let wbits = w.format.elem.bits();
    debug_assert_eq!(w.scales.len(), in_f * bpr);
    // Minifloat codes decode through a 256-entry value LUT; integer codes
    // sign-extend to the element value directly.
    let lut: Option<Vec<f32>> = w.format.elem.fp_spec().map(|spec| {
        let mask = ((1u16 << spec.bits()) - 1) as u8;
        (0..256u16).map(|b| spec.decode(b as u8 & mask)).collect()
    });
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        let mut sc = vec![0.0f32; bpr];
        let mut int_row = vec![0i8; out_f];
        let mut fp_row = vec![0u8; out_f];
        for k in 0..in_f {
            for (j, &s) in w.scales[k * bpr..(k + 1) * bpr].iter().enumerate() {
                sc[j] = exp2i(s as i32);
            }
            // Unpack weight row `k` straight out of the packed stream.
            if lut.is_none() {
                pack::unpack_signed_at(&w.packed, wbits, k * out_f, &mut int_row);
            } else {
                pack::unpack_unsigned_at(&w.packed, wbits, k * out_f, &mut fp_row);
            }
            for r in 0..rn {
                let xv = x[(r0 + r) * in_f + k];
                if xv == 0.0 {
                    continue;
                }
                let yr = &mut yc[r * out_f..(r + 1) * out_f];
                match &lut {
                    // MXINT path: y += (x_k · scale_j) · code.
                    None => {
                        for (j, &s) in sc.iter().enumerate() {
                            let f = xv * s;
                            let n0 = j * bs;
                            let n1 = (n0 + bs).min(out_f);
                            for n in n0..n1 {
                                yr[n] += f * int_row[n] as f32;
                            }
                        }
                    }
                    // MXFP path: same shape, element value via the LUT.
                    Some(lut) => {
                        for (j, &s) in sc.iter().enumerate() {
                            let f = xv * s;
                            let n0 = j * bs;
                            let n1 = (n0 + bs).min(out_f);
                            for n in n0..n1 {
                                yr[n] += f * lut[fp_row[n] as usize];
                            }
                        }
                    }
                }
            }
        }
    });
}

/// `y[r, :] = x[r, :] @ W` for a dense f32 weight `[in_features,
/// out_features]` — the reference oracle path (dequantize-then-matmul) and
/// the kernel for unquantized parameters (`head`). Same loop structure and
/// summation order as [`gemm_packed`] so the two paths are comparable to
/// float-rounding error.
pub fn gemm_dense(x: &[f32], rows: usize, w: &[f32], in_f: usize, out_f: usize, y: &mut [f32]) {
    assert_eq!(x.len(), rows * in_f, "x must be [rows, in_features]");
    assert_eq!(w.len(), in_f * out_f, "w must be [in_features, out_features]");
    assert_eq!(y.len(), rows * out_f, "y must be [rows, out_features]");
    if rows == 0 {
        return;
    }
    par_chunks_mut(y, ROW_TILE * out_f, |ci, yc| {
        let r0 = ci * ROW_TILE;
        let rn = yc.len() / out_f;
        yc.fill(0.0);
        for k in 0..in_f {
            let wrow = &w[k * out_f..(k + 1) * out_f];
            for r in 0..rn {
                let xv = x[(r0 + r) * in_f + k];
                if xv == 0.0 {
                    continue;
                }
                let yr = &mut yc[r * out_f..(r + 1) * out_f];
                for (yv, &wv) in yr.iter_mut().zip(wrow) {
                    *yv += xv * wv;
                }
            }
        }
    });
}

/// RMSNorm over the last dimension: `out = x · rsqrt(mean(x²) + 1e-6) · g`
/// (matches `_rmsnorm` in `python/compile/model.py`).
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    let d = gain.len();
    assert!(d > 0 && x.len() % d == 0, "x must be [n, {d}]");
    assert_eq!(x.len(), out.len());
    for (xr, or) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-6).sqrt();
        for ((o, &v), &g) in or.iter_mut().zip(xr).zip(gain) {
            *o = v * r * g;
        }
    }
}

/// Tanh-approximate GELU, in place (jax.nn.gelu `approximate=True`).
pub fn gelu_in_place(x: &mut [f32]) {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    for v in x.iter_mut() {
        let u = *v;
        let inner = SQRT_2_OVER_PI * (u + 0.044_715 * u * u * u);
        *v = 0.5 * u * (1.0 + inner.tanh());
    }
}

/// `acc += delta`, element-wise (residual connections).
pub fn add_assign(acc: &mut [f32], delta: &[f32]) {
    assert_eq!(acc.len(), delta.len());
    for (a, &b) in acc.iter_mut().zip(delta) {
        *a += b;
    }
}

/// Multi-head causal self-attention.
///
/// `qkv` is the fused projection output `[rows·t, 3·d_model]` (row `b·t + i`
/// holds `[q | k | v]` for sequence `b`, position `i`); `out` is
/// `[rows·t, d_model]`. Softmax is computed per (sequence, head, query) over
/// the causal prefix — numerically identical to the python reference's
/// masked full-softmax (masked scores underflow to exactly 0 probability).
pub fn causal_attention(
    qkv: &[f32],
    rows: usize,
    t: usize,
    n_heads: usize,
    d_model: usize,
    out: &mut [f32],
) {
    assert!(n_heads > 0 && d_model % n_heads == 0);
    assert_eq!(qkv.len(), rows * t * 3 * d_model);
    assert_eq!(out.len(), rows * t * d_model);
    let hd = d_model / n_heads;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    par_chunks_mut(out, t * d_model, |b, ob| {
        ob.fill(0.0);
        let base = b * t * 3 * d_model;
        let mut probs = vec![0.0f32; t];
        for h in 0..n_heads {
            let qo = h * hd;
            let ko = d_model + h * hd;
            let vo = 2 * d_model + h * hd;
            for i in 0..t {
                let q = &qkv[base + i * 3 * d_model + qo..][..hd];
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=i {
                    let krow = &qkv[base + j * 3 * d_model + ko..][..hd];
                    let mut s = 0.0f32;
                    for (&a, &k) in q.iter().zip(krow) {
                        s += a * k;
                    }
                    let s = s * inv_sqrt;
                    probs[j] = s;
                    if s > max_s {
                        max_s = s;
                    }
                }
                let mut denom = 0.0f32;
                for p in probs[..=i].iter_mut() {
                    *p = (*p - max_s).exp();
                    denom += *p;
                }
                let inv_denom = 1.0 / denom;
                let orow = &mut ob[i * d_model + qo..i * d_model + qo + hd];
                for j in 0..=i {
                    let wgt = probs[j] * inv_denom;
                    let vrow = &qkv[base + j * 3 * d_model + vo..][..hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += wgt * vv;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{ElementFormat, MxFormat};
    use crate::util::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    fn naive_matmul(x: &[f32], rows: usize, w: &[f32], in_f: usize, out_f: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; rows * out_f];
        for r in 0..rows {
            for n in 0..out_f {
                let mut acc = 0.0f64;
                for k in 0..in_f {
                    acc += x[r * in_f + k] as f64 * w[k * out_f + n] as f64;
                }
                y[r * out_f + n] = acc as f32;
            }
        }
        y
    }

    #[test]
    fn dense_gemm_matches_naive() {
        let (rows, in_f, out_f) = (5, 48, 33);
        let x = randvec(rows * in_f, 1);
        let w = randvec(in_f * out_f, 2);
        let mut y = vec![0.0f32; rows * out_f];
        gemm_dense(&x, rows, &w, in_f, out_f, &mut y);
        let want = naive_matmul(&x, rows, &w, in_f, out_f);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_gemm_matches_dequantized_dense() {
        // The fused-scale packed path must equal dequantize-then-f32-matmul
        // (the ref.py mx_matmul_ref oracle) to float rounding error.
        for fmt in [
            ElementFormat::int(4),
            ElementFormat::int(6),
            ElementFormat::int(8),
            ElementFormat::fp_from_bits(4),
            ElementFormat::fp_from_bits(6),
            ElementFormat::fp_from_bits(8),
        ] {
            let (rows, in_f, out_f) = (7, 64, 96);
            let x = randvec(rows * in_f, 3);
            let wdata = randvec(in_f * out_f, 4);
            let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::new(fmt, 32)).unwrap();
            let wd = w.dequantize();
            let mut y_packed = vec![0.0f32; rows * out_f];
            let mut y_dense = vec![0.0f32; rows * out_f];
            gemm_packed(&x, rows, &w, &mut y_packed);
            gemm_dense(&x, rows, &wd, in_f, out_f, &mut y_dense);
            for (i, (a, b)) in y_packed.iter().zip(&y_dense).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{}[{i}]: packed {a} vs dense {b}",
                    fmt.long_name()
                );
            }
        }
    }

    #[test]
    fn packed_gemm_handles_ragged_blocks_and_row_tiles() {
        // out_f not a multiple of the block size; rows beyond one ROW_TILE.
        let (rows, in_f, out_f) = (ROW_TILE + 3, 32, 40);
        let x = randvec(rows * in_f, 5);
        let wdata = randvec(in_f * out_f, 6);
        let w = MxTensor::quantize(&wdata, &[in_f, out_f], MxFormat::mxint(5, 32)).unwrap();
        let wd = w.dequantize();
        let mut y_packed = vec![0.0f32; rows * out_f];
        gemm_packed(&x, rows, &w, &mut y_packed);
        let want = naive_matmul(&x, rows, &wd, in_f, out_f);
        for (a, b) in y_packed.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn rmsnorm_scales_to_unit_rms() {
        let d = 16;
        let x = randvec(3 * d, 7);
        let gain = vec![1.0f32; d];
        let mut out = vec![0.0f32; x.len()];
        rmsnorm(&x, &gain, &mut out);
        for row in out.chunks_exact(d) {
            let rms = (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
            assert!((rms - 1.0).abs() < 1e-2, "rms={rms}");
        }
    }

    #[test]
    fn gelu_known_values() {
        let mut x = vec![0.0f32, 10.0, -10.0, 1.0];
        gelu_in_place(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 10.0).abs() < 1e-4);
        assert!(x[2].abs() < 1e-4);
        assert!((x[3] - 0.8412).abs() < 1e-3); // gelu(1) ≈ 0.8412
    }

    #[test]
    fn attention_with_one_position_returns_v() {
        // t = 1: softmax over a single score is 1, so out == v.
        let (rows, t, heads, d) = (2, 1, 2, 8);
        let qkv = randvec(rows * t * 3 * d, 8);
        let mut out = vec![0.0f32; rows * t * d];
        causal_attention(&qkv, rows, t, heads, d, &mut out);
        for b in 0..rows {
            let v = &qkv[b * 3 * d + 2 * d..][..d];
            let o = &out[b * d..][..d];
            for (a, e) in o.iter().zip(v) {
                assert!((a - e).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attention_is_causal() {
        // The output at position i must not change when future positions do.
        let (rows, t, heads, d) = (1, 6, 2, 8);
        let qkv = randvec(rows * t * 3 * d, 9);
        let mut full = vec![0.0f32; t * d];
        causal_attention(&qkv, rows, t, heads, d, &mut full);
        let t2 = 4;
        let mut prefix = vec![0.0f32; t2 * d];
        causal_attention(&qkv[..t2 * 3 * d], rows, t2, heads, d, &mut prefix);
        for i in 0..t2 * d {
            assert_eq!(full[i], prefix[i], "position {} differs", i / d);
        }
    }

    #[test]
    fn par_chunks_mut_visits_every_chunk_once() {
        let mut data = vec![0u32; 100_000];
        par_chunks_mut(&mut data, 7, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        for (pos, &v) in data.iter().enumerate() {
            assert_eq!(v, (pos / 7) as u32 + 1, "pos {pos}");
        }
    }
}
