//! Packed microscaling tensors — the storage and conversion unit of the
//! elastic-inference pipeline.
//!
//! An [`MxTensor`] holds a tensor quantized to one MX format: bit-packed
//! element codes plus one `i8` shared-scale exponent per block. Blocks run
//! along the last dimension and never cross rows (a ragged final block per
//! row is allowed). This is the in-memory *and* checkpoint layout; the
//! anchor-checkpoint workflow of the paper (§3.5) is
//! `MxTensor::quantize(fp32, anchor)` → store → [`MxTensor::slice_and_scale`]
//! → [`MxTensor::dequantize`] into the serving weight buffer.

use crate::formats::int::{int_range, shift_round};
use crate::formats::mxblock::{self, MxBlock, RoundMode, SCALE_EXP_MAX};
use crate::formats::{exp2i, pack, ElementFormat, MxFormat};
use anyhow::{bail, Result};

/// A tensor stored in a microscaling format.
#[derive(Debug, Clone, PartialEq)]
pub struct MxTensor {
    /// The microscaling format (element type + block size).
    pub format: MxFormat,
    /// Logical tensor shape (row-major).
    pub shape: Vec<usize>,
    /// One scale exponent per block, row-major block order.
    pub scales: Vec<i8>,
    /// Bit-packed element codes, one contiguous plane.
    pub packed: Vec<u8>,
}

impl MxTensor {
    /// Quantize dense f32 data into the given MX format (paper Eq. 1–3).
    pub fn quantize(data: &[f32], shape: &[usize], format: MxFormat) -> Result<MxTensor> {
        Self::quantize_mode(data, shape, format, RoundMode::HalfEven)
    }

    /// Quantize with an explicit rounding mode (ablation support).
    pub fn quantize_mode(
        data: &[f32],
        shape: &[usize],
        format: MxFormat,
        mode: RoundMode,
    ) -> Result<MxTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
        }
        let row_len = shape.last().copied().unwrap_or(1).max(1);
        let rows = if n == 0 { 0 } else { n / row_len };
        let bs = format.block_size;
        let bpr = row_len.div_ceil(bs);
        let mut scales = Vec::with_capacity(rows * bpr);
        let mut codes: Vec<i8> = Vec::with_capacity(n);
        for r in 0..rows {
            let row = &data[r * row_len..(r + 1) * row_len];
            for chunk in row.chunks(bs) {
                let block = mxblock::encode_block(chunk, format.elem, mode);
                scales.push(block.scale_exp);
                codes.extend_from_slice(&block.codes);
            }
        }
        let packed = pack::pack(&codes, format.elem.bits());
        Ok(MxTensor {
            format,
            shape: shape.to_vec(),
            scales,
            packed,
        })
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks per row (ragged tail included).
    pub fn blocks_per_row(&self) -> usize {
        self.row_len().div_ceil(self.format.block_size)
    }

    fn row_len(&self) -> usize {
        self.shape.last().copied().unwrap_or(1).max(1)
    }

    fn rows(&self) -> usize {
        if self.len() == 0 {
            0
        } else {
            self.len() / self.row_len()
        }
    }

    /// Storage footprint in bytes (packed codes + scales).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scales.len()
    }

    /// Unpack the full code plane (sign-extended for int formats, raw codes
    /// for fp formats).
    pub fn unpack_codes(&self) -> Vec<i8> {
        let w = self.format.elem.bits();
        let n = self.len();
        if self.format.elem.is_int() {
            pack::unpack_signed(&self.packed, w, n)
        } else {
            pack::unpack_unsigned(&self.packed, w, n)
                .into_iter()
                .map(|c| c as i8)
                .collect()
        }
    }

    /// Dequantize to dense f32.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.dequantize_into(&mut out);
        out
    }

    /// Dequantize into a caller-provided buffer (serving hot path).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        let codes = self.unpack_codes();
        let bs = self.format.block_size;
        let row_len = self.row_len();
        let bpr = self.blocks_per_row();
        match self.format.elem {
            ElementFormat::Int { .. } => {
                for r in 0..self.rows() {
                    for b in 0..bpr {
                        let scale = exp2i(self.scales[r * bpr + b] as i32);
                        let start = r * row_len + b * bs;
                        let end = (start + bs).min((r + 1) * row_len);
                        for i in start..end {
                            out[i] = codes[i] as f32 * scale;
                        }
                    }
                }
            }
            ElementFormat::Fp { .. } => {
                let spec = self.format.elem.fp_spec().unwrap();
                // Decode LUT over the full code byte (sign included).
                let nbits = spec.bits();
                let lut: Vec<f32> = (0..(1u16 << nbits))
                    .map(|c| spec.decode(c as u8))
                    .collect();
                for r in 0..self.rows() {
                    for b in 0..bpr {
                        let scale = exp2i(self.scales[r * bpr + b] as i32);
                        let start = r * row_len + b * bs;
                        let end = (start + bs).min((r + 1) * row_len);
                        for i in start..end {
                            out[i] = lut[(codes[i] as u8) as usize & ((1 << nbits) - 1)] * scale;
                        }
                    }
                }
            }
        }
    }

    /// Slice-and-Scale conversion to a lower-precision element format
    /// (paper §3.3/§3.4) — no FP32 weights involved.
    pub fn slice_and_scale(&self, target: ElementFormat) -> Result<MxTensor> {
        self.slice_and_scale_mode(target, RoundMode::HalfEven)
    }

    /// Slice-and-Scale with an explicit rounding mode.
    pub fn slice_and_scale_mode(
        &self,
        target: ElementFormat,
        mode: RoundMode,
    ) -> Result<MxTensor> {
        let codes = self.unpack_codes();
        let mut out_codes = vec![0i8; codes.len()];
        let mut out_scales = vec![0i8; self.scales.len()];
        match (self.format.elem, target) {
            (ElementFormat::Int { bits: bh }, ElementFormat::Int { bits: bl }) => {
                if bl > bh {
                    bail!("SSMXINT requires b_l <= b_h (got {bh} -> {bl})");
                }
                let de = (bh - bl) as u32;
                let (lo, hi) = int_range(bl);
                // Element transform is block-independent: shift+round+clip.
                for (o, &c) in out_codes.iter_mut().zip(&codes) {
                    *o = shift_round(c as i32, de, mode).clamp(lo, hi) as i8;
                }
                for (o, &s) in out_scales.iter_mut().zip(&self.scales) {
                    *o = ((s as i32 + de as i32).min(SCALE_EXP_MAX)) as i8;
                }
            }
            (ElementFormat::Fp { .. }, ElementFormat::Fp { .. }) => {
                let sh = self.format.elem.fp_spec().unwrap();
                let sl = target.fp_spec().unwrap();
                if sl.emax() > sh.emax() || (sl.emax() == sh.emax() && sl.m > sh.m) {
                    bail!(
                        "SSMXFP requires a lower-precision target ({} -> {})",
                        self.format.elem,
                        target
                    );
                }
                let de = sh.emax() - sl.emax();
                let down = exp2i(-de);
                // Requantization LUT: high code → low code (256 entries max).
                let hbits = sh.bits();
                let lut: Vec<i8> = (0..(1u16 << hbits))
                    .map(|c| sl.quantize_code(sh.decode(c as u8) * down) as i8)
                    .collect();
                let hmask = (1u16 << hbits) - 1;
                for (o, &c) in out_codes.iter_mut().zip(&codes) {
                    *o = lut[((c as u8) as u16 & hmask) as usize];
                }
                for (o, &s) in out_scales.iter_mut().zip(&self.scales) {
                    *o = ((s as i32 + de).min(SCALE_EXP_MAX)) as i8;
                }
            }
            _ => bail!(
                "slice-and-scale cannot cross element families ({} -> {})",
                self.format.elem,
                target
            ),
        }
        Ok(MxTensor {
            format: MxFormat::new(target, self.format.block_size),
            shape: self.shape.clone(),
            scales: out_scales,
            packed: pack::pack(&out_codes, target.bits()),
        })
    }

    /// Extract one block (for tests / inspection).
    pub fn block(&self, row: usize, block_in_row: usize) -> MxBlock {
        let bs = self.format.block_size;
        let row_len = self.row_len();
        let bpr = self.blocks_per_row();
        let codes = self.unpack_codes();
        let start = row * row_len + block_in_row * bs;
        let end = (start + bs).min((row + 1) * row_len);
        MxBlock {
            format: self.format.elem,
            scale_exp: self.scales[row * bpr + block_in_row],
            codes: codes[start..end].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::props::{run_cases, Gen};
    use crate::util::stats::mse;
    use crate::util::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn quantize_dequantize_matches_blockwise_reference() {
        let data = randvec(4 * 96, 1);
        let fmt = MxFormat::mxint(6, 32);
        let t = MxTensor::quantize(&data, &[4, 96], fmt).unwrap();
        let got = t.dequantize();
        let want = mxblock::fake_quantize(&data, fmt.elem, 32, RoundMode::HalfEven);
        // Rows are 96 = 3 blocks each; fake_quantize on the flat slice has the
        // same block boundaries here because 96 % 32 == 0.
        assert_eq!(got, want);
    }

    #[test]
    fn blocks_do_not_cross_rows() {
        // Rows of 48 with block 32: per-row blocks are [32, 16]; a flat
        // 96-element quantization would instead put elements 32..64 together.
        let mut data = vec![0.01f32; 2 * 48];
        data[47] = 100.0; // spike at the end of row 0
        let t = MxTensor::quantize(&data, &[2, 48], MxFormat::mxint(8, 32)).unwrap();
        // Row 1 scales must be unaffected by the row-0 spike.
        let bpr = t.blocks_per_row();
        assert_eq!(bpr, 2);
        let s_row1 = &t.scales[bpr..];
        let t_clean = MxTensor::quantize(&vec![0.01f32; 48], &[1, 48], MxFormat::mxint(8, 32))
            .unwrap();
        assert_eq!(s_row1, &t_clean.scales[..]);
    }

    #[test]
    fn storage_footprint() {
        let data = randvec(1024, 2);
        let t = MxTensor::quantize(&data, &[1, 1024], MxFormat::mxint(4, 32)).unwrap();
        assert_eq!(t.packed.len(), 1024 * 4 / 8);
        assert_eq!(t.scales.len(), 32);
        assert_eq!(t.storage_bytes(), 512 + 32);
        // 8x smaller than f32 (plus scales).
        assert!(t.storage_bytes() < 1024 * 4 / 7);
    }

    #[test]
    fn ss_matches_blockwise_ss() {
        let data = randvec(8 * 64, 3);
        let anchor = MxTensor::quantize(&data, &[8, 64], MxFormat::mxint(8, 32)).unwrap();
        let low = anchor.slice_and_scale(ElementFormat::int(4)).unwrap();
        // Compare each block against the block-level SS reference.
        for r in 0..8 {
            for b in 0..anchor.blocks_per_row() {
                let hb = anchor.block(r, b);
                let want =
                    crate::formats::ss::slice_and_scale(&hb, ElementFormat::int(4), RoundMode::HalfEven)
                        .unwrap();
                let got = low.block(r, b);
                assert_eq!(got, want, "r={r} b={b}");
            }
        }
    }

    #[test]
    fn ss_fp_matches_blockwise_ss() {
        let data = randvec(4 * 64, 4);
        let anchor = MxTensor::quantize(&data, &[4, 64], MxFormat::mxfp(8, 32)).unwrap();
        for bits in 4..=7u8 {
            let tgt = ElementFormat::fp_from_bits(bits);
            let low = anchor.slice_and_scale(tgt).unwrap();
            for r in 0..4 {
                for b in 0..anchor.blocks_per_row() {
                    let hb = anchor.block(r, b);
                    let want = crate::formats::ss::slice_and_scale(&hb, tgt, RoundMode::HalfEven)
                        .unwrap();
                    assert_eq!(low.block(r, b), want, "bits={bits} r={r} b={b}");
                }
            }
        }
    }

    #[test]
    fn prop_ss_tensor_close_to_direct() {
        run_cases("tensor SS ≈ direct", 24, |g: &mut Gen| {
            let rows = g.len(1, 4);
            let cols = 64;
            let data: Vec<f32> = (0..rows * cols).map(|_| g.rng.normal()).collect();
            let anchor =
                MxTensor::quantize(&data, &[rows, cols], MxFormat::mxint(8, 32)).unwrap();
            for bits in [2u8, 4, 6] {
                let ss = anchor.slice_and_scale(ElementFormat::int(bits)).unwrap();
                let direct =
                    MxTensor::quantize(&data, &[rows, cols], MxFormat::mxint(bits, 32)).unwrap();
                let m_ss = mse(&data, &ss.dequantize());
                let m_direct = mse(&data, &direct.dequantize());
                if m_ss > m_direct * 2.5 + 1e-12 {
                    return Err(format!("bits={bits}: {m_ss} vs {m_direct}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ragged_rows() {
        let data = randvec(3 * 40, 5);
        let t = MxTensor::quantize(&data, &[3, 40], MxFormat::mxint(5, 32)).unwrap();
        assert_eq!(t.blocks_per_row(), 2);
        assert_eq!(t.scales.len(), 6);
        let dec = t.dequantize();
        assert_eq!(dec.len(), 120);
        // Error bound still holds on the ragged tail.
        for (v, d) in data.iter().zip(&dec) {
            assert!((v - d).abs() < 0.2, "v={v} d={d}");
        }
    }

    #[test]
    fn scalar_and_empty_shapes() {
        let t = MxTensor::quantize(&[1.5], &[1], MxFormat::mxint(8, 32)).unwrap();
        assert_eq!(t.dequantize().len(), 1);
        assert!((t.dequantize()[0] - 1.5).abs() < 0.02);
        let e = MxTensor::quantize(&[], &[0], MxFormat::mxint(8, 32)).unwrap();
        assert_eq!(e.dequantize().len(), 0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(MxTensor::quantize(&[1.0; 5], &[2, 3], MxFormat::mxint(8, 32)).is_err());
    }

    #[test]
    fn fp_tensor_roundtrip_quality_improves_with_mantissa() {
        // MXFP MSE is dominated by the mantissa width, which grows every
        // two bitwidths (E2M1→E2M2→E3M2→E3M3→E4M3). Adjacent bitwidths
        // need not be monotone — e.g. MXFP8 (E4M3) can lose to MXFP7 (E3M3)
        // because E4M3's NaN slot clips the block max at 448/512 of the top
        // binade (the paper's Table 2 likewise shows MXFP7 ≥ MXFP8 rows).
        let data = randvec(2048, 6);
        let m: Vec<f64> = [4u8, 5, 6, 7, 8]
            .iter()
            .map(|&bits| {
                let t =
                    MxTensor::quantize(&data, &[2, 1024], MxFormat::mxfp(bits, 32)).unwrap();
                mse(&data, &t.dequantize())
            })
            .collect();
        assert!(m[2] < m[0], "fp6 < fp4: {m:?}"); // +1 mantissa bit
        assert!(m[3] < m[1], "fp7 < fp5: {m:?}");
        assert!(m[4] < m[0], "fp8 < fp4: {m:?}");
        assert!(m[3] < m[0], "fp7 < fp4: {m:?}");
    }

    #[test]
    fn int_tensor_roundtrip_quality_improves_with_bits() {
        let data = randvec(2048, 7);
        let mut last = f64::INFINITY;
        for bits in 2..=8u8 {
            let t = MxTensor::quantize(&data, &[2, 1024], MxFormat::mxint(bits, 32)).unwrap();
            let m = mse(&data, &t.dequantize());
            assert!(m < last, "bits={bits}: {m} !< {last}");
            last = m;
        }
    }
}
