//! Elastic inference server: request queue → dynamic batcher → worker.
//!
//! The deployment story the paper motivates (§1): one device, one anchor
//! checkpoint, and the *numeric format chosen per batch* based on current
//! load. The server owns a worker thread with the [`ElasticEngine`]; clients
//! submit scoring requests over a channel; the batcher groups up to
//! `train_batch` requests inside a gather window; the [`policy`] maps queue
//! depth to the serving format; metrics record latency/throughput/format mix.

pub mod costmodel;
pub mod metrics;
pub mod policy;

pub use costmodel::HwModel;
pub use metrics::Metrics;
pub use policy::{Policy, SloState};

use crate::coordinator::ElasticEngine;
use crate::formats::ElementFormat;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A scoring request: one token window of width `seq_len + 1` (shorter
/// windows are right-padded by the caller). `format` pins a precision;
/// `None` lets the policy decide.
pub struct ScoreRequest {
    pub tokens: Vec<i32>,
    pub format: Option<ElementFormat>,
    pub respond: Sender<Result<ScoreResponse, String>>,
    pub enqueued: Instant,
}

/// The response: per-sequence mean NLL plus serving telemetry.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub nll: f32,
    pub format: ElementFormat,
    pub batch_size: usize,
    pub queue_depth: usize,
    pub latency: Duration,
}

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub policy: Policy,
    /// How long the batcher waits to fill a batch.
    pub gather_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: Policy::default_ladder(),
            gather_window: Duration::from_millis(2),
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<ScoreRequest>,
    pub metrics: Arc<Mutex<Metrics>>,
    worker: Option<std::thread::JoinHandle<()>>,
    alive: Arc<AtomicBool>,
}

/// Client handle (cheap to clone).
#[derive(Clone)]
pub struct Client {
    tx: Sender<ScoreRequest>,
    width: usize,
    /// Cleared on shutdown — a live client must not enqueue into a queue
    /// nobody drains (its own `tx` clone keeps the channel open).
    alive: Arc<AtomicBool>,
}

impl Client {
    /// Submit and wait. `tokens` is truncated / right-padded to the window.
    pub fn score(&self, tokens: &[i32], format: Option<ElementFormat>) -> Result<ScoreResponse> {
        let rx = self.submit(tokens, format)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit without waiting; returns the response channel.
    pub fn submit(
        &self,
        tokens: &[i32],
        format: Option<ElementFormat>,
    ) -> Result<Receiver<Result<ScoreResponse, String>>> {
        if !self.alive.load(Ordering::Acquire) {
            anyhow::bail!("server is shut down");
        }
        let mut t = tokens.to_vec();
        t.truncate(self.width);
        t.resize(self.width, crate::data::PAD as i32);
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(ScoreRequest {
                tokens: t,
                format,
                respond: tx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }
}

impl Server {
    /// Start the worker thread.
    ///
    /// PJRT handles are not `Send`, so the [`ElasticEngine`] must be *built
    /// inside* the worker: `factory` runs on the worker thread and its error
    /// (if any) is returned from `start`. `width` is `seq_len + 1` of the
    /// serving model (used for client-side padding).
    pub fn start<F>(width: usize, factory: F, config: ServerConfig) -> Result<(Server, Client)>
    where
        F: FnOnce() -> Result<ElasticEngine> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ScoreRequest>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let m2 = metrics.clone();
        let alive = Arc::new(AtomicBool::new(true));
        let alive_worker = alive.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("mfqat-server".into())
            .spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        alive_worker.store(false, Ordering::Release);
                        return;
                    }
                };
                worker_loop(engine, config, rx, m2, &alive_worker);
                alive_worker.store(false, Ordering::Release);
            })
            .expect("spawn server worker");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine init failed: {e}"))?;
        let client = Client {
            tx: tx.clone(),
            width,
            alive: alive.clone(),
        };
        Ok((
            Server {
                tx,
                metrics,
                worker: Some(worker),
                alive,
            },
            client,
        ))
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        // Mark dead first so live clients stop enqueueing (their tx clones
        // keep the channel open), then drop our sender and join.
        self.alive.store(false, Ordering::Release);
        drop(std::mem::replace(&mut self.tx, mpsc::channel().0));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(
    engine: ElasticEngine,
    config: ServerConfig,
    rx: Receiver<ScoreRequest>,
    metrics: Arc<Mutex<Metrics>>,
    alive: &AtomicBool,
) {
    let b = engine.dims().train_batch;
    let width = engine.dims().seq_len + 1;
    let mut backlog: Vec<ScoreRequest> = Vec::new();
    let mut slo = SloState::default();
    loop {
        // Wait for the first request, polling the shutdown flag (client tx
        // clones can keep the channel open past Server::shutdown).
        if backlog.is_empty() {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => backlog.push(r),
                Err(RecvTimeoutError::Timeout) => {
                    if alive.load(Ordering::Acquire) {
                        continue;
                    }
                    break; // shutdown requested
                }
                Err(RecvTimeoutError::Disconnected) => break, // all senders dropped
            }
        }
        let deadline = Instant::now() + config.gather_window;
        while backlog.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => backlog.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Drain anything already queued (for depth measurement + batching).
        while let Ok(r) = rx.try_recv() {
            backlog.push(r);
        }

        let queue_depth = backlog.len();
        let batch: Vec<ScoreRequest> = backlog.drain(..backlog.len().min(b)).collect();
        // Unpinned requests take the policy's pick for the *total* queue
        // depth; pinned requests must be served at their pin, so the batch
        // splits into per-format sub-batches (one execution each) instead
        // of letting the first pin silently win for everyone.
        let policy_fmt = config.policy.choose_with(queue_depth, &slo);
        let mut groups: Vec<(ElementFormat, Vec<ScoreRequest>)> = Vec::new();
        for r in batch {
            let fmt = r.format.unwrap_or(policy_fmt);
            match groups.iter_mut().find(|(f, _)| *f == fmt) {
                Some((_, reqs)) => reqs.push(r),
                None => groups.push((fmt, vec![r])),
            }
        }

        for (fmt, group) in groups {
            let t0 = Instant::now();
            // Sub-batches execute at their true size; only the PJRT graph
            // pads internally to its fixed batch shape.
            let mut flat = Vec::with_capacity(group.len() * width);
            for r in &group {
                flat.extend_from_slice(&r.tokens);
            }
            let result = engine.score_batch(&flat, fmt);
            let elapsed = t0.elapsed();
            slo.observe(&config.policy, elapsed.as_secs_f64());

            match result {
                Ok(nlls) => {
                    let bs = group.len();
                    let latencies: Vec<Duration> =
                        group.iter().map(|r| r.enqueued.elapsed()).collect();
                    // One metrics lock per executed sub-batch.
                    {
                        let mut m = metrics.lock().unwrap();
                        for latency in &latencies {
                            m.record(fmt, latency.as_secs_f64(), bs, elapsed.as_secs_f64());
                        }
                        m.set_cache(engine.cache_stats());
                    }
                    for ((j, req), latency) in group.into_iter().enumerate().zip(latencies) {
                        let _ = req.respond.send(Ok(ScoreResponse {
                            nll: nlls[j],
                            format: fmt,
                            batch_size: bs,
                            queue_depth,
                            latency,
                        }));
                    }
                }
                Err(e) => {
                    let msg = format!("batch execution failed: {e:#}");
                    log::error!("{msg}");
                    for req in group {
                        let _ = req.respond.send(Err(msg.clone()));
                    }
                }
            }
        }
    }
    log::info!("server worker exiting; {}", metrics.lock().unwrap().summary());
}
