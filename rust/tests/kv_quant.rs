//! Quantized KV pages: `--kv-format f32` must be **bit-identical** to the
//! pre-quantization dense arenas, the MX formats must track the f32-KV
//! decode within a per-format parity tolerance (int8 tightest, int4
//! loosest), page size must stay **bit-invisible** at any fixed format
//! (quantization is per position and 32-channel block, never per page),
//! resident accounting must report true packed bytes, and every pool
//! behavior built on page identity — prefix-share copy-on-write, the
//! speculative `truncate_row` rollback, zero-on-release — must operate on
//! code bytes exactly as it did on floats.

use mfqat::backend::forward::{forward_cached, forward_cached_batch_mixed, KvCache, RowTag};
use mfqat::backend::{ActMode, KvFormat, KvPageCfg, NativeWeights, SharedParams};
use mfqat::eval::generate::{ContinuousBatch, FinishedRow, SampleCfg, SpecPolicy};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use std::sync::Arc;

/// Byte-level prompts need the full 256-token vocab; tiny window so page
/// boundaries and overflow re-prefills land fast.
fn gen_dims() -> ModelDims {
    let mut dims = ModelDims::new("kvqgen", 256, 32, 1, 2, 10);
    dims.train_batch = 4;
    dims
}

/// Small forward-level model (no text decode, vocab can stay tiny).
fn fwd_dims() -> ModelDims {
    let mut dims = ModelDims::new("kvqfwd", 64, 32, 2, 2, 12);
    dims.train_batch = 2;
    dims
}

fn anchor(dims: &ModelDims, seed: u64, fmt: ElementFormat) -> mfqat::checkpoint::Checkpoint {
    let m = dims.to_manifest();
    ParamSet::init(&m, seed).to_anchor_checkpoint(&m, fmt).unwrap()
}

/// One weight set per format over a single `Arc`'d f32 parameter set.
fn shared_weight_sets(
    dims: &ModelDims,
    ck: &mfqat::checkpoint::Checkpoint,
    formats: &[ElementFormat],
    act: ActMode,
) -> Vec<NativeWeights> {
    let shared = Arc::new(SharedParams::from_checkpoint(dims, ck).unwrap());
    formats
        .iter()
        .map(|&fmt| NativeWeights::packed_with_shared(dims, ck, fmt, shared.clone(), act).unwrap())
        .collect()
}

/// Step a batch until every live row finishes, collecting completions.
fn drain(cb: &mut ContinuousBatch<&NativeWeights>) -> Vec<FinishedRow> {
    let mut done = Vec::new();
    let mut steps = 0usize;
    while cb.active() > 0 {
        done.extend(cb.step().unwrap());
        steps += 1;
        assert!(steps < 1000, "decode did not converge");
    }
    done
}

/// Decode every prompt to completion through a `ContinuousBatch` over the
/// given KV paging, returning the continuations in prompt order.
fn run_batch(
    dims: &ModelDims,
    w: &NativeWeights,
    prompts: &[&str],
    kv: KvPageCfg,
    n_tokens: usize,
    cfg: &SampleCfg,
) -> Vec<String> {
    let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(dims, prompts.len(), kv);
    let mut slot_of = Vec::new();
    for p in prompts {
        slot_of.push(cb.join(w, p, n_tokens, cfg).unwrap());
    }
    let mut out: Vec<Option<String>> = vec![None; prompts.len()];
    for f in drain(&mut cb) {
        let i = slot_of.iter().position(|&s| s == f.slot).unwrap();
        out[i] = Some(f.text);
    }
    out.into_iter().map(|t| t.unwrap()).collect()
}

/// Prefill `prefix` then append `appends` one token at a time, returning
/// every logit row the cache emitted (prefill rows first, then one row per
/// append) — the multi-step cached-decode trace the parity oracles compare.
fn decode_trace(w: &NativeWeights, kv: KvPageCfg, prefix: &[i32], appends: &[i32]) -> Vec<f32> {
    let mut cache = KvCache::with_rows_cfg(&w.dims, 1, kv);
    let mut out = forward_cached(w, &mut cache, prefix).unwrap();
    for &t in appends {
        out.extend(forward_cached(w, &mut cache, &[t]).unwrap());
    }
    out
}

/// Relative L2 distance `||a - b|| / ||b||` over a full logit trace.
fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - y as f64) * (x as f64 - y as f64);
        den += y as f64 * y as f64;
    }
    (num / den.max(1e-12)).sqrt()
}

#[test]
fn explicit_f32_kv_format_is_the_default_dense_path() {
    // The compatibility oracle: `--kv-format f32` is not a near-miss of the
    // pre-quantization pool, it IS that pool — logits bit-identical to a
    // cfg that never mentions a format, 1.0x compression, and the packed
    // arenas never engage.
    let dims = fwd_dims();
    let ck = anchor(&dims, 71, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let prefix: Vec<i32> = (0..7).map(|i| ((i * 5 + 3) % 64) as i32).collect();
    let appends: Vec<i32> = (0..4).map(|i| ((i * 11 + 2) % 64) as i32).collect();
    let default_trace = decode_trace(&w, KvPageCfg::with_page(4), &prefix, &appends);
    let explicit = decode_trace(
        &w,
        KvPageCfg::with_page(4).format(KvFormat::F32),
        &prefix,
        &appends,
    );
    assert_eq!(explicit, default_trace, "explicit f32 kv-format drifted from the default");

    let mut cache = KvCache::with_rows_cfg(&dims, 1, KvPageCfg::with_page(4).format(KvFormat::F32));
    forward_cached(&w, &mut cache, &prefix).unwrap();
    let m = cache.kv_memory();
    assert_eq!(m.kv_format, "f32");
    assert_eq!(m.resident_bytes, m.resident_f32_equiv_bytes, "f32 pages are their own dense size");
    assert_eq!(m.compression_ratio(), 1.0);
}

#[test]
fn quantized_decode_tracks_f32_within_per_format_tolerance() {
    // The parity-tolerance oracle the tentpole promises: a multi-step
    // cached decode over MX-coded pages lands within a per-format bound of
    // the f32-KV trace — int8 tightest, fp8 mid, int4 loosest — and never
    // produces a non-finite logit. Bounds are deliberately generous (the
    // per-element code error is amplified through two attention layers);
    // what they rule out is wrong-scale/wrong-block decode, not noise.
    let dims = fwd_dims();
    let ck = anchor(&dims, 72, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let prefix: Vec<i32> = (0..7).map(|i| ((i * 7 + 1) % 64) as i32).collect();
    let appends: Vec<i32> = (0..4).map(|i| ((i * 13 + 5) % 64) as i32).collect();
    let dense = decode_trace(&w, KvPageCfg::with_page(4), &prefix, &appends);
    for (fmt, tol) in [
        (KvFormat::MxInt8, 0.12),
        (KvFormat::MxFp8, 0.35),
        (KvFormat::MxInt4, 0.75),
    ] {
        let quant = decode_trace(&w, KvPageCfg::with_page(4).format(fmt), &prefix, &appends);
        assert!(quant.iter().all(|v| v.is_finite()), "{}: non-finite logit", fmt.name());
        let err = rel_l2(&quant, &dense);
        assert!(
            err <= tol,
            "{}: quantized decode drifted {err:.4} from f32 KV (tolerance {tol})",
            fmt.name()
        );
    }
}

#[test]
fn quantized_pages_account_packed_bytes_and_compression() {
    // `kv_resident_bytes` must report what the packed arenas actually hold:
    // pages × (code bytes + one E8M0 scale byte per 32 channels), with the
    // dense-equivalent mirrored in `resident_f32_equiv_bytes` so the
    // compression ratio is exact — ~3.9x for the 8-bit codes, ~7.3x for
    // int4 at d=32 (one scale byte per 32-channel block).
    let dims = fwd_dims();
    let ck = anchor(&dims, 73, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let toks: Vec<i32> = (0..6).map(|i| ((i * 3 + 1) % 64) as i32).collect();
    let pp = 4usize;
    let f32_page = 2 * dims.n_layers * pp * dims.d_model * std::mem::size_of::<f32>();
    for (fmt, min_ratio) in [
        (KvFormat::MxInt8, 3.5),
        (KvFormat::MxFp8, 3.5),
        (KvFormat::MxInt4, 7.0),
    ] {
        let mut cache = KvCache::with_rows_cfg(&dims, 1, KvPageCfg::with_page(pp).format(fmt));
        forward_cached(&w, &mut cache, &toks).unwrap();
        let m = cache.kv_memory();
        assert_eq!(m.used_pages, 2, "6 positions at 4/page map 2 pages");
        let quant_page = dims.n_layers * pp * fmt.bytes_per_position(dims.d_model);
        assert_eq!(
            m.resident_bytes,
            2 * quant_page,
            "{}: resident bytes must be the packed page size",
            fmt.name()
        );
        assert_eq!(m.resident_f32_equiv_bytes, 2 * f32_page, "{}", fmt.name());
        assert_eq!(m.kv_format, fmt.name());
        assert!(
            m.compression_ratio() >= min_ratio,
            "{}: compression {:.2} below {min_ratio}",
            fmt.name(),
            m.compression_ratio()
        );
        // Truncate-to-zero drops residency like the dense pool does.
        cache.truncate(0);
        assert_eq!(cache.kv_memory().resident_bytes, 0);
    }
}

#[test]
fn quantized_page_size_is_bit_invisible_at_fixed_format() {
    // Quantization is per (position, 32-channel block) — page boundaries
    // never land inside a scale group — so at any fixed kv-format the page
    // size must stay exactly as invisible as it is for f32: bit-identical
    // logit traces at the forward level, identical tokens through the
    // continuous-batching text decode (overflow re-prefills included).
    let dims = fwd_dims();
    let ck = anchor(&dims, 74, ElementFormat::int(8));
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let prefix: Vec<i32> = (0..7).map(|i| ((i * 9 + 4) % 64) as i32).collect();
    let appends: Vec<i32> = (0..4).map(|i| ((i * 5 + 2) % 64) as i32).collect();
    for fmt in [KvFormat::MxInt8, KvFormat::MxFp8, KvFormat::MxInt4] {
        let dense =
            decode_trace(&w, KvPageCfg::with_page(dims.seq_len).format(fmt), &prefix, &appends);
        for pp in [1usize, 3, 4] {
            let paged = decode_trace(&w, KvPageCfg::with_page(pp).format(fmt), &prefix, &appends);
            assert_eq!(paged, dense, "{} pp={pp}: page size leaked into logits", fmt.name());
        }
    }

    // Text-level: the full serve decode path over quantized pages.
    let gdims = gen_dims();
    let gck = anchor(&gdims, 75, ElementFormat::int(8));
    let gw = NativeWeights::packed_from_checkpoint(&gdims, &gck, ElementFormat::int(8)).unwrap();
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 9,
    };
    let prompts = ["kova", "the color of kova is violet"];
    let n_tokens = 2 * gdims.seq_len; // past the window: forced overflow
    let dense = run_batch(
        &gdims,
        &gw,
        &prompts,
        KvPageCfg::with_page(gdims.seq_len).format(KvFormat::MxInt8),
        n_tokens,
        &cfg,
    );
    for pp in [3usize, 4] {
        let paged = run_batch(
            &gdims,
            &gw,
            &prompts,
            KvPageCfg::with_page(pp).format(KvFormat::MxInt8),
            n_tokens,
            &cfg,
        );
        assert_eq!(paged, dense, "mxint8 pp={pp} changed decode output");
    }
}

#[test]
fn cow_on_packed_pages_preserves_co_holders() {
    // Copy-on-write over code bytes, with exact packed refcount accounting:
    // a row that truncates back *into* a shared quantized page and appends
    // divergent tokens gets a private partial-page copy of the codes and
    // scales, while the original page — still visible to the other row and
    // the index — is never touched. Oracles are fresh caches at the SAME
    // kv-format: sharing must be bit-invisible within the quantized world.
    let dims = fwd_dims();
    let ck = anchor(&dims, 76, ElementFormat::int(8));
    let ws = shared_weight_sets(&dims, &ck, &[ElementFormat::int(8)], ActMode::F32);
    let w = &ws[0];
    let vocab = dims.vocab;
    let kv = KvPageCfg::with_page(4).format(KvFormat::MxInt8);
    let page_bytes = dims.n_layers * 4 * KvFormat::MxInt8.bytes_per_position(dims.d_model);
    let mut cache = KvCache::with_slots_cfg(&dims, 2, kv.share(true));
    let total = cache.total_pages();

    // Row 0 prefills an 8-token window (2 full pages) and indexes it.
    let win: Vec<i32> = (0..8).map(|i| ((i * 5 + 3) % 64) as i32).collect();
    let (r0, sh0) = cache.join_row_prefix(RowTag::of(w), &win).unwrap();
    assert_eq!((r0, sh0), (0, 0), "empty index shares nothing");
    let l0 = forward_cached_batch_mixed(&[w, w], &mut cache, &[&win, &[]]).unwrap();
    cache.register_prefix(0, &win);
    assert_eq!(cache.kv_memory().retained_pages, 2);

    // Row 1 joins the same window: one full page is shareable, and its
    // prefilled tail logits equal row 0's — the shared page's packed codes
    // dequantize to exactly what prefill would have written.
    let (r1, sh1) = cache.join_row_prefix(RowTag::of(w), &win).unwrap();
    assert_eq!((r1, sh1), (1, 4), "one of two pages is shareable");
    // Page 0: row0 + index + row1 = 3 refs (2 extra); page 1: row0 +
    // index = 2 refs (1 extra) — counted at the PACKED page size.
    assert_eq!(cache.kv_memory().shared_bytes, 3 * page_bytes);
    let l1 = forward_cached_batch_mixed(&[w, w], &mut cache, &[&[], &win[4..]]).unwrap();
    assert_eq!(
        l1,
        l0[4 * vocab..].to_vec(),
        "decoding over a shared packed page diverged from the prefilled original"
    );

    // Row 1 rolls back into the shared page and appends divergent tokens:
    // the mid-page copy-on-write gives it a private page holding just the
    // 2 retained positions' codes.
    cache.truncate_row(r1, 2);
    let div: Vec<i32> = vec![(win[2] + 1) % 64, 7, 9];
    let l1b = forward_cached_batch_mixed(&[w, w], &mut cache, &[&[], &div]).unwrap();
    let mut hist = win[..2].to_vec();
    hist.extend_from_slice(&div);
    let mut fresh = KvCache::with_rows_cfg(&dims, 1, kv);
    let oracle = forward_cached(w, &mut fresh, &hist).unwrap();
    assert_eq!(
        l1b,
        oracle[2 * vocab..].to_vec(),
        "post-divergence decode must match a quantized cache that never shared"
    );
    assert_eq!(cache.kv_memory().shared_bytes, 2 * page_bytes);

    // Row 0 still sees pristine codes: its next decode equals a fresh
    // replay of its full history.
    let probe = [11i32];
    let l0b = forward_cached_batch_mixed(&[w, w], &mut cache, &[&probe, &[]]).unwrap();
    let mut h0 = win.clone();
    h0.push(probe[0]);
    let mut fresh0 = KvCache::with_rows_cfg(&dims, 1, kv);
    let o0 = forward_cached(w, &mut fresh0, &h0).unwrap();
    assert_eq!(l0b, o0[8 * vocab..].to_vec(), "COW mutated a packed page another row could see");

    cache.retire_row(r0);
    cache.retire_row(r1);
    cache.clear_prefix_index();
    let m = cache.kv_memory();
    assert_eq!((m.used_pages, m.free_pages), (0, total), "pages leaked");
    assert_eq!(m.shared_bytes, 0);
}

#[test]
fn prop_truncate_rollback_replays_exactly_on_quantized_pages() {
    // Property over the speculative-rollback primitive on packed pages:
    // `truncate_row` at any row count keeps the free list consistent with
    // the per-row lengths, truncate-to-zero returns the pool to baseline,
    // and a rolled-back row re-decodes bit-identically to a same-format
    // cache that never held the discarded positions — quantization is
    // per-position, so overwriting a row's codes leaves no trace of what
    // the block previously encoded.
    let dims = fwd_dims();
    let ck = anchor(&dims, 77, ElementFormat::int(8));
    let ws = shared_weight_sets(&dims, &ck, &[ElementFormat::int(8)], ActMode::F32);
    let w = &ws[0];
    let formats = [KvFormat::MxInt8, KvFormat::MxFp8, KvFormat::MxInt4];
    mfqat::util::props::run_cases("kv_quant_rollback", 6, |g| {
        let pp = 1 + g.rng.below(4); // 1..=4 positions per page
        let fmt = formats[g.rng.below(formats.len())];
        let rows = 2 + g.rng.below(2); // 2..=3 rows
        let kv = KvPageCfg::with_page(pp).format(fmt);
        let mut cache = KvCache::with_rows_cfg(&dims, rows, kv);
        let total = cache.kv_memory().total_pages;
        let wrefs: Vec<&NativeWeights> = (0..rows).map(|_| w).collect();
        let mut hist: Vec<Vec<i32>> = Vec::new();
        for _ in 0..rows {
            let n = 1 + g.rng.below(4);
            hist.push((0..n).map(|_| g.rng.below(dims.vocab) as i32).collect());
        }
        let feeds: Vec<Vec<i32>> = hist.clone();
        let slices: Vec<&[i32]> = feeds.iter().map(|t| t.as_slice()).collect();
        forward_cached_batch_mixed(&wrefs, &mut cache, &slices).map_err(|e| e.to_string())?;
        for _ in 0..g.rng.range(4, 10) {
            let r = g.rng.below(rows);
            if g.rng.chance(0.5) && hist[r].len() + 1 < dims.seq_len {
                let t = g.rng.below(dims.vocab) as i32;
                hist[r].push(t);
                let one = [t];
                let mut slices: Vec<&[i32]> = vec![&[]; rows];
                slices[r] = &one;
                forward_cached_batch_mixed(&wrefs, &mut cache, &slices)
                    .map_err(|e| e.to_string())?;
            } else {
                let keep = g.rng.below(hist[r].len() + 1);
                cache.truncate_row(r, keep);
                hist[r].truncate(keep);
            }
            let m = cache.kv_memory();
            let mapped: usize = hist.iter().map(|h| h.len().div_ceil(pp)).sum();
            if m.used_pages != mapped || m.used_pages + m.free_pages != total {
                return Err(format!(
                    "{} pp={pp}: free list drifted: {} used (want {mapped}), {} free of {total}",
                    fmt.name(),
                    m.used_pages,
                    m.free_pages
                ));
            }
        }
        // Truncate-to-zero on every row returns the pool to baseline…
        for r in 0..rows {
            cache.truncate_row(r, 0);
        }
        let m = cache.kv_memory();
        if m.used_pages != 0 || m.free_pages != total || m.resident_bytes != 0 {
            return Err(format!(
                "{} pp={pp}: truncate-to-zero leaked: {} used, {} free of {total}",
                fmt.name(),
                m.used_pages,
                m.free_pages
            ));
        }
        // …and a re-fed row is bit-identical to a fresh never-truncated
        // same-format cache — the discarded codes left no trace.
        let probe: Vec<i32> = (0..5).map(|i| ((i * 13 + 2) % dims.vocab) as i32).collect();
        let r = g.rng.below(rows);
        let mut slices: Vec<&[i32]> = vec![&[]; rows];
        slices[r] = &probe;
        let replay =
            forward_cached_batch_mixed(&wrefs, &mut cache, &slices).map_err(|e| e.to_string())?;
        let mut fresh = KvCache::with_rows_cfg(&dims, 1, kv);
        let solo = forward_cached(w, &mut fresh, &probe).map_err(|e| e.to_string())?;
        if replay != solo {
            return Err(format!(
                "{} pp={pp}: post-truncate decode diverged from a fresh cache",
                fmt.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn spec_decode_rollback_is_token_identical_on_quantized_kv() {
    // Self-speculative decoding over quantized pages: the verify pass
    // writes each drafted position's codes before any query reads them, so
    // multi-position verification sees exactly the quantized rows a plain
    // one-token-at-a-time decode would — greedy speculation must therefore
    // stay token-identical to the plain decode AT THE SAME kv-format, with
    // rejected drafts rolled back through `truncate_row` on packed pages.
    let dims = gen_dims();
    let ck = anchor(&dims, 78, ElementFormat::int(8));
    let ws = shared_weight_sets(
        &dims,
        &ck,
        &[ElementFormat::int(8), ElementFormat::int(4)],
        ActMode::F32,
    );
    let (verify, draft) = (&ws[0], &ws[1]);
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 6,
        seed: 9,
    };
    for fmt in [KvFormat::MxInt8, KvFormat::MxInt4] {
        let kv = KvPageCfg::with_page(4).format(fmt);
        let plain = run_batch(&dims, verify, &["the colors"], kv, 8, &cfg);
        let mut cb: ContinuousBatch<&NativeWeights> = ContinuousBatch::with_kv(&dims, 1, kv);
        let s = cb
            .join_spec(verify, draft, "the colors", 8, &cfg, 3, SpecPolicy::Greedy)
            .unwrap();
        let done = drain(&mut cb);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].slot, s);
        assert!(done[0].spec_drafted > 0, "{}: the row never drafted", fmt.name());
        assert_eq!(
            done[0].text,
            plain[0],
            "{}: greedy speculation changed tokens on quantized KV",
            fmt.name()
        );
        let m = cb.kv_memory();
        assert_eq!((m.used_pages, m.free_pages), (0, m.total_pages), "pages leaked");
    }
}
