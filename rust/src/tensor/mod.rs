//! Host-side tensors: dense f32 ([`Tensor`]) and packed microscaling
//! ([`MxTensor`]).

pub mod mxtensor;

pub use mxtensor::MxTensor;

use crate::util::Rng;
use anyhow::{bail, Result};

/// A dense, row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Flat f32 data.
    pub data: Vec<f32>,
}

impl Tensor {
    /// New tensor (errors when shape and data disagree).
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    /// All-zeros tensor of `shape`.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// Constant-filled tensor of `shape`.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    /// Gaussian init with the given std (for host-side fallback init).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as a 2-D `[prod(shape[..-1]), last]` matrix
    /// (scalars/vectors view as a single row).
    pub fn rows(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    /// Last-dimension length (1 for scalars).
    pub fn row_len(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    /// L2 norm of the data.
    pub fn l2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_row_len() {
        let t = Tensor::zeros(&[4, 5, 6]);
        assert_eq!(t.rows(), 20);
        assert_eq!(t.row_len(), 6);
        let v = Tensor::zeros(&[7]);
        assert_eq!(v.rows(), 1);
        assert_eq!(v.row_len(), 7);
    }

    #[test]
    fn randn_is_seed_deterministic() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(
            Tensor::randn(&[3, 3], 0.5, &mut r1),
            Tensor::randn(&[3, 3], 0.5, &mut r2)
        );
    }
}
