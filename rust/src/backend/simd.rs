//! Explicit-SIMD inner loops for the integer-MAC GEMM.
//!
//! The hot loop of [`super::kernels::gemm_repacked_int`] is a rank-`kl`
//! update: for one `(k-block, out-block)` tile it accumulates
//! `acc[n] += m[k] · w[k][n]` over aligned activation codes `m` and decoded
//! weight codes `w`, in `i16` (≤4-bit elements) or `i32`. PR 2 left that
//! loop to the autovectorizer; this module hand-writes it:
//!
//! * **AVX2** (x86-64, runtime-detected): `_mm256_mullo_epi16` /
//!   `_mm256_mullo_epi32` broadcast-MACs with the accumulator tile held in
//!   registers across the whole `k` loop — 16 (i16) / 8 (i32) lanes, two
//!   accumulator vectors deep so a 32-wide MX block is one register pass.
//! * **NEON** (aarch64): the same structure over `vmlaq_s16` / `vmlaq_s32`
//!   (8 / 4 lanes, two vectors deep).
//! * **Portable**: the scalar loop the autovectorizer already handled,
//!   retained as the fallback for other ISAs *and as the differential-test
//!   oracle* — the SIMD paths must produce bit-identical accumulators
//!   (all arithmetic is wrapping two's complement, so any reassociation of
//!   the same products is exact).
//!
//! Dispatch is per-call ([`tile_mac_i16`] / [`tile_mac_i32`]) against a
//! once-per-process [`SimdLevel`]. The tiles these kernels chew arrive
//! from any GEMM the forward issues — full-sequence scoring, `rows ≥ 1`
//! KV-batched decode, or a mixed-format continuous-batching step (where
//! one step dispatches several per-format GEMMs); the kernels are
//! oblivious to batching shape, seeing only `[rows, k]` tiles.
//! `MFQAT_SIMD=off` forces the portable path (the forced-fallback leg of
//! CI's differential run); the env-var surface is documented once in
//! [`crate::util::cli`].

use std::sync::OnceLock;

/// Which instruction set the integer-MAC tile kernels run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Scalar/autovectorized fallback (also the differential oracle).
    Portable,
    /// 256-bit AVX2 integer ops (x86-64, runtime-detected).
    Avx2,
    /// 128-bit NEON integer ops (aarch64).
    Neon,
}

impl SimdLevel {
    /// Stable identifier (`"portable"` / `"avx2"` / `"neon"`) for logs and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Portable => "portable",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

/// What the running CPU supports.
fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Portable
}

/// Resolve the dispatch level from the `MFQAT_SIMD` override and the
/// detected capability. `off`/`0`/`false`/`portable` force the portable
/// path; anything else (including unset) keeps the detected level.
pub fn resolve_level(env: Option<&str>, detected: SimdLevel) -> SimdLevel {
    match env.map(|s| s.trim().to_ascii_lowercase()) {
        Some(v) if matches!(v.as_str(), "off" | "0" | "false" | "portable" | "none") => {
            SimdLevel::Portable
        }
        _ => detected,
    }
}

/// The active dispatch level (`MFQAT_SIMD` consulted once per process).
pub fn level() -> SimdLevel {
    static L: OnceLock<SimdLevel> = OnceLock::new();
    *L.get_or_init(|| resolve_level(std::env::var("MFQAT_SIMD").ok().as_deref(), detect()))
}

#[inline]
fn check_tile(acc_len: usize, kl: usize, w_len: usize, stride: usize) {
    assert!(stride >= acc_len, "row stride shorter than the accumulator");
    assert!(
        kl == 0 || w_len >= (kl - 1) * stride + acc_len,
        "weight tile too short for {kl} rows of stride {stride}"
    );
}

// --------------------------------------------------------------------------
// i16 rank update (narrow path: ≤4-bit weight codes).
// --------------------------------------------------------------------------

/// `acc[n] += Σ_k m[k] · w[k·stride + n]` in wrapping `i16`, dispatched to
/// the active [`SimdLevel`]. Bit-identical to [`tile_mac_i16_portable`] on
/// every input (wrapping integer MACs reassociate exactly).
#[inline]
pub fn tile_mac_i16(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds checked above; AVX2 presence runtime-verified.
        SimdLevel::Avx2 => unsafe { tile_mac_i16_avx2(acc, m, w, stride) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: bounds checked above; NEON presence runtime-verified.
        SimdLevel::Neon => unsafe { tile_mac_i16_neon(acc, m, w, stride) },
        _ => tile_mac_i16_scalar(acc, m, w, stride, 0),
    }
}

/// The portable reference (public for differential tests and benches).
pub fn tile_mac_i16_portable(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    tile_mac_i16_scalar(acc, m, w, stride, 0);
}

/// Scalar core over columns `n0..acc.len()` (also the SIMD tail).
fn tile_mac_i16_scalar(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize, n0: usize) {
    let nl = acc.len();
    for (k, &mk) in m.iter().enumerate() {
        if mk == 0 {
            continue;
        }
        let row = &w[k * stride + n0..k * stride + nl];
        for (a, &c) in acc[n0..].iter_mut().zip(row) {
            *a = a.wrapping_add(mk.wrapping_mul(c));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_mac_i16_avx2(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    use std::arch::x86_64::*;
    let nl = acc.len();
    let mut n = 0usize;
    // Two accumulator vectors deep: a 32-wide MX block is one pass with a
    // single broadcast per k.
    while n + 32 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        let mut a1 = _mm256_loadu_si256(acc.as_ptr().add(n + 16) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = _mm256_set1_epi16(mk);
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            let w1 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n + 16) as *const __m256i);
            a0 = _mm256_add_epi16(a0, _mm256_mullo_epi16(mv, w0));
            a1 = _mm256_add_epi16(a1, _mm256_mullo_epi16(mv, w1));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        _mm256_storeu_si256(acc.as_mut_ptr().add(n + 16) as *mut __m256i, a1);
        n += 32;
    }
    while n + 16 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            a0 = _mm256_add_epi16(a0, _mm256_mullo_epi16(_mm256_set1_epi16(mk), w0));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        n += 16;
    }
    if n < nl {
        tile_mac_i16_scalar(acc, m, w, stride, n);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_mac_i16_neon(acc: &mut [i16], m: &[i16], w: &[i16], stride: usize) {
    use std::arch::aarch64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 16 <= nl {
        let mut a0 = vld1q_s16(acc.as_ptr().add(n));
        let mut a1 = vld1q_s16(acc.as_ptr().add(n + 8));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = vdupq_n_s16(mk);
            a0 = vmlaq_s16(a0, mv, vld1q_s16(w.as_ptr().add(k * stride + n)));
            a1 = vmlaq_s16(a1, mv, vld1q_s16(w.as_ptr().add(k * stride + n + 8)));
        }
        vst1q_s16(acc.as_mut_ptr().add(n), a0);
        vst1q_s16(acc.as_mut_ptr().add(n + 8), a1);
        n += 16;
    }
    while n + 8 <= nl {
        let mut a0 = vld1q_s16(acc.as_ptr().add(n));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            a0 = vmlaq_s16(a0, vdupq_n_s16(mk), vld1q_s16(w.as_ptr().add(k * stride + n)));
        }
        vst1q_s16(acc.as_mut_ptr().add(n), a0);
        n += 8;
    }
    if n < nl {
        tile_mac_i16_scalar(acc, m, w, stride, n);
    }
}

// --------------------------------------------------------------------------
// i32 rank update (wide path: 5..8-bit weight codes).
// --------------------------------------------------------------------------

/// `acc[n] += Σ_k m[k] · w[k·stride + n]` in wrapping `i32`, dispatched to
/// the active [`SimdLevel`]. Bit-identical to [`tile_mac_i32_portable`].
#[inline]
pub fn tile_mac_i32(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: bounds checked above; AVX2 presence runtime-verified.
        SimdLevel::Avx2 => unsafe { tile_mac_i32_avx2(acc, m, w, stride) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: bounds checked above; NEON presence runtime-verified.
        SimdLevel::Neon => unsafe { tile_mac_i32_neon(acc, m, w, stride) },
        _ => tile_mac_i32_scalar(acc, m, w, stride, 0),
    }
}

/// The portable reference (public for differential tests and benches).
pub fn tile_mac_i32_portable(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    check_tile(acc.len(), m.len(), w.len(), stride);
    tile_mac_i32_scalar(acc, m, w, stride, 0);
}

fn tile_mac_i32_scalar(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize, n0: usize) {
    let nl = acc.len();
    for (k, &mk) in m.iter().enumerate() {
        if mk == 0 {
            continue;
        }
        let row = &w[k * stride + n0..k * stride + nl];
        for (a, &c) in acc[n0..].iter_mut().zip(row) {
            *a = a.wrapping_add(mk.wrapping_mul(c));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_mac_i32_avx2(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    use std::arch::x86_64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 16 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        let mut a1 = _mm256_loadu_si256(acc.as_ptr().add(n + 8) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = _mm256_set1_epi32(mk);
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            let w1 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n + 8) as *const __m256i);
            a0 = _mm256_add_epi32(a0, _mm256_mullo_epi32(mv, w0));
            a1 = _mm256_add_epi32(a1, _mm256_mullo_epi32(mv, w1));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        _mm256_storeu_si256(acc.as_mut_ptr().add(n + 8) as *mut __m256i, a1);
        n += 16;
    }
    while n + 8 <= nl {
        let mut a0 = _mm256_loadu_si256(acc.as_ptr().add(n) as *const __m256i);
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let w0 = _mm256_loadu_si256(w.as_ptr().add(k * stride + n) as *const __m256i);
            a0 = _mm256_add_epi32(a0, _mm256_mullo_epi32(_mm256_set1_epi32(mk), w0));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(n) as *mut __m256i, a0);
        n += 8;
    }
    if n < nl {
        tile_mac_i32_scalar(acc, m, w, stride, n);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_mac_i32_neon(acc: &mut [i32], m: &[i32], w: &[i32], stride: usize) {
    use std::arch::aarch64::*;
    let nl = acc.len();
    let mut n = 0usize;
    while n + 8 <= nl {
        let mut a0 = vld1q_s32(acc.as_ptr().add(n));
        let mut a1 = vld1q_s32(acc.as_ptr().add(n + 4));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            let mv = vdupq_n_s32(mk);
            a0 = vmlaq_s32(a0, mv, vld1q_s32(w.as_ptr().add(k * stride + n)));
            a1 = vmlaq_s32(a1, mv, vld1q_s32(w.as_ptr().add(k * stride + n + 4)));
        }
        vst1q_s32(acc.as_mut_ptr().add(n), a0);
        vst1q_s32(acc.as_mut_ptr().add(n + 4), a1);
        n += 8;
    }
    while n + 4 <= nl {
        let mut a0 = vld1q_s32(acc.as_ptr().add(n));
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 {
                continue;
            }
            a0 = vmlaq_s32(a0, vdupq_n_s32(mk), vld1q_s32(w.as_ptr().add(k * stride + n)));
        }
        vst1q_s32(acc.as_mut_ptr().add(n), a0);
        n += 4;
    }
    if n < nl {
        tile_mac_i32_scalar(acc, m, w, stride, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::props::{run_cases, Gen};

    #[test]
    fn env_override_forces_portable() {
        for v in ["off", "OFF", " 0 ", "false", "portable", "none"] {
            assert_eq!(
                resolve_level(Some(v), SimdLevel::Avx2),
                SimdLevel::Portable,
                "MFQAT_SIMD={v}"
            );
        }
        assert_eq!(resolve_level(None, SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(resolve_level(Some("auto"), SimdLevel::Neon), SimdLevel::Neon);
        assert_eq!(resolve_level(Some("on"), SimdLevel::Portable), SimdLevel::Portable);
    }

    #[test]
    fn level_is_consistent_and_named() {
        // Whatever this process resolved to, repeated queries agree and the
        // name round-trips (smoke for the OnceLock path).
        let l = level();
        assert_eq!(level(), l);
        assert!(!l.name().is_empty());
    }

    #[test]
    fn prop_tile_mac_i16_matches_portable_bit_exact() {
        // The dispatched path (whatever this host runs) must produce
        // bit-identical i16 accumulators to the scalar oracle at every
        // tile shape, including ragged widths that exercise the tails.
        run_cases("tile_mac_i16 == portable", 48, |g: &mut Gen| {
            let stride = g.len(1, 40);
            let nl = g.rng.range(1, stride + 1);
            let kl = g.len(0, 33);
            let m: Vec<i16> = (0..kl)
                .map(|_| g.rng.range(0, 255) as i16 - 127)
                .collect();
            let w: Vec<i16> = (0..kl * stride)
                .map(|_| g.rng.range(0, 17) as i16 - 8)
                .collect();
            let init: Vec<i16> = (0..nl).map(|_| g.rng.range(0, 201) as i16 - 100).collect();
            let mut fast = init.clone();
            let mut slow = init;
            tile_mac_i16(&mut fast, &m, &w, stride);
            tile_mac_i16_portable(&mut slow, &m, &w, stride);
            if fast != slow {
                return Err(format!("i16 mismatch (stride={stride} nl={nl} kl={kl})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tile_mac_i32_matches_portable_bit_exact() {
        run_cases("tile_mac_i32 == portable", 48, |g: &mut Gen| {
            let stride = g.len(1, 40);
            let nl = g.rng.range(1, stride + 1);
            let kl = g.len(0, 33);
            let m: Vec<i32> = (0..kl).map(|_| g.rng.range(0, 255) as i32 - 127).collect();
            let w: Vec<i32> = (0..kl * stride)
                .map(|_| g.rng.range(0, 255) as i32 - 127)
                .collect();
            let init: Vec<i32> =
                (0..nl).map(|_| g.rng.range(0, 2001) as i32 - 1000).collect();
            let mut fast = init.clone();
            let mut slow = init;
            tile_mac_i32(&mut fast, &m, &w, stride);
            tile_mac_i32_portable(&mut slow, &m, &w, stride);
            if fast != slow {
                return Err(format!("i32 mismatch (stride={stride} nl={nl} kl={kl})"));
            }
            Ok(())
        });
    }

    #[test]
    fn tile_mac_handles_empty_and_zero_rows() {
        // kl = 0 and all-zero multipliers leave the accumulator untouched.
        let mut acc = vec![3i16; 8];
        tile_mac_i16(&mut acc, &[], &[], 8);
        assert_eq!(acc, vec![3i16; 8]);
        let w = vec![5i16; 2 * 8];
        tile_mac_i16(&mut acc, &[0, 0], &w, 8);
        assert_eq!(acc, vec![3i16; 8]);
        let mut acc32 = vec![-7i32; 5];
        tile_mac_i32(&mut acc32, &[0], &vec![9i32; 5], 5);
        assert_eq!(acc32, vec![-7i32; 5]);
    }

    #[test]
    fn tile_mac_known_values() {
        // 2 rows, stride 6, nl 5: acc[n] = m0*w0[n] + m1*w1[n].
        let w: Vec<i32> = vec![1, 2, 3, 4, 5, 99, -1, -2, -3, -4, -5, 99];
        let mut acc = vec![10i32; 5];
        tile_mac_i32(&mut acc, &[2, 3], &w, 6);
        assert_eq!(acc, vec![10 + 2 - 3, 10 + 4 - 6, 10 + 6 - 9, 10 + 8 - 12, 10 + 10 - 15]);
        let w16: Vec<i16> = w.iter().map(|&v| v as i16).collect();
        let mut acc16 = vec![10i16; 5];
        tile_mac_i16(&mut acc16, &[2, 3], &w16, 6);
        assert_eq!(acc16, vec![9, 8, 7, 6, 5]);
    }
}
