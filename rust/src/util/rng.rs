//! Deterministic, seedable PRNG (xoshiro256** + splitmix64 seeding).
//!
//! Every stochastic component in the repo — corpus generation, task
//! generation, weight init fallback, property tests, benchmark workloads —
//! draws from this generator so experiments are exactly reproducible from a
//! single `u64` seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for parallel / per-component use).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(13);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
