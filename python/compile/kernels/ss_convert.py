"""L1 Pallas kernel: Slice-and-Scale conversion on (scale, element) planes.

Implements the paper's on-the-fly anchor->target conversion (sections 3.3
and 3.4) as it would run on the serving accelerator: inputs are the stored
anchor planes — per-block scale exponents and element values — and outputs
are the converted planes. For MXINT the element transform is the
shift-with-round of Eq. 4 (realized as an exact divide + RNE, equivalent for
the small integer codes); for MXFP it is the requantization of Eq. 6.

The grid walks row tiles of the element plane so each step converts one
VMEM-resident slab; the per-block scales ride along in a parallel BlockSpec.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats as F
from . import ref
from .mx_quant import _pick_tile


def _ss_kernel(se_ref, p_ref, se_o_ref, p_o_ref, *, src: F.ElementFormat,
               dst: F.ElementFormat):
    se_l, p_l = ref.ss_convert(se_ref[...], p_ref[...], src, dst)
    se_o_ref[...] = se_l
    p_o_ref[...] = p_l


@partial(jax.jit, static_argnames=("src", "dst", "max_tile"))
def ss_convert_pallas(se, p, src: F.ElementFormat, dst: F.ElementFormat,
                      max_tile: int = 64):
    """Convert planes ``se`` [R, NB] (int32), ``p`` [R, NB, BS] (f32 element
    values) from ``src`` to the lower-precision ``dst``."""
    rows, nb, bs = p.shape
    assert se.shape == (rows, nb), (se.shape, p.shape)
    tile_r = _pick_tile(rows, max_tile)
    se_out, p_out = pl.pallas_call(
        partial(_ss_kernel, src=src, dst=dst),
        grid=(rows // tile_r,),
        in_specs=[
            pl.BlockSpec((tile_r, nb), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, nb, bs), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, nb), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, nb, bs), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, nb), jnp.int32),
            jax.ShapeDtypeStruct((rows, nb, bs), jnp.float32),
        ],
        interpret=True,
    )(jnp.asarray(se, jnp.int32), jnp.asarray(p, jnp.float32))
    return se_out, p_out
