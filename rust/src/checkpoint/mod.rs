//! Anchor checkpoint container (`.mfq` files) — paper §3.5.
//!
//! The elastic-inference workflow stores **one** checkpoint in the anchor
//! format (MXINT8 or MXFP8) and derives every lower-precision variant at
//! runtime via Slice-and-Scale. A `.mfq` file holds named [`MxTensor`]s plus
//! free-form JSON metadata (model config, training provenance).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "MFQAT\0"  | u16 version | u32 meta_len | meta JSON bytes
//! u32 n_tensors
//! per tensor:
//!   u16 name_len | name utf-8
//!   u8 elem_kind (0=int,1=fp) | u8 bits_or_exp | u8 man | u32 block_size
//!   u8 ndim | u64 dims[ndim]
//!   u64 n_scales | i8 scales[n_scales]
//!   u64 n_packed | u8 packed[n_packed]
//! u32 n_raw
//! per raw tensor (f32 — embeddings/norms/head, which the paper leaves in
//! high precision):
//!   u16 name_len | name utf-8
//!   u8 ndim | u64 dims[ndim]
//!   u64 n_data | f32 data[n_data]
//! u32 crc32 of everything above
//! ```

use crate::formats::{ElementFormat, MxFormat};
use crate::tensor::{MxTensor, Tensor};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"MFQAT\0";
const VERSION: u16 = 1;

/// A named collection of MX tensors (quantized weights), raw f32 tensors
/// (high-precision parameters), and metadata.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    /// Free-form metadata (anchor format, provenance, training plan).
    pub meta: BTreeMap<String, Json>,
    /// Quantized MX tensors by parameter name.
    pub tensors: BTreeMap<String, MxTensor>,
    /// Raw f32 tensors by parameter name (unquantized parameters; master checkpoints store everything here).
    pub raw: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    /// Empty checkpoint.
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    /// Insert a quantized MX tensor under `name`.
    pub fn insert(&mut self, name: &str, tensor: MxTensor) {
        self.tensors.insert(name.to_string(), tensor);
    }

    /// Insert a raw f32 tensor under `name`.
    pub fn insert_raw(&mut self, name: &str, tensor: Tensor) {
        self.raw.insert(name.to_string(), tensor);
    }

    /// Look up a quantized tensor by name.
    pub fn get(&self, name: &str) -> Option<&MxTensor> {
        self.tensors.get(name)
    }

    /// Look up a raw f32 tensor by name.
    pub fn get_raw(&self, name: &str) -> Option<&Tensor> {
        self.raw.get(name)
    }

    /// Set a metadata entry.
    pub fn set_meta(&mut self, key: &str, value: Json) {
        self.meta.insert(key.to_string(), value);
    }

    /// Anchor element format recorded in the metadata, if any (`None` for
    /// master/f32 checkpoints that carry no `anchor` entry). Shared by the
    /// backends so they parse the meta identically; what to do about a
    /// missing anchor is each backend's policy.
    pub fn anchor_format(&self) -> Result<Option<ElementFormat>> {
        self.meta
            .get("anchor")
            .and_then(|j| j.as_str())
            .map(ElementFormat::parse)
            .transpose()
    }

    /// Total storage in bytes (packed codes + scales + raw f32 payloads).
    pub fn storage_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.storage_bytes()).sum::<usize>()
            + self.raw.values().map(|t| t.len() * 4).sum::<usize>()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        let meta = Json::Obj(self.meta.clone()).to_string();
        buf.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta.as_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            match t.format.elem {
                ElementFormat::Int { bits } => {
                    buf.push(0);
                    buf.push(bits);
                    buf.push(0);
                }
                ElementFormat::Fp { exp, man } => {
                    buf.push(1);
                    buf.push(exp);
                    buf.push(man);
                }
            }
            buf.extend_from_slice(&(t.format.block_size as u32).to_le_bytes());
            buf.push(t.shape.len() as u8);
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&(t.scales.len() as u64).to_le_bytes());
            buf.extend_from_slice(unsafe {
                std::slice::from_raw_parts(t.scales.as_ptr() as *const u8, t.scales.len())
            });
            buf.extend_from_slice(&(t.packed.len() as u64).to_le_bytes());
            buf.extend_from_slice(&t.packed);
        }
        buf.extend_from_slice(&(self.raw.len() as u32).to_le_bytes());
        for (name, t) in &self.raw {
            buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.push(t.shape.len() as u8);
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 2 + 4 + 4 {
            bail!("checkpoint truncated");
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("checkpoint CRC mismatch (corrupt file)");
        }
        let mut r = Reader { b: body, i: 0 };
        if r.take(6)? != MAGIC {
            bail!("bad magic (not an .mfq checkpoint)");
        }
        let version = r.u16()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let meta_len = r.u32()? as usize;
        let meta_text = std::str::from_utf8(r.take(meta_len)?).context("meta utf-8")?;
        let meta_json = Json::parse(meta_text).map_err(|e| anyhow::anyhow!("meta json: {e}"))?;
        let meta = match meta_json {
            Json::Obj(m) => m,
            _ => bail!("meta must be a JSON object"),
        };
        let n_tensors = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n_tensors {
            let name_len = r.u16()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .context("tensor name utf-8")?
                .to_string();
            let kind = r.u8()?;
            let a = r.u8()?;
            let b = r.u8()?;
            let elem = match kind {
                0 => ElementFormat::int(a),
                1 => ElementFormat::fp(a, b),
                k => bail!("bad element kind {k}"),
            };
            let block_size = r.u32()? as usize;
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u64()? as usize);
            }
            let n_scales = r.u64()? as usize;
            let scales_bytes = r.take(n_scales)?;
            let scales: Vec<i8> = scales_bytes.iter().map(|&x| x as i8).collect();
            let n_packed = r.u64()? as usize;
            let packed = r.take(n_packed)?.to_vec();
            let t = MxTensor {
                format: MxFormat::new(elem, block_size),
                shape,
                scales,
                packed,
            };
            // Structural validation.
            let n = t.len();
            let expected_packed = crate::formats::pack::packed_len(n, elem.bits());
            if t.packed.len() != expected_packed {
                bail!("tensor '{name}': packed length {} != expected {expected_packed}", t.packed.len());
            }
            let row_len = t.shape.last().copied().unwrap_or(1).max(1);
            let rows = if n == 0 { 0 } else { n / row_len };
            if t.scales.len() != rows * row_len.div_ceil(block_size) {
                bail!("tensor '{name}': scale count mismatch");
            }
            tensors.insert(name, t);
        }
        let n_raw = r.u32()? as usize;
        let mut raw = BTreeMap::new();
        for _ in 0..n_raw {
            let name_len = r.u16()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .context("raw tensor name utf-8")?
                .to_string();
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u64()? as usize);
            }
            let n_data = r.u64()? as usize;
            let bytes = r.take(n_data * 4)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            raw.insert(name.clone(), Tensor::new(&shape, data).context(name)?);
        }
        if r.i != body.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { meta, tensors, raw })
    }

    /// Save to a file (atomic: write temp + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes();
        let tmp = path.with_extension("mfq.tmp");
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename to {}", path.display()))?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes).with_context(|| format!("parse {}", path.display()))
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("checkpoint truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// CRC-32 (IEEE 802.3), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, entry) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        table
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_checkpoint() -> Checkpoint {
        let mut rng = Rng::new(42);
        let mut ck = Checkpoint::new();
        ck.set_meta("model", Json::from("tiny"));
        ck.set_meta("anchor", Json::from("int8"));
        ck.set_meta("seed", Json::from(42usize));
        let a: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        ck.insert(
            "w.0",
            MxTensor::quantize(&a, &[8, 32], MxFormat::mxint(8, 32)).unwrap(),
        );
        let b: Vec<f32> = (0..192).map(|_| rng.normal()).collect();
        ck.insert(
            "w.1",
            MxTensor::quantize(&b, &[3, 64], MxFormat::mxfp(8, 16)).unwrap(),
        );
        let c: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
        ck.insert_raw("emb", Tensor::new(&[6, 8], c).unwrap());
        ck
    }

    #[test]
    fn crc32_known_vector() {
        // CRC32("123456789") = 0xCBF43926 (standard check value).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample_checkpoint();
        let bytes = ck.to_bytes();
        let re = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck.tensors, re.tensors);
        assert_eq!(ck.meta, re.meta);
        assert_eq!(ck.raw, re.raw);
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("mfqat_ck_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.mfq");
        let ck = sample_checkpoint();
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.tensors, re.tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let ck = sample_checkpoint();
        let mut bytes = ck.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let ck = sample_checkpoint();
        let bytes = ck.to_bytes();
        for cut in [0, 3, 10, bytes.len() - 5] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn bad_magic_detected() {
        let ck = sample_checkpoint();
        let mut bytes = ck.to_bytes();
        bytes[0] = b'X';
        // CRC covers the magic, so recompute it to reach the magic check.
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint::new();
        let re = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert!(re.tensors.is_empty());
        assert!(re.meta.is_empty());
    }

    #[test]
    fn anchor_to_target_storage_savings() {
        // The point of the anchor workflow: one 8-bit checkpoint instead of
        // one fp32 model per format.
        let ck = sample_checkpoint();
        // 8-bit MX elements + per-block scales ≈ 4× smaller than fp32 for
        // the quantized tensors (raw tensors stay fp32 on both sides).
        let fp32_bytes: usize = ck.tensors.values().map(|t| t.len() * 4).sum();
        let mx_bytes: usize = ck.tensors.values().map(|t| t.storage_bytes()).sum();
        assert!(mx_bytes * 3 < fp32_bytes, "{mx_bytes} vs {fp32_bytes}");
    }
}
