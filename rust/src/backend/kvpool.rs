//! Paged KV-cache storage: a fixed-size page-pool allocator with
//! refcounted sharing, a content-addressed prefix index, and a
//! cross-worker page ledger.
//!
//! Dense KV allocation sizes every slot for its worst case
//! (`slots × seq_len × d_model` per layer), so a mostly-idle pool of short
//! sequences pays full-window memory the whole time. [`KvPagePool`] instead
//! carves one arena per K and V into fixed-size **pages** of
//! [`KvPageCfg::page_positions`] positions (each page spans every layer, so
//! one allocation funds a position range across the whole stack), hands
//! them out from a free list as rows append tokens, and takes them back —
//! zeroed — when a row retires, resets, or re-prefills after window
//! overflow. Resident KV memory therefore tracks **live context**, not slot
//! capacity, and admission can be budgeted in pages instead of slots
//! ([`crate::backend::forward::KvCache::can_fund_row`]).
//!
//! Three structures layer sharing on top of the allocator:
//!
//! - **Per-page refcounts.** [`KvPagePool::alloc`] hands a page out with
//!   one reference; [`KvPagePool::retain`] adds more (a prefix-sharing row
//!   or the prefix index mapping the same immutable page) and
//!   [`KvPagePool::release`] drops one. Zeroing happens **only at the last
//!   drop**, so release is keyed to the refcount reaching zero, never to
//!   the call site — a page referenced by any other row or by the index is
//!   untouched, and a page that does reach zero can never leak a previous
//!   occupant's keys/values to the next sequence that maps it (the
//!   quarantine guarantee `rust/tests/kv_paging.rs` and
//!   `rust/tests/prefix_sharing.rs` regress).
//! - **[`PrefixIndex`]** — a content-addressed map from
//!   `(chained token hash, row tag)` to full pages already holding that
//!   prefix's K/V. Lookups verify **exact token equality** (the hash only
//!   narrows the search), so a hash collision can cause a missed share but
//!   never a wrong one. The index holds its own page reference, which is
//!   what keeps a retired conversation's prefix warm for the next turn;
//!   LRU eviction under pool pressure (or a retain cap) drops index-only
//!   pages back to the free list, and a later miss simply recomputes via
//!   normal prefill.
//! - **[`PageLedger`]** — a pool-wide admission budget shared across
//!   worker sessions through an `Arc`. Each admitted row claims its
//!   worst-case page count from the ledger and returns it at retire (or
//!   when the owning cache drops, so a panicking worker can never strand
//!   its share), letting admission trade memory between workers under
//!   skewed load instead of capping each worker independently.
//!
//! Pages themselves store K/V in a selectable element format
//! ([`KvFormat`], `--kv-format` / `MFQAT_KV_FORMAT`): dense f32 (the
//! default, bit-identical to the pre-quantization pool) or MX-coded blocks
//! — packed integer/minifloat codes plus one E8M0 scale per
//! [`KV_SCALE_BLOCK`] channels, encoded with the same edge-hardening rules
//! as weight blocks ([`crate::formats::mxblock::shared_exponent`]). The
//! allocator, refcounting, prefix index, and ledger are format-agnostic —
//! they deal in whole pages — while [`KvPagePool::write_pos`] /
//! [`KvPagePool::dequant_positions`] / [`KvPagePool::copy_prefix`] move
//! the actual bytes, so sharing, copy-on-write, speculative rollback, and
//! zero-on-release all work unchanged on quantized pages.
//!
//! [`KvMemory`] is the accounting snapshot surfaced through
//! [`crate::backend::DecodeSession::kv_memory`] and
//! `server::Metrics::summary()`; `benches/serving.rs` records it as the
//! `kv_memory.*` and `prefix_sharing.*` sections of `BENCH_serving.json`.

use crate::backend::simd;
use crate::formats::int::quantize_int;
use crate::formats::mxblock::shared_exponent;
use crate::formats::pack::{pack_into, packed_len};
use crate::formats::{exp2i, ElementFormat, FpSpec, RoundMode};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Default page size in positions when `MFQAT_KV_PAGE` is unset.
pub const DEFAULT_PAGE_POSITIONS: usize = 64;

/// Channels per shared E8M0 scale in quantized KV pages: each run of
/// `KV_SCALE_BLOCK` channels within one position's K (or V) row shares one
/// power-of-two exponent, mirroring the MX block size used for weights.
/// Fixed (not a knob) so the per-position byte cost is a pure function of
/// [`KvFormat`] and `d_model`.
pub const KV_SCALE_BLOCK: usize = 32;

/// Element format of the K/V pages held by a [`KvPagePool`].
///
/// `F32` is the dense default and is bit-identical to the pre-quantization
/// pool. The MX variants store packed per-position codes plus one E8M0
/// scale per [`KV_SCALE_BLOCK`] channels, encoded with the same
/// edge-hardening rules as weight blocks (NaN-ignoring amax, all-zero
/// blocks pin the minimum exponent, infinities saturate — see
/// [`crate::formats::mxblock::shared_exponent`]), cutting resident KV
/// bytes roughly 3.9× (8-bit codes) to 7.3× (4-bit codes) versus dense
/// f32 at `d_model = 64`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvFormat {
    /// Dense f32 K/V (the default; bit-identical to the unquantized pool).
    #[default]
    F32,
    /// MXINT8 codes: one signed byte per channel + block scales.
    MxInt8,
    /// MXFP8 (OCP E4M3) codes: one minifloat byte per channel + block
    /// scales.
    MxFp8,
    /// MXINT4 codes: two channels per byte + block scales.
    MxInt4,
}

impl KvFormat {
    /// Parse a CLI/env spelling (`f32`|`dense`, `mxint8`|`int8`,
    /// `mxfp8`|`fp8`, `mxint4`|`int4`); `None` when unrecognised.
    pub fn parse(s: &str) -> Option<KvFormat> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "dense" => Some(KvFormat::F32),
            "mxint8" | "int8" => Some(KvFormat::MxInt8),
            "mxfp8" | "fp8" => Some(KvFormat::MxFp8),
            "mxint4" | "int4" => Some(KvFormat::MxInt4),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::MxInt8 => "mxint8",
            KvFormat::MxFp8 => "mxfp8",
            KvFormat::MxInt4 => "mxint4",
        }
    }

    /// The MX element format of the stored codes; `None` for dense f32.
    pub fn elem(self) -> Option<ElementFormat> {
        match self {
            KvFormat::F32 => None,
            KvFormat::MxInt8 => Some(ElementFormat::int(8)),
            KvFormat::MxFp8 => Some(ElementFormat::fp(4, 3)),
            KvFormat::MxInt4 => Some(ElementFormat::int(4)),
        }
    }

    /// True for the MX-coded variants.
    pub fn is_quantized(self) -> bool {
        !matches!(self, KvFormat::F32)
    }

    /// Stored code bytes for one position's K (or V) row of `d_model`
    /// channels (f32 rows count their dense bytes).
    fn code_bytes_per_row(self, d_model: usize) -> usize {
        match self.elem() {
            None => d_model * std::mem::size_of::<f32>(),
            Some(e) => packed_len(d_model, e.bits()),
        }
    }

    /// Scale bytes (one E8M0 exponent per [`KV_SCALE_BLOCK`] channels) for
    /// one position's K (or V) row; `0` for dense f32.
    fn scale_bytes_per_row(self, d_model: usize) -> usize {
        if self.is_quantized() {
            d_model.div_ceil(KV_SCALE_BLOCK)
        } else {
            0
        }
    }

    /// Stored bytes for one position of one layer across both arenas
    /// (K + V): the per-position cost accounting and admission see.
    pub fn bytes_per_position(self, d_model: usize) -> usize {
        2 * (self.code_bytes_per_row(d_model) + self.scale_bytes_per_row(d_model))
    }
}

/// Position layout of a [`KvPagePool`]'s pages: each page holds
/// `page_positions` positions across all `n_layers` layers of `d_model`
/// channels, stored as [`KvFormat`] elements. Within a page, one layer's
/// positions are contiguous (`[layer][position][channel]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPageLayout {
    /// Transformer layers spanned by each page.
    pub n_layers: usize,
    /// Positions per page.
    pub page_positions: usize,
    /// Channels per position (per layer, per arena).
    pub d_model: usize,
    /// Element format of the stored K/V.
    pub format: KvFormat,
}

/// Page-pool sizing for a [`crate::backend::forward::KvCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPageCfg {
    /// Positions per page (the paging granularity). Clamped to the model
    /// window at cache construction; tiny values (e.g. `8`) force page
    /// boundaries mid-prompt and mid-decode, which CI exercises via
    /// `MFQAT_KV_PAGE=8`.
    pub page_positions: usize,
    /// Total pages in the pool; `0` funds every row's worst case
    /// (`rows × ceil(seq_len / page_positions)` — dense-equivalent
    /// capacity, the default). Smaller budgets make admission
    /// memory-aware: [`crate::backend::forward::KvCache::join_row`] defers
    /// rows the pool cannot fund. Clamped up to at least one row's worst
    /// case so a pool can always serve one sequence.
    pub budget_pages: usize,
    /// Enable prefix sharing: joining rows map full pages already holding
    /// an identical `(prefix tokens, row tag)` span and skip prefill for
    /// it, and retired rows leave their full pages behind in the
    /// [`PrefixIndex`] for later turns. Off by default — retention changes
    /// the "free list returns to baseline after drain" invariant, so it is
    /// strictly opt-in (`--prefix-share` / `MFQAT_PREFIX_SHARE`).
    pub prefix_share: bool,
    /// Cap on pages the prefix index may retain beyond live rows
    /// (LRU-evicted past the cap); `0` means no cap — index pages are
    /// evicted only under pool pressure (`MFQAT_KV_RETAIN` / `--kv-retain`).
    pub retain_pages: usize,
    /// Element format of the K/V pages (`--kv-format` /
    /// `MFQAT_KV_FORMAT`). Dense f32 by default — bit-identical to the
    /// pre-quantization cache; the MX variants trade a bounded per-format
    /// decode error for several-fold more admitted rows per page budget.
    pub kv_format: KvFormat,
}

impl Default for KvPageCfg {
    fn default() -> Self {
        KvPageCfg::from_env()
    }
}

/// True for "1" / "true" / "on" (case-insensitive), false otherwise.
fn env_flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on"),
        Err(_) => false,
    }
}

impl KvPageCfg {
    /// Page size from the `MFQAT_KV_PAGE` environment pin (positions per
    /// page; see `util/cli.rs` for the env-var table), full funding.
    /// Prefix sharing follows `MFQAT_PREFIX_SHARE`, the retain cap
    /// follows `MFQAT_KV_RETAIN`, and the page element format follows
    /// `MFQAT_KV_FORMAT` (all optional).
    pub fn from_env() -> KvPageCfg {
        let kv_format = match std::env::var("MFQAT_KV_FORMAT") {
            Ok(v) => KvFormat::parse(&v).unwrap_or_else(|| {
                log::warn!(
                    "MFQAT_KV_FORMAT='{v}' is not f32|mxint8|mxfp8|mxint4; using dense f32"
                );
                KvFormat::F32
            }),
            Err(_) => KvFormat::F32,
        };
        let page_positions = match std::env::var("MFQAT_KV_PAGE") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => {
                    log::warn!(
                        "MFQAT_KV_PAGE='{v}' is not a positive integer; \
                         using the default page of {DEFAULT_PAGE_POSITIONS} positions"
                    );
                    DEFAULT_PAGE_POSITIONS
                }
            },
            Err(_) => DEFAULT_PAGE_POSITIONS,
        };
        let retain_pages = match std::env::var("MFQAT_KV_RETAIN") {
            Ok(v) => v.trim().parse::<usize>().unwrap_or_else(|_| {
                log::warn!("MFQAT_KV_RETAIN='{v}' is not an integer; using no cap");
                0
            }),
            Err(_) => 0,
        };
        KvPageCfg {
            page_positions,
            budget_pages: 0,
            prefix_share: env_flag("MFQAT_PREFIX_SHARE"),
            retain_pages,
            kv_format,
        }
    }

    /// Explicit page size, full funding, sharing off, dense f32 pages.
    pub fn with_page(page_positions: usize) -> KvPageCfg {
        KvPageCfg {
            page_positions: page_positions.max(1),
            budget_pages: 0,
            prefix_share: false,
            retain_pages: 0,
            kv_format: KvFormat::F32,
        }
    }

    /// Restrict the pool to `budget_pages` total pages (builder-style).
    pub fn budget(mut self, budget_pages: usize) -> KvPageCfg {
        self.budget_pages = budget_pages;
        self
    }

    /// Toggle prefix sharing (builder-style).
    pub fn share(mut self, on: bool) -> KvPageCfg {
        self.prefix_share = on;
        self
    }

    /// Cap retained prefix-index pages (builder-style; `0` = no cap).
    pub fn retain(mut self, retain_pages: usize) -> KvPageCfg {
        self.retain_pages = retain_pages;
        self
    }

    /// Select the K/V page element format (builder-style).
    pub fn format(mut self, kv_format: KvFormat) -> KvPageCfg {
        self.kv_format = kv_format;
        self
    }
}

/// A snapshot of paged-KV accounting: what is resident now versus what the
/// pre-paging dense layout would have preallocated, plus the
/// prefix-sharing economy counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvMemory {
    /// Bytes held by pages currently mapped into row page tables (K + V).
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` over the cache's lifetime,
    /// recorded **at page-allocation time** — so a row that maps pages and
    /// retires within one decode step still registers its footprint (a
    /// snapshot taken between steps would miss it).
    pub resident_peak_bytes: usize,
    /// Dense-f32 bytes the currently mapped pages would occupy if stored
    /// unquantized; equals `resident_bytes` for `kv_format = "f32"`, and
    /// `resident_bytes × compression` for MX-coded pages.
    pub resident_f32_equiv_bytes: usize,
    /// Bytes the dense f32 layout would preallocate for the same cache
    /// (`rows × n_layers × seq_len × d_model × 2 × 4`).
    pub dense_equivalent_bytes: usize,
    /// Total arena bytes backing the pool (all pages, free or mapped).
    pub pool_bytes: usize,
    /// Pages currently mapped into page tables.
    pub used_pages: usize,
    /// Pages on the free list.
    pub free_pages: usize,
    /// Pool size in pages.
    pub total_pages: usize,
    /// Positions per page.
    pub page_positions: usize,
    /// Bytes deduplicated by sharing: `Σ max(refcount − 1, 0) × page_bytes`
    /// — each extra reference to a page is one page of K/V some consumer
    /// did not have to store (or recompute) itself.
    pub shared_bytes: usize,
    /// Pages currently retained by the prefix index (each index entry
    /// holds exactly one page reference).
    pub retained_pages: usize,
    /// Row admissions that mapped at least one shared prefix page.
    pub prefix_hits: u64,
    /// Prompt positions whose prefill was skipped because a shared page
    /// already held their K/V.
    pub prefill_tokens_saved: u64,
    /// Prefix-index entries dropped by LRU eviction (pool pressure or the
    /// retain cap); a later lookup for that span recomputes via prefill.
    pub prefix_evictions: u64,
    /// Canonical [`KvFormat`] name of the pool's pages (empty when the
    /// snapshot was aggregated across pools without format information).
    pub kv_format: &'static str,
}

impl KvMemory {
    /// Fraction of the pool's pages currently mapped (0.0 on an empty or
    /// absent pool).
    pub fn utilization(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            self.used_pages as f64 / self.total_pages as f64
        }
    }

    /// Resident bytes over the dense-equivalent allocation (the headline
    /// paging win; 0.0 when there is no dense baseline).
    pub fn resident_over_dense(&self) -> f64 {
        if self.dense_equivalent_bytes == 0 {
            0.0
        } else {
            self.resident_bytes as f64 / self.dense_equivalent_bytes as f64
        }
    }

    /// Dense-f32 bytes per stored byte for the mapped pages (the
    /// quantization win; `1.0` for dense f32 pools or when nothing is
    /// resident).
    pub fn compression_ratio(&self) -> f64 {
        if self.resident_bytes == 0 || self.resident_f32_equiv_bytes == 0 {
            1.0
        } else {
            self.resident_f32_equiv_bytes as f64 / self.resident_bytes as f64
        }
    }
}

/// Fixed-size page arenas (one set for K, one for V) plus a LIFO free
/// list and per-page reference counts.
///
/// Dense f32 pools keep K/V in two `Vec<f32>` arenas; quantized pools
/// ([`KvFormat::is_quantized`]) keep packed code-byte arenas plus i8
/// E8M0-scale arenas instead, with one code row + scale row per
/// `(layer, position)` of each page. Position addressing follows
/// [`KvPageLayout`]; the allocator itself (alloc/retain/release/shrink)
/// deals only in whole pages.
#[derive(Debug, Clone)]
pub struct KvPagePool {
    layout: KvPageLayout,
    /// Dense-equivalent f32 count per arena-page
    /// (`n_layers × page_positions × d_model`): the f32 arenas' page
    /// stride, and the compression baseline for quantized pools.
    floats_per_page: usize,
    /// Packed code bytes per arena-page (quantized formats; `0` for f32).
    codes_per_page: usize,
    /// Scale bytes per arena-page (quantized formats; `0` for f32).
    scales_per_page: usize,
    total: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    k_codes: Vec<u8>,
    v_codes: Vec<u8>,
    k_scales: Vec<i8>,
    v_scales: Vec<i8>,
    /// 256-entry minifloat decode table ([`KvFormat::MxFp8`] only).
    fp_lut: Vec<f32>,
    /// Per-row i8 code scratch for the sub-byte quantized write path.
    scratch: Vec<i8>,
    free: Vec<usize>,
    /// Reference count per page: `0` = free, `1` = one holder (a single
    /// row's table, or the prefix index alone), `> 1` = shared.
    refs: Vec<u32>,
    /// Per-page high-water mark: highest written in-page position + 1.
    /// Zero-on-release wipes only this occupied span instead of the whole
    /// page, so a page that held two positions of a 64-position layout
    /// memsets 2/64ths of its arenas.
    hiwater: Vec<u32>,
    /// Pages removed from service by [`Self::shrink`]: still part of the
    /// arena (so release-time range asserts stay valid) but never handed
    /// out again and excluded from every capacity report.
    quarantined: Vec<usize>,
}

impl KvPagePool {
    /// Pool of `total` dense-f32 pages of `floats_per_page` f32s per
    /// arena, all free — the layout-agnostic constructor, kept for callers
    /// that index pages by raw [`Self::k_mut`] spans (each page is treated
    /// as one position of `floats_per_page` channels).
    pub fn new(total: usize, floats_per_page: usize) -> KvPagePool {
        KvPagePool::with_layout(
            total,
            KvPageLayout {
                n_layers: 1,
                page_positions: 1,
                d_model: floats_per_page,
                format: KvFormat::F32,
            },
        )
    }

    /// Pool of `total` pages with an explicit position [`KvPageLayout`]
    /// (quantized formats need the layout to place per-position code and
    /// scale rows), all free.
    pub fn with_layout(total: usize, layout: KvPageLayout) -> KvPagePool {
        let floats_per_page = layout.n_layers * layout.page_positions * layout.d_model;
        let rows_per_page = layout.n_layers * layout.page_positions;
        let quant = layout.format.is_quantized();
        let codes_per_page = if quant {
            rows_per_page * layout.format.code_bytes_per_row(layout.d_model)
        } else {
            0
        };
        let scales_per_page = rows_per_page * layout.format.scale_bytes_per_row(layout.d_model);
        let dense_floats = if quant { 0 } else { total * floats_per_page };
        let fp_lut = if layout.format == KvFormat::MxFp8 {
            let spec = FpSpec::new(4, 3);
            (0..=255u8).map(|b| spec.decode(b)).collect()
        } else {
            Vec::new()
        };
        KvPagePool {
            layout,
            floats_per_page,
            codes_per_page,
            scales_per_page,
            total,
            k: vec![0.0; dense_floats],
            v: vec![0.0; dense_floats],
            k_codes: vec![0; total * codes_per_page],
            v_codes: vec![0; total * codes_per_page],
            k_scales: vec![0; total * scales_per_page],
            v_scales: vec![0; total * scales_per_page],
            fp_lut,
            scratch: Vec::new(),
            // LIFO so recently-hot pages are remapped first.
            free: (0..total).rev().collect(),
            refs: vec![0; total],
            hiwater: vec![0; total],
            quarantined: Vec::new(),
        }
    }

    /// Permanently remove up to `want` **free** pages from service
    /// (mid-run budget shrink — the fault-injection harness and elastic
    /// memory pressure both use this). Mapped pages are never touched, so
    /// live rows keep every page they hold; the pool simply gets smaller.
    /// Returns how many pages were actually quarantined.
    pub fn shrink(&mut self, want: usize) -> usize {
        let take = want.min(self.free.len());
        for _ in 0..take {
            let p = self.free.pop().expect("free list length checked above");
            self.quarantined.push(p);
        }
        take
    }

    /// Pages removed from service by [`Self::shrink`].
    pub fn quarantined_pages(&self) -> usize {
        self.quarantined.len()
    }

    /// Claim a page with one reference; `None` when the pool is exhausted.
    /// Handed-out pages are always zeroed (arenas start zeroed,
    /// [`Self::release`]'s last drop re-zeroes).
    pub fn alloc(&mut self) -> Option<usize> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p], 0, "free page {p} had live references");
        debug_assert_eq!(self.hiwater[p], 0, "free page {p} had an occupied span");
        self.refs[p] = 1;
        Some(p)
    }

    /// Add a reference to an already-held page (a sharing row or the
    /// prefix index mapping the same immutable content).
    pub fn retain(&mut self, page: usize) {
        debug_assert!(page < self.total, "retained page {page} out of range");
        assert!(
            self.refs[page] > 0,
            "retain of free KV page {page} (use alloc)"
        );
        self.refs[page] += 1;
    }

    /// Current reference count of `page` (`0` = free).
    pub fn ref_count(&self, page: usize) -> u32 {
        self.refs[page]
    }

    /// Drop one reference to `page`. The page is returned to the free
    /// list — **with its occupied K and V spans zeroed** so no stale
    /// keys/values survive into the next mapping — only when the **last**
    /// reference drops; earlier drops leave the content untouched for the
    /// remaining holders. This keys zeroing to the refcount reaching zero
    /// rather than to any particular call site (`retire_row` /
    /// `truncate_row` / `reset_row` all funnel here), which is what makes
    /// those paths safe to run against shared pages. Only the span up to
    /// the per-page high-water mark is memset (positions above it were
    /// never written and are still zero from the previous release).
    pub fn release(&mut self, page: usize) {
        debug_assert!(page < self.total, "released page {page} out of range");
        debug_assert!(!self.free.contains(&page), "double free of KV page {page}");
        assert!(self.refs[page] > 0, "release of free KV page {page}");
        self.refs[page] -= 1;
        if self.refs[page] == 0 {
            self.zero_occupied(page);
            self.free.push(page);
        }
    }

    /// Zero `page`'s occupied span (positions `0..high_water`) in every
    /// arena and reset the mark.
    fn zero_occupied(&mut self, page: usize) {
        let hw = std::mem::take(&mut self.hiwater[page]) as usize;
        if hw == 0 {
            return;
        }
        let KvPageLayout {
            n_layers,
            page_positions: pp,
            d_model: d,
            format,
        } = self.layout;
        if format.is_quantized() {
            let cbr = format.code_bytes_per_row(d);
            let sbr = format.scale_bytes_per_row(d);
            for l in 0..n_layers {
                let row0 = (page * n_layers + l) * pp;
                self.k_codes[row0 * cbr..(row0 + hw) * cbr].fill(0);
                self.v_codes[row0 * cbr..(row0 + hw) * cbr].fill(0);
                self.k_scales[row0 * sbr..(row0 + hw) * sbr].fill(0);
                self.v_scales[row0 * sbr..(row0 + hw) * sbr].fill(0);
            }
        } else {
            for l in 0..n_layers {
                let s = page * self.floats_per_page + l * pp * d;
                self.k[s..s + hw * d].fill(0.0);
                self.v[s..s + hw * d].fill(0.0);
            }
        }
    }

    /// K-arena span of `page` (dense f32 pools only).
    pub fn k(&self, page: usize) -> &[f32] {
        debug_assert!(!self.layout.format.is_quantized(), "raw span on quantized pool");
        &self.k[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// V-arena span of `page` (dense f32 pools only).
    pub fn v(&self, page: usize) -> &[f32] {
        debug_assert!(!self.layout.format.is_quantized(), "raw span on quantized pool");
        &self.v[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Mutable K-arena span of `page` (dense f32 pools only). A raw-span
    /// writer may touch any position, so the whole page counts as occupied
    /// for zero-on-release.
    pub fn k_mut(&mut self, page: usize) -> &mut [f32] {
        debug_assert!(!self.layout.format.is_quantized(), "raw span on quantized pool");
        self.hiwater[page] = self.layout.page_positions as u32;
        &mut self.k[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Mutable V-arena span of `page` (dense f32 pools only; see
    /// [`Self::k_mut`] for the high-water effect).
    pub fn v_mut(&mut self, page: usize) -> &mut [f32] {
        debug_assert!(!self.layout.format.is_quantized(), "raw span on quantized pool");
        self.hiwater[page] = self.layout.page_positions as u32;
        &mut self.v[page * self.floats_per_page..(page + 1) * self.floats_per_page]
    }

    /// Write one position's K and V channel rows (layer `layer`, in-page
    /// position `pos`) in the pool's element format. Quantized formats
    /// encode each [`KV_SCALE_BLOCK`]-channel run into one shared E8M0
    /// exponent plus packed codes; the position's full code + scale rows
    /// are overwritten, so re-writing a position (speculative-rollback
    /// replay) is deterministic regardless of prior content.
    pub fn write_pos(
        &mut self,
        page: usize,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let KvPageLayout {
            n_layers,
            page_positions: pp,
            d_model: d,
            format,
        } = self.layout;
        debug_assert!(layer < n_layers && pos < pp, "write_pos outside page layout");
        debug_assert_eq!(k_row.len(), d);
        debug_assert_eq!(v_row.len(), d);
        debug_assert!(self.refs[page] > 0, "write to unmapped page {page}");
        if let Some(elem) = format.elem() {
            let cbr = format.code_bytes_per_row(d);
            let sbr = format.scale_bytes_per_row(d);
            let row = (page * n_layers + layer) * pp + pos;
            let mut scratch = std::mem::take(&mut self.scratch);
            encode_row(
                elem,
                k_row,
                &mut scratch,
                &mut self.k_codes[row * cbr..(row + 1) * cbr],
                &mut self.k_scales[row * sbr..(row + 1) * sbr],
            );
            encode_row(
                elem,
                v_row,
                &mut scratch,
                &mut self.v_codes[row * cbr..(row + 1) * cbr],
                &mut self.v_scales[row * sbr..(row + 1) * sbr],
            );
            self.scratch = scratch;
        } else {
            let off = page * self.floats_per_page + (layer * pp + pos) * d;
            self.k[off..off + d].copy_from_slice(k_row);
            self.v[off..off + d].copy_from_slice(v_row);
        }
        let hw = (pos + 1) as u32;
        if self.hiwater[page] < hw {
            self.hiwater[page] = hw;
        }
    }

    /// Decode `n` consecutive positions of layer `layer` starting at
    /// in-page position `pos` into dense f32 rows (`n × d_model` floats
    /// each for K and V). Dense pools copy; quantized pools dispatch the
    /// SIMD dequant kernels in [`crate::backend::simd`] (bit-identical to
    /// their portable oracles, so decode output is independent of the
    /// dispatch level).
    pub fn dequant_positions(
        &self,
        page: usize,
        layer: usize,
        pos: usize,
        n: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let KvPageLayout {
            n_layers,
            page_positions: pp,
            d_model: d,
            format,
        } = self.layout;
        debug_assert!(layer < n_layers && pos + n <= pp, "span outside page layout");
        debug_assert_eq!(k_out.len(), n * d);
        debug_assert_eq!(v_out.len(), n * d);
        if format.is_quantized() {
            let cbr = format.code_bytes_per_row(d);
            let sbr = format.scale_bytes_per_row(d);
            let row0 = (page * n_layers + layer) * pp + pos;
            let kc = &self.k_codes[row0 * cbr..(row0 + n) * cbr];
            let vc = &self.v_codes[row0 * cbr..(row0 + n) * cbr];
            let ks = &self.k_scales[row0 * sbr..(row0 + n) * sbr];
            let vs = &self.v_scales[row0 * sbr..(row0 + n) * sbr];
            match format {
                KvFormat::MxInt8 => {
                    simd::kv_dequant_i8(kc, ks, d, KV_SCALE_BLOCK, k_out);
                    simd::kv_dequant_i8(vc, vs, d, KV_SCALE_BLOCK, v_out);
                }
                KvFormat::MxFp8 => {
                    simd::kv_dequant_fp8(kc, ks, &self.fp_lut, d, KV_SCALE_BLOCK, k_out);
                    simd::kv_dequant_fp8(vc, vs, &self.fp_lut, d, KV_SCALE_BLOCK, v_out);
                }
                KvFormat::MxInt4 => {
                    simd::kv_dequant_i4(kc, ks, d, KV_SCALE_BLOCK, k_out);
                    simd::kv_dequant_i4(vc, vs, d, KV_SCALE_BLOCK, v_out);
                }
                KvFormat::F32 => unreachable!("quantized match arm"),
            }
        } else {
            let off = page * self.floats_per_page + (layer * pp + pos) * d;
            k_out.copy_from_slice(&self.k[off..off + n * d]);
            v_out.copy_from_slice(&self.v[off..off + n * d]);
        }
    }

    /// Copy the first `positions` positions of **every** layer from page
    /// `src` to page `dst`, in whatever representation the pool stores
    /// (the copy-on-write primitive: the owner of `dst` gets a private
    /// copy of `src`'s prefix while `src` stays intact for its remaining
    /// holders). Raises `dst`'s high-water mark to cover the copy.
    pub fn copy_prefix(&mut self, src: usize, dst: usize, positions: usize) {
        let KvPageLayout {
            n_layers,
            page_positions: pp,
            d_model: d,
            format,
        } = self.layout;
        debug_assert!(positions <= pp, "span exceeds page");
        if format.is_quantized() {
            let cbr = format.code_bytes_per_row(d);
            let sbr = format.scale_bytes_per_row(d);
            for l in 0..n_layers {
                let s = (src * n_layers + l) * pp;
                let t = (dst * n_layers + l) * pp;
                self.k_codes.copy_within(s * cbr..(s + positions) * cbr, t * cbr);
                self.v_codes.copy_within(s * cbr..(s + positions) * cbr, t * cbr);
                self.k_scales.copy_within(s * sbr..(s + positions) * sbr, t * sbr);
                self.v_scales.copy_within(s * sbr..(s + positions) * sbr, t * sbr);
            }
        } else {
            for l in 0..n_layers {
                let s = src * self.floats_per_page + l * pp * d;
                let t = dst * self.floats_per_page + l * pp * d;
                self.k.copy_within(s..s + positions * d, t);
                self.v.copy_within(s..s + positions * d, t);
            }
        }
        let hw = positions as u32;
        if self.hiwater[dst] < hw {
            self.hiwater[dst] = hw;
        }
    }

    /// Pages on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently handed out (distinct pages, however many references
    /// each carries).
    pub fn used_pages(&self) -> usize {
        self.total - self.free.len() - self.quarantined.len()
    }

    /// Pool size in pages (excluding pages quarantined by
    /// [`Self::shrink`]).
    pub fn total_pages(&self) -> usize {
        self.total - self.quarantined.len()
    }

    /// Dense-equivalent f32s per page per arena.
    pub fn floats_per_page(&self) -> usize {
        self.floats_per_page
    }

    /// Position layout of the pool's pages.
    pub fn layout(&self) -> KvPageLayout {
        self.layout
    }

    /// Element format of the stored pages.
    pub fn format(&self) -> KvFormat {
        self.layout.format
    }

    /// Highest written in-page position + 1 on `page` (the span
    /// zero-on-release wipes); `0` for a never-written page.
    pub fn page_high_water(&self, page: usize) -> usize {
        self.hiwater[page] as usize
    }

    /// Bytes one mapped page actually stores across both arenas (K + V):
    /// dense f32 bytes for [`KvFormat::F32`], packed codes + scales for
    /// the MX formats.
    pub fn page_bytes(&self) -> usize {
        if self.layout.format.is_quantized() {
            2 * (self.codes_per_page + self.scales_per_page)
        } else {
            2 * self.floats_per_page * std::mem::size_of::<f32>()
        }
    }

    /// Bytes the same page would occupy stored as dense f32 (the
    /// compression baseline; equals [`Self::page_bytes`] for f32 pools).
    pub fn dense_page_bytes(&self) -> usize {
        2 * self.floats_per_page * std::mem::size_of::<f32>()
    }

    /// Total in-service arena bytes (all pages, free or mapped; pages
    /// quarantined by [`Self::shrink`] no longer count).
    pub fn pool_bytes(&self) -> usize {
        self.total_pages() * self.page_bytes()
    }

    /// Bytes deduplicated by sharing: `Σ max(refcount − 1, 0) × page_bytes`.
    pub fn shared_bytes(&self) -> usize {
        let extra: usize = self
            .refs
            .iter()
            .map(|&r| (r as usize).saturating_sub(1))
            .sum();
        extra * self.page_bytes()
    }
}

/// Encode one position's channel row into MX codes + per-block E8M0
/// scales, with the same edge rules as weight blocks: the shared exponent
/// is the NaN-ignoring amax exponent minus the element's `emax`, all-zero
/// blocks pin the minimum exponent, infinities saturate the exponent, and
/// element quantization is saturating round-to-nearest-even (NaN → 0).
/// `scratch` is a reusable code buffer for the sub-byte bit-packing path.
fn encode_row(
    elem: ElementFormat,
    x: &[f32],
    scratch: &mut Vec<i8>,
    codes: &mut [u8],
    scales: &mut [i8],
) {
    let bits = elem.bits();
    if let Some(spec) = elem.fp_spec() {
        for (b, chunk) in x.chunks(KV_SCALE_BLOCK).enumerate() {
            let e = shared_exponent(chunk, elem);
            scales[b] = e as i8;
            let inv = exp2i(-e);
            for (c, &v) in codes[b * KV_SCALE_BLOCK..].iter_mut().zip(chunk.iter()) {
                *c = spec.quantize_code(v * inv);
            }
        }
    } else if bits == 8 {
        for (b, chunk) in x.chunks(KV_SCALE_BLOCK).enumerate() {
            let e = shared_exponent(chunk, elem);
            scales[b] = e as i8;
            let inv = exp2i(-e);
            for (c, &v) in codes[b * KV_SCALE_BLOCK..].iter_mut().zip(chunk.iter()) {
                *c = quantize_int(v * inv, 8, RoundMode::HalfEven) as u8;
            }
        }
    } else {
        // Sub-byte integer codes quantize into the scratch row, then
        // bit-pack in one pass (pack_into zero-fills `codes` first, so the
        // row is fully overwritten).
        scratch.resize(x.len(), 0);
        for (b, chunk) in x.chunks(KV_SCALE_BLOCK).enumerate() {
            let e = shared_exponent(chunk, elem);
            scales[b] = e as i8;
            let inv = exp2i(-e);
            for (s, &v) in scratch[b * KV_SCALE_BLOCK..].iter_mut().zip(chunk.iter()) {
                *s = quantize_int(v * inv, bits, RoundMode::HalfEven);
            }
        }
        pack_into(&scratch[..x.len()], bits, codes);
    }
}

/// A pool-wide page-admission budget shared across worker sessions.
///
/// Each admitted row claims its worst-case page count
/// ([`crate::backend::forward::KvCache`]'s `pages_per_row`) with
/// [`Self::try_claim`] and returns it at retire (or when the owning cache
/// drops — panic unwinding included — so a crashed worker can never strand
/// its share). Workers that attach a ledger run their local pool at full
/// size and let the ledger be the single admission gate, which is what
/// lets one hot worker borrow the headroom an idle worker isn't using.
#[derive(Debug)]
pub struct PageLedger {
    total: usize,
    claimed: AtomicUsize,
}

impl PageLedger {
    /// Ledger holding `total` claimable pages.
    pub fn new(total: usize) -> PageLedger {
        PageLedger {
            total,
            claimed: AtomicUsize::new(0),
        }
    }

    /// Total claimable pages.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Pages currently claimed.
    pub fn claimed(&self) -> usize {
        self.claimed.load(Ordering::Acquire)
    }

    /// Pages still claimable.
    pub fn available(&self) -> usize {
        self.total.saturating_sub(self.claimed())
    }

    /// Atomically claim `n` pages; `false` (claiming nothing) when fewer
    /// than `n` remain.
    pub fn try_claim(&self, n: usize) -> bool {
        let mut cur = self.claimed.load(Ordering::Acquire);
        loop {
            if cur + n > self.total {
                return false;
            }
            match self.claimed.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Return `n` claimed pages to the ledger.
    pub fn release(&self, n: usize) {
        let prev = self.claimed.fetch_sub(n, Ordering::AcqRel);
        debug_assert!(prev >= n, "ledger released {n} pages but held {prev}");
    }
}

/// One cache's claim against a shared [`PageLedger`].
///
/// Dropping the share (the owning cache retiring normally, or unwinding
/// through a worker panic) returns every still-claimed page, so ledger
/// capacity can never be stranded by a crashed worker.
#[derive(Debug)]
pub struct LedgerShare {
    ledger: Arc<PageLedger>,
    claimed: usize,
}

impl LedgerShare {
    /// A zero-claim share against `ledger`.
    pub fn new(ledger: Arc<PageLedger>) -> LedgerShare {
        LedgerShare { ledger, claimed: 0 }
    }

    /// The ledger this share draws from.
    pub fn ledger(&self) -> &Arc<PageLedger> {
        &self.ledger
    }

    /// Pages this share currently holds.
    pub fn claimed(&self) -> usize {
        self.claimed
    }

    /// Claim `n` more pages; `false` if the ledger cannot fund them.
    pub fn try_claim(&mut self, n: usize) -> bool {
        if self.ledger.try_claim(n) {
            self.claimed += n;
            true
        } else {
            false
        }
    }

    /// Return `n` of this share's pages to the ledger.
    pub fn release(&mut self, n: usize) {
        debug_assert!(n <= self.claimed, "share released more than it claimed");
        let n = n.min(self.claimed);
        self.claimed -= n;
        self.ledger.release(n);
    }
}

impl Drop for LedgerShare {
    fn drop(&mut self) {
        if self.claimed > 0 {
            self.ledger.release(self.claimed);
            self.claimed = 0;
        }
    }
}

impl Clone for LedgerShare {
    /// Clones start with **zero** claims: a claim belongs to the cache
    /// instance that made it, so a cloned cache re-claims as it admits
    /// rows rather than double-releasing the original's pages on drop.
    fn clone(&self) -> LedgerShare {
        LedgerShare {
            ledger: Arc::clone(&self.ledger),
            claimed: 0,
        }
    }
}

/// Chained content hash of a tagged token prefix: `hash(tag, len, tokens)`.
/// Used only to narrow [`PrefixIndex`] lookups — every hit is verified by
/// exact token comparison, so collisions can cost a share but never
/// fabricate one.
fn chain_hash<K: Hash>(tag: &K, tokens: &[i32]) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    tag.hash(&mut h);
    tokens.len().hash(&mut h);
    tokens.hash(&mut h);
    h.finish()
}

#[derive(Debug, Clone)]
struct PrefixEntry {
    page: usize,
    /// Positions covered from the window start: `(ordinal + 1) × page`.
    positions: usize,
    /// The registering row's full token window (shared, not copied per
    /// entry); `tokens[..positions]` is this entry's exact content key.
    tokens: Arc<Vec<i32>>,
    /// Last-touched tick for LRU eviction.
    tick: u64,
}

/// Content-addressed index of full KV pages by `(token prefix, row tag)`.
///
/// Every entry maps one **full, immutable** page: the page holding
/// positions `[i × page, (i + 1) × page)` of some row whose window began
/// with `tokens[..(i + 1) × page]` under tag `K` (K/V bytes are a pure
/// function of that pair — positions are cache-absolute — so any row with
/// the same tagged prefix can map the page verbatim). The index holds its
/// own reference to each page ([`KvPagePool::retain`]), which is what
/// keeps a retired session's prefix warm; [`Self::evict_lru`] hands pages
/// back under pressure.
///
/// Chains are looked up page by page and stop at the first miss, so
/// evicting an early page of a chain orphans the later ones — they stay
/// evictable and age out by the same LRU order.
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex<K> {
    entries: HashMap<(u64, K), PrefixEntry>,
    tick: u64,
}

impl<K: Eq + Hash + Copy> PrefixIndex<K> {
    /// An empty index.
    pub fn new() -> PrefixIndex<K> {
        PrefixIndex {
            entries: HashMap::new(),
            tick: 0,
        }
    }

    /// Registered entries (== pages the index retains).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest verified run of indexed full pages matching `tokens` under
    /// `tag`, capped at `max_pages`. Matched entries are LRU-touched. The
    /// caller maps the returned pages (adding its own references) and
    /// prefills only the remainder.
    pub fn lookup(
        &mut self,
        tag: K,
        tokens: &[i32],
        page_positions: usize,
        max_pages: usize,
    ) -> Vec<usize> {
        let mut pages = Vec::new();
        self.tick += 1;
        for i in 0..max_pages {
            let span = (i + 1) * page_positions;
            if span > tokens.len() {
                break;
            }
            let h = chain_hash(&tag, &tokens[..span]);
            match self.entries.get_mut(&(h, tag)) {
                Some(e)
                    if e.positions == span
                        && e.tokens.len() >= span
                        && e.tokens[..span] == tokens[..span] =>
                {
                    e.tick = self.tick;
                    pages.push(e.page);
                }
                _ => break,
            }
        }
        pages
    }

    /// Register a row's full pages under its tagged window. `pages` is the
    /// row's page table; every full-page ordinal (`(i + 1) × page ≤
    /// tokens.len()`) not already indexed is inserted and reported through
    /// `on_retain` so the caller can add the index's page reference.
    /// Already-indexed spans are deduplicated in favor of the existing
    /// entry (and LRU-touched). Returns how many entries were added.
    pub fn register(
        &mut self,
        tag: K,
        tokens: &Arc<Vec<i32>>,
        page_positions: usize,
        pages: &[usize],
        mut on_retain: impl FnMut(usize),
    ) -> usize {
        self.tick += 1;
        let full = (tokens.len() / page_positions).min(pages.len());
        let mut added = 0;
        for (i, &page) in pages.iter().enumerate().take(full) {
            let span = (i + 1) * page_positions;
            let h = chain_hash(&tag, &tokens[..span]);
            use std::collections::hash_map::Entry;
            match self.entries.entry((h, tag)) {
                Entry::Occupied(mut o) => {
                    o.get_mut().tick = self.tick;
                }
                Entry::Vacant(v) => {
                    v.insert(PrefixEntry {
                        page,
                        positions: span,
                        tokens: Arc::clone(tokens),
                        tick: self.tick,
                    });
                    on_retain(page);
                    added += 1;
                }
            }
        }
        added
    }

    /// Number of entries whose page passes `evictable` (typically
    /// "refcount == 1": the index is the only holder).
    pub fn evictable(&self, evictable: impl Fn(usize) -> bool) -> usize {
        self.entries.values().filter(|e| evictable(e.page)).count()
    }

    /// Drop the least-recently-used entry whose page passes `evictable`
    /// and return its page (the caller releases the index's reference).
    /// `None` when no entry qualifies.
    pub fn evict_lru(&mut self, evictable: impl Fn(usize) -> bool) -> Option<usize> {
        let key = self
            .entries
            .iter()
            .filter(|(_, e)| evictable(e.page))
            .min_by_key(|(_, e)| e.tick)
            .map(|(k, _)| *k)?;
        self.entries.remove(&key).map(|e| e.page)
    }

    /// Remove every entry, returning the retained pages for the caller to
    /// release.
    pub fn drain_pages(&mut self) -> Vec<usize> {
        self.entries.drain().map(|(_, e)| e.page).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_accounting_round_trips() {
        let mut pool = KvPagePool::new(3, 8);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.used_pages(), 0);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.alloc(), None, "pool exhausted");
        assert_eq!(pool.used_pages(), 3);
        pool.release(b);
        assert_eq!(pool.free_pages(), 1);
        // LIFO: the page just released is the next handed out.
        assert_eq!(pool.alloc(), Some(b));
        pool.release(a);
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.pool_bytes(), 3 * 2 * 8 * 4);
    }

    #[test]
    fn released_pages_are_zeroed() {
        // The quarantine fix: contents written by one occupant must never
        // be observable after the page returns to the pool.
        let mut pool = KvPagePool::new(2, 4);
        let p = pool.alloc().unwrap();
        pool.k_mut(p).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        pool.v_mut(p).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        pool.release(p);
        let q = pool.alloc().unwrap();
        assert_eq!(q, p, "LIFO hands the same page back");
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "stale K leaked");
        assert!(pool.v(q).iter().all(|&x| x == 0.0), "stale V leaked");
    }

    #[test]
    fn refcounts_zero_only_at_last_drop() {
        // Zero-on-release is keyed to the refcount drop, not the call
        // site: intermediate releases leave content for remaining holders.
        let mut pool = KvPagePool::new(2, 4);
        let p = pool.alloc().unwrap();
        assert_eq!(pool.ref_count(p), 1);
        pool.retain(p);
        pool.retain(p);
        assert_eq!(pool.ref_count(p), 3);
        pool.k_mut(p).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        pool.v_mut(p).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(pool.shared_bytes(), 2 * pool.page_bytes());

        pool.release(p);
        assert_eq!(pool.ref_count(p), 2);
        assert_eq!(pool.free_pages(), 1, "still held, not freed");
        assert_eq!(pool.k(p)[0], 1.0, "content intact for remaining holders");
        pool.release(p);
        assert_eq!(pool.k(p)[3], 4.0, "still intact at one holder");
        assert_eq!(pool.shared_bytes(), 0);

        pool.release(p);
        assert_eq!(pool.ref_count(p), 0);
        assert_eq!(pool.free_pages(), 2, "last drop frees");
        let q = pool.alloc().unwrap();
        assert_eq!(q, p);
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "stale K leaked");
        assert!(pool.v(q).iter().all(|&x| x == 0.0), "stale V leaked");
    }

    #[test]
    fn freed_then_reshared_page_never_leaks_prior_kv() {
        // Regression for the double-zero hazard audit: a page that cycles
        // occupant → shared → fully released → re-allocated must come back
        // zeroed, and the intermediate shared drops must not zero it early.
        let mut pool = KvPagePool::new(1, 4);
        let p = pool.alloc().unwrap();
        pool.k_mut(p).copy_from_slice(&[9.0; 4]);
        pool.retain(p); // second occupant shares it
        pool.release(p); // first occupant leaves — no zero, no free
        assert_eq!(pool.k(p), &[9.0; 4], "shared content survives a release");
        pool.release(p); // last occupant leaves — zero + free
        let q = pool.alloc().unwrap();
        assert_eq!(q, p);
        assert!(pool.k(q).iter().all(|&x| x == 0.0), "prior occupant leaked");
    }

    #[test]
    fn shrink_quarantines_free_pages_only() {
        let mut pool = KvPagePool::new(4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.shrink(10), 3, "only the free pages can go");
        assert_eq!(pool.quarantined_pages(), 3);
        assert_eq!(pool.total_pages(), 1);
        assert_eq!(pool.used_pages(), 1);
        assert_eq!(pool.free_pages(), 0);
        assert_eq!(pool.alloc(), None, "quarantined pages never come back");
        assert_eq!(pool.pool_bytes(), 2 * 2 * 4, "one page in service");
        // The mapped page still releases normally into the shrunken pool.
        pool.release(a);
        assert_eq!(pool.free_pages(), 1);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.alloc(), Some(a));
    }

    #[test]
    fn cfg_env_pin_and_builders() {
        let c = KvPageCfg::with_page(16)
            .budget(5)
            .share(true)
            .retain(7)
            .format(KvFormat::MxInt8);
        assert_eq!(c.page_positions, 16);
        assert_eq!(c.budget_pages, 5);
        assert!(c.prefix_share);
        assert_eq!(c.retain_pages, 7);
        assert_eq!(c.kv_format, KvFormat::MxInt8);
        assert_eq!(KvPageCfg::with_page(0).page_positions, 1, "clamped");
        assert!(!KvPageCfg::with_page(4).prefix_share, "sharing is opt-in");
        assert_eq!(
            KvPageCfg::with_page(4).kv_format,
            KvFormat::F32,
            "dense f32 is the default"
        );
    }

    #[test]
    fn kv_format_parse_names_and_bytes() {
        for f in [
            KvFormat::F32,
            KvFormat::MxInt8,
            KvFormat::MxFp8,
            KvFormat::MxInt4,
        ] {
            assert_eq!(KvFormat::parse(f.name()), Some(f), "name round-trips");
        }
        assert_eq!(KvFormat::parse("dense"), Some(KvFormat::F32));
        assert_eq!(KvFormat::parse("INT8"), Some(KvFormat::MxInt8));
        assert_eq!(KvFormat::parse("fp8"), Some(KvFormat::MxFp8));
        assert_eq!(KvFormat::parse("int4"), Some(KvFormat::MxInt4));
        assert_eq!(KvFormat::parse("mxfp4"), None);
        // Per-position bytes at d_model = 64 (K + V, one layer): dense
        // 512B; mxint8/mxfp8 64 codes + 2 scales per arena; mxint4 packs
        // two channels per byte.
        assert_eq!(KvFormat::F32.bytes_per_position(64), 512);
        assert_eq!(KvFormat::MxInt8.bytes_per_position(64), 2 * (64 + 2));
        assert_eq!(KvFormat::MxFp8.bytes_per_position(64), 2 * (64 + 2));
        assert_eq!(KvFormat::MxInt4.bytes_per_position(64), 2 * (32 + 2));
        // Remainder blocks still get a scale.
        assert_eq!(KvFormat::MxInt8.bytes_per_position(40), 2 * (40 + 2));
    }

    #[test]
    fn partial_fill_zero_on_release_spans_high_water() {
        // Zero-on-release memsets only the occupied span: write two of
        // four positions, release, and the whole page must still read as
        // zero afterwards (the unwritten tail was never dirtied).
        let layout = KvPageLayout {
            n_layers: 2,
            page_positions: 4,
            d_model: 8,
            format: KvFormat::F32,
        };
        let mut pool = KvPagePool::with_layout(1, layout);
        let p = pool.alloc().unwrap();
        assert_eq!(pool.page_high_water(p), 0);
        let row = [3.0f32; 8];
        pool.write_pos(p, 0, 0, &row, &row);
        pool.write_pos(p, 1, 1, &row, &row);
        assert_eq!(pool.page_high_water(p), 2, "high water tracks max position");
        pool.release(p);
        assert_eq!(pool.page_high_water(p), 0, "release resets the mark");
        let q = pool.alloc().unwrap();
        assert_eq!(q, p, "LIFO hands the same page back");
        let (mut k, mut v) = (vec![f32::NAN; 4 * 8], vec![f32::NAN; 4 * 8]);
        for l in 0..2 {
            pool.dequant_positions(q, l, 0, 4, &mut k, &mut v);
            assert!(
                k.iter().chain(v.iter()).all(|&x| x == 0.0),
                "stale KV leaked in layer {l}"
            );
        }
    }

    #[test]
    fn quantized_pages_round_trip_and_account_packed_bytes() {
        for (fmt, tol_frac) in [
            (KvFormat::MxInt8, 1.0 / 64.0),
            (KvFormat::MxFp8, 1.0 / 8.0),
            (KvFormat::MxInt4, 1.0 / 4.0),
        ] {
            let d = 40usize; // exercises the remainder scale block
            let layout = KvPageLayout {
                n_layers: 1,
                page_positions: 2,
                d_model: d,
                format: fmt,
            };
            let mut pool = KvPagePool::with_layout(2, layout);
            let elem = fmt.elem().unwrap();
            let cbr = packed_len(d, elem.bits());
            let sbr = d.div_ceil(KV_SCALE_BLOCK);
            assert_eq!(pool.page_bytes(), 2 * 2 * (cbr + sbr), "{fmt:?} packed bytes");
            assert_eq!(pool.dense_page_bytes(), 2 * 2 * d * 4);
            assert!(pool.page_bytes() < pool.dense_page_bytes() / 3, "{fmt:?} compresses");

            let p = pool.alloc().unwrap();
            let x: Vec<f32> = (0..d).map(|i| (i as f32 - 20.0) * 0.37).collect();
            pool.write_pos(p, 0, 1, &x, &x);
            let (mut k, mut v) = (vec![0.0f32; d], vec![0.0f32; d]);
            pool.dequant_positions(p, 0, 1, 1, &mut k, &mut v);
            assert_eq!(k, v, "K and V rows encode identically");
            let max_abs = x.iter().fold(0.0f32, |m, &a| m.max(a.abs()));
            let tol = max_abs * tol_frac as f32;
            for (i, (&got, &want)) in k.iter().zip(x.iter()).enumerate() {
                assert!(
                    (got - want).abs() <= tol,
                    "{fmt:?} channel {i}: {got} vs {want} (tol {tol})"
                );
            }
            // Zero-on-release covers the code + scale arenas too.
            pool.release(p);
            let q = pool.alloc().unwrap();
            assert_eq!(q, p);
            let (mut k, mut v) = (vec![f32::NAN; 2 * d], vec![f32::NAN; 2 * d]);
            pool.dequant_positions(q, 0, 0, 2, &mut k, &mut v);
            assert!(k.iter().chain(v.iter()).all(|&z| z == 0.0), "{fmt:?} leaked");
        }
    }

    #[test]
    fn copy_prefix_cow_preserves_co_holder_on_packed_pages() {
        // The COW primitive on a quantized pool: copy a one-position
        // prefix to a fresh page, diverge the copy, and the source's
        // content must be untouched for its co-holder.
        let layout = KvPageLayout {
            n_layers: 2,
            page_positions: 2,
            d_model: 32,
            format: KvFormat::MxInt8,
        };
        let mut pool = KvPagePool::with_layout(2, layout);
        let src = pool.alloc().unwrap();
        let a: Vec<f32> = (0..32).map(|i| i as f32 * 0.5 - 8.0).collect();
        let b: Vec<f32> = (0..32).map(|i| 16.0 - i as f32).collect();
        for l in 0..2 {
            pool.write_pos(src, l, 0, &a, &b);
            pool.write_pos(src, l, 1, &b, &a);
        }
        pool.retain(src); // co-holder
        let dst = pool.alloc().unwrap();
        pool.copy_prefix(src, dst, 1);
        assert_eq!(pool.page_high_water(dst), 1);

        let (mut ks, mut vs) = (vec![0.0f32; 32], vec![0.0f32; 32]);
        let (mut kd, mut vd) = (vec![0.0f32; 32], vec![0.0f32; 32]);
        for l in 0..2 {
            pool.dequant_positions(src, l, 0, 1, &mut ks, &mut vs);
            pool.dequant_positions(dst, l, 0, 1, &mut kd, &mut vd);
            assert_eq!(ks, kd, "layer {l}: copied K prefix is bit-identical");
            assert_eq!(vs, vd, "layer {l}: copied V prefix is bit-identical");
        }
        // Diverge the copy at position 1; the source co-holder's view of
        // position 1 must not move.
        pool.dequant_positions(src, 0, 1, 1, &mut ks, &mut vs);
        pool.write_pos(dst, 0, 1, &a, &a);
        let (mut ks2, mut vs2) = (vec![0.0f32; 32], vec![0.0f32; 32]);
        pool.dequant_positions(src, 0, 1, 1, &mut ks2, &mut vs2);
        assert_eq!(ks, ks2, "source K untouched by the diverged copy");
        assert_eq!(vs, vs2, "source V untouched by the diverged copy");
        pool.release(src);
        pool.dequant_positions(src, 0, 1, 1, &mut ks2, &mut vs2);
        assert_eq!(ks, ks2, "first release leaves content for the co-holder");
    }

    #[test]
    fn memory_snapshot_ratios() {
        let m = KvMemory {
            resident_bytes: 256,
            resident_peak_bytes: 512,
            dense_equivalent_bytes: 1024,
            pool_bytes: 512,
            used_pages: 2,
            free_pages: 6,
            total_pages: 8,
            page_positions: 4,
            ..Default::default()
        };
        assert!((m.utilization() - 0.25).abs() < 1e-12);
        assert!((m.resident_over_dense() - 0.25).abs() < 1e-12);
        assert_eq!(KvMemory::default().utilization(), 0.0);
        assert_eq!(KvMemory::default().resident_over_dense(), 0.0);
    }

    #[test]
    fn ledger_claims_release_and_share_drop() {
        let ledger = Arc::new(PageLedger::new(10));
        assert!(ledger.try_claim(6));
        assert!(!ledger.try_claim(5), "only 4 left");
        assert!(ledger.try_claim(4));
        assert_eq!(ledger.available(), 0);
        ledger.release(10);
        assert_eq!(ledger.claimed(), 0);

        // A share returns whatever it still holds when dropped (the
        // worker-panic path), and clones never inherit claims.
        let mut share = LedgerShare::new(Arc::clone(&ledger));
        assert!(share.try_claim(7));
        let clone = share.clone();
        assert_eq!(clone.claimed(), 0, "clones start unclaimed");
        share.release(2);
        assert_eq!(ledger.claimed(), 5);
        drop(share);
        assert_eq!(ledger.claimed(), 0, "drop returned the remainder");
        drop(clone);
        assert_eq!(ledger.claimed(), 0);
    }

    #[test]
    fn ledger_is_safe_across_threads() {
        let ledger = Arc::new(PageLedger::new(64));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let l = Arc::clone(&ledger);
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    for _ in 0..100 {
                        if l.try_claim(2) {
                            got += 2;
                            l.release(2);
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ledger.claimed(), 0, "every claim was returned");
        assert!(ledger.try_claim(64), "full capacity claimable after churn");
    }

    #[test]
    fn prefix_index_chains_verify_and_evict() {
        let mut idx: PrefixIndex<u8> = PrefixIndex::new();
        let pp = 4usize;
        let win: Arc<Vec<i32>> = Arc::new((0..10).collect());
        let mut retained = Vec::new();
        // 10 tokens at page 4 → two full pages (ordinals 0 and 1).
        let added = idx.register(7, &win, pp, &[100, 101, 102], |p| retained.push(p));
        assert_eq!(added, 2);
        assert_eq!(retained, vec![100, 101]);
        assert_eq!(idx.len(), 2);
        // Re-registering the same content dedupes in favor of the
        // existing entries.
        assert_eq!(idx.register(7, &win, pp, &[200, 201], |_| panic!()), 0);

        // Full-chain hit, capped hit, tag miss, content miss.
        let toks: Vec<i32> = (0..9).collect();
        assert_eq!(idx.lookup(7, &toks, pp, 8), vec![100, 101]);
        assert_eq!(idx.lookup(7, &toks, pp, 1), vec![100]);
        assert!(idx.lookup(8, &toks, pp, 8).is_empty(), "tag keys content");
        let mut diverged = toks.clone();
        diverged[2] = 99;
        assert!(idx.lookup(7, &diverged, pp, 8).is_empty());
        let mut late = toks.clone();
        late[6] = 99; // second page diverges; first still matches
        assert_eq!(idx.lookup(7, &late, pp, 8), vec![100]);

        // LRU eviction respects the evictability predicate and order:
        // page 101 was touched by the chain lookups after 100? Both were
        // touched together; re-touch 100 alone via a capped lookup, then
        // evict — 101 is the LRU entry.
        assert_eq!(idx.lookup(7, &toks, pp, 1), vec![100]);
        assert_eq!(idx.evict_lru(|p| p != 101), Some(100), "predicate gates");
        assert_eq!(idx.evict_lru(|_| true), Some(101));
        assert!(idx.evict_lru(|_| true).is_none());
        assert!(idx.is_empty());

        // drain_pages returns everything for release.
        idx.register(7, &win, pp, &[100, 101], |_| {});
        let mut drained = idx.drain_pages();
        drained.sort_unstable();
        assert_eq!(drained, vec![100, 101]);
        assert!(idx.is_empty());
    }
}
