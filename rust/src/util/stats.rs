//! Streaming statistics and fixed-bucket latency histograms.
//!
//! Used by the serving metrics ([`crate::server::metrics`]), the experiment
//! reports, and the bench harness.

/// Online mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Running {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Push one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Log-scaled latency histogram from 1µs to ~100s, plus exact quantiles over a
/// bounded reservoir.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    reservoir: Vec<f64>,
    cap: usize,
    seen: u64,
    rng_state: u64,
}

const BUCKETS_PER_DECADE: usize = 10;
const DECADES: usize = 8; // 1e-6 .. 1e2 seconds

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// Empty histogram.
    pub fn new() -> LatencyHist {
        LatencyHist {
            buckets: vec![0; BUCKETS_PER_DECADE * DECADES + 2],
            reservoir: Vec::new(),
            cap: 4096,
            seen: 0,
            rng_state: 0x1234_5678_9abc_def0,
        }
    }

    fn bucket_index(secs: f64) -> usize {
        if secs <= 1e-6 {
            return 0;
        }
        let log = (secs / 1e-6).log10(); // decades above 1µs
        let idx = 1 + (log * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(BUCKETS_PER_DECADE * DECADES + 1)
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_index(secs)] += 1;
        self.seen += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(secs);
        } else {
            // Reservoir sampling (xorshift64*).
            self.rng_state ^= self.rng_state >> 12;
            self.rng_state ^= self.rng_state << 25;
            self.rng_state ^= self.rng_state >> 27;
            let r = (self.rng_state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 32) as u64;
            let j = (r % self.seen) as usize;
            if j < self.cap {
                self.reservoir[j] = secs;
            }
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.seen
    }

    /// Quantile over the reservoir (exact for <= cap samples).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut v = self.reservoir.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx]
    }

    /// One-line `n`/`p50`/`p95`/`p99` summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} p50={} p95={} p99={}",
            self.seen,
            super::timer::fmt_time(self.quantile(0.5)),
            super::timer::fmt_time(self.quantile(0.95)),
            super::timer::fmt_time(self.quantile(0.99)),
        )
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn hist_quantiles_ordered() {
        let mut h = LatencyHist::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        // p50 should be near 5ms.
        assert!((h.quantile(0.5) - 5e-3).abs() < 1e-3);
    }

    #[test]
    fn hist_reservoir_overflow_is_safe() {
        let mut h = LatencyHist::new();
        for i in 0..10_000 {
            h.record((i % 100) as f64 * 1e-4);
        }
        assert_eq!(h.count(), 10_000);
        assert!(h.quantile(0.99) <= 1e-2 + 1e-9);
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1.0, -3.0], &[2.0, 1.0]), 4.0);
    }
}
