//! Observability: lock-free metrics and request-lifecycle tracing.
//!
//! Two building blocks, both designed so that a fully *disabled*
//! configuration stays within noise of the untouched hot path and never
//! perturbs decode numerics:
//!
//! * [`registry`] — atomic [`Counter`]s/[`Gauge`]s, CAS-accumulated
//!   [`AtomicRunning`] stats and sharded bucketed [`Hist`]ograms behind a
//!   named [`Registry`]. These replace the server's former once-per-batch
//!   metrics mutex: workers cache `Arc` handles and update with plain
//!   atomics. The registry renders a JSON snapshot and a Prometheus text
//!   exposition.
//! * [`trace`] — a [`TraceSink`] collecting per-request lifecycle spans
//!   (enqueue → admit/defer → prefill → per-step decode → complete)
//!   through the continuous-batching state machine, exported as
//!   Perfetto-loadable Chrome trace-event JSON with one track per worker
//!   and one lane per decode row.
//!
//! The serving glue — which counters exist, how spans map onto
//! [`crate::server`]'s worker loops, snapshotting back into
//! [`crate::server::Metrics`] — lives in [`crate::server::metrics`]; this
//! module is the reusable substrate.

pub mod registry;
pub mod trace;

pub use registry::{AtomicRunning, Counter, Gauge, Hist, Metric, Registry};
pub use trace::{TraceEvent, TraceSink};
