//! Elastic precision policies: queue depth → serving format.
//!
//! The paper's motivation: "the same device might want to serve at
//! different precisions for different batches based on the current load of
//! the system". The ladder policy drops precision as the backlog grows
//! (lower bits ⇒ cheaper dequant + smaller working set ⇒ higher throughput
//! on MX-native hardware); SLO mode is a latency-target wrapper around it.

use crate::formats::ElementFormat;

/// Precision-selection policy.
#[derive(Debug, Clone)]
pub enum Policy {
    /// Always serve at one format.
    Fixed(ElementFormat),
    /// Depth thresholds, ascending: the first entry whose depth bound is
    /// `>= queue_depth` wins; beyond the last bound, its format is used.
    Ladder(Vec<(usize, ElementFormat)>),
    /// Latency-SLO mode: walk a precision ladder adaptively — degrade when
    /// the EWMA batch latency exceeds `target_s`, recover when it falls
    /// below `target_s * low_water`. State lives in [`SloState`], owned by
    /// the server worker.
    Slo {
        rungs: Vec<ElementFormat>,
        target_s: f64,
        low_water: f64,
    },
}

/// Mutable state for [`Policy::Slo`] (EWMA latency + current rung).
#[derive(Debug, Clone)]
pub struct SloState {
    /// Current ladder rung (0 = highest precision).
    pub rung: usize,
    /// EWMA of observed batch latency, in seconds.
    pub ewma_s: f64,
}

impl Default for SloState {
    fn default() -> Self {
        SloState { rung: 0, ewma_s: 0.0 }
    }
}

impl SloState {
    /// Feed one observed batch latency; moves the rung if needed.
    pub fn observe(&mut self, policy: &Policy, batch_latency_s: f64) {
        if let Policy::Slo { rungs, target_s, low_water } = policy {
            const ALPHA: f64 = 0.3;
            self.ewma_s = if self.ewma_s == 0.0 {
                batch_latency_s
            } else {
                ALPHA * batch_latency_s + (1.0 - ALPHA) * self.ewma_s
            };
            if self.ewma_s > *target_s && self.rung + 1 < rungs.len() {
                self.rung += 1;
                log::info!("SLO: degrade to {} (ewma {:.2}ms)", rungs[self.rung], self.ewma_s * 1e3);
            } else if self.ewma_s < *target_s * *low_water && self.rung > 0 {
                self.rung -= 1;
                log::info!("SLO: recover to {} (ewma {:.2}ms)", rungs[self.rung], self.ewma_s * 1e3);
            }
        }
    }
}

/// Which load-shedding tier an admission decision landed in.
///
/// The server degrades in a fixed order before giving up on a request:
/// serve at a cheaper format than light load would pick
/// ([`ShedTier::Downshift`]), hold the request in the backlog until a row
/// and its KV pages free up ([`ShedTier::Defer`]), and only turn traffic
/// away once the bounded ingress queue is full ([`ShedTier::Reject`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedTier {
    /// Admitted at the baseline (zero-depth) format — no shedding.
    Admit,
    /// Admitted, but at a cheaper format than the baseline.
    Downshift,
    /// Held in the backlog: no free decode row or KV pages right now.
    Defer,
    /// Rejected at the queue boundary with a retry-after hint.
    Reject,
}

impl ShedTier {
    /// Stable lower-case name for logs and metrics labels.
    pub fn name(self) -> &'static str {
        match self {
            ShedTier::Admit => "admit",
            ShedTier::Downshift => "downshift",
            ShedTier::Defer => "defer",
            ShedTier::Reject => "reject",
        }
    }

    /// Classify an admission: the tier a request landed in given the
    /// format the policy chose against the baseline (zero-depth) format.
    pub fn classify(baseline: ElementFormat, chosen: ElementFormat) -> ShedTier {
        if chosen == baseline {
            ShedTier::Admit
        } else {
            ShedTier::Downshift
        }
    }
}

impl Policy {
    /// The default MXINT ladder: light load serves the anchor precision,
    /// heavy load degrades gracefully (8 → 6 → 4 bits).
    pub fn default_ladder() -> Policy {
        Policy::Ladder(vec![
            (8, ElementFormat::int(8)),
            (24, ElementFormat::int(6)),
            (usize::MAX, ElementFormat::int(4)),
        ])
    }

    /// An MXFP ladder (anchor MXFP8).
    pub fn fp_ladder() -> Policy {
        Policy::Ladder(vec![
            (8, ElementFormat::fp_from_bits(8)),
            (24, ElementFormat::fp_from_bits(6)),
            (usize::MAX, ElementFormat::fp_from_bits(4)),
        ])
    }

    /// An SLO policy over the MXINT ladder.
    pub fn slo(target: std::time::Duration) -> Policy {
        Policy::Slo {
            rungs: vec![
                ElementFormat::int(8),
                ElementFormat::int(6),
                ElementFormat::int(4),
            ],
            target_s: target.as_secs_f64(),
            low_water: 0.5,
        }
    }

    /// Choose the serving format for the current queue depth + SLO state.
    pub fn choose_with(&self, queue_depth: usize, slo: &SloState) -> ElementFormat {
        match self {
            Policy::Fixed(f) => *f,
            Policy::Ladder(steps) => {
                for &(bound, fmt) in steps {
                    if queue_depth <= bound {
                        return fmt;
                    }
                }
                steps.last().expect("non-empty ladder").1
            }
            Policy::Slo { rungs, .. } => rungs[slo.rung.min(rungs.len() - 1)],
        }
    }

    /// Choose ignoring SLO state (ladder/fixed policies).
    pub fn choose(&self, queue_depth: usize) -> ElementFormat {
        self.choose_with(queue_depth, &SloState::default())
    }

    /// Parse `fixed:<fmt>`, `ladder` / `ladder-fp`, or `slo:<millis>`.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        if let Some(f) = s.strip_prefix("fixed:") {
            return Ok(Policy::Fixed(ElementFormat::parse(f)?));
        }
        if let Some(ms) = s.strip_prefix("slo:") {
            let ms: f64 = ms.parse().map_err(|_| anyhow::anyhow!("bad slo millis '{ms}'"))?;
            return Ok(Policy::slo(std::time::Duration::from_secs_f64(ms / 1e3)));
        }
        match s {
            "ladder" | "ladder-int" => Ok(Policy::default_ladder()),
            "ladder-fp" => Ok(Policy::fp_ladder()),
            _ => anyhow::bail!(
                "unknown policy '{s}' (fixed:<fmt> | ladder | ladder-fp | slo:<ms>)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_degrades_with_load() {
        let p = Policy::default_ladder();
        assert_eq!(p.choose(0), ElementFormat::int(8));
        assert_eq!(p.choose(8), ElementFormat::int(8));
        assert_eq!(p.choose(9), ElementFormat::int(6));
        assert_eq!(p.choose(24), ElementFormat::int(6));
        assert_eq!(p.choose(25), ElementFormat::int(4));
        assert_eq!(p.choose(10_000), ElementFormat::int(4));
    }

    #[test]
    fn fixed_ignores_load() {
        let p = Policy::Fixed(ElementFormat::int(5));
        assert_eq!(p.choose(0), ElementFormat::int(5));
        assert_eq!(p.choose(1000), ElementFormat::int(5));
    }

    #[test]
    fn parse_forms() {
        assert!(matches!(Policy::parse("ladder").unwrap(), Policy::Ladder(_)));
        assert!(matches!(Policy::parse("ladder-fp").unwrap(), Policy::Ladder(_)));
        match Policy::parse("fixed:int4").unwrap() {
            Policy::Fixed(f) => assert_eq!(f, ElementFormat::int(4)),
            _ => panic!(),
        }
        assert!(matches!(Policy::parse("slo:20").unwrap(), Policy::Slo { .. }));
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("slo:abc").is_err());
    }

    #[test]
    fn shed_tier_names_and_classification() {
        assert_eq!(ShedTier::Admit.name(), "admit");
        assert_eq!(ShedTier::Downshift.name(), "downshift");
        assert_eq!(ShedTier::Defer.name(), "defer");
        assert_eq!(ShedTier::Reject.name(), "reject");
        let p = Policy::default_ladder();
        let base = p.choose(0);
        assert_eq!(ShedTier::classify(base, p.choose(0)), ShedTier::Admit);
        assert_eq!(ShedTier::classify(base, p.choose(100)), ShedTier::Downshift);
    }

    #[test]
    fn slo_degrades_and_recovers() {
        let p = Policy::slo(std::time::Duration::from_millis(10));
        let mut st = SloState::default();
        assert_eq!(p.choose_with(0, &st), ElementFormat::int(8));
        // Sustained slow batches → degrade one rung at a time.
        for _ in 0..8 {
            st.observe(&p, 0.050);
        }
        assert_eq!(p.choose_with(0, &st), ElementFormat::int(4), "bottom rung");
        // Sustained fast batches → recover.
        for _ in 0..40 {
            st.observe(&p, 0.001);
        }
        assert_eq!(p.choose_with(0, &st), ElementFormat::int(8));
    }
}
