//! End-to-end serving benchmarks over the **native** backend — the elastic
//! trade-off the paper motivates (§1) measured where it now lives: a
//! multi-worker server pool sharing one packed-weight engine.
//!
//! Sections (all artifact-free; no XLA):
//!   score/<fmt>/workersN    closed-loop scoring throughput + latency
//!                           (p50/p99) by worker count and format — the
//!                           worker-pool scaling story
//!   generate/<fmt>/workersN generation tokens/sec by worker count through
//!                           the continuous-batching lane
//!   continuous_batching/*   open-loop Poisson arrivals of MIXED-format
//!                           generation requests served by (a) legacy
//!                           gather batching — which serializes formats
//!                           into per-group convoys — and (b) continuous
//!                           batching with per-row formats and
//!                           prefill-on-join; p50/p99 request latency and
//!                           tokens/sec per mode, plus the headline
//!                           p50 speedup of continuous over gather
//!   batched_decode/rowsN    raw `generate_native_batch` tokens/sec by
//!                           batch width (no server) — the KV-batching win
//!   kv_quant/<fmt>          quantized KV pages under a fixed 128 KiB page
//!                           budget: rows admitted, peak resident bytes
//!                           and next-token NLL per KV storage format
//!                           (f32 / mxint8 / mxfp8 / mxint4), plus each
//!                           packed format's admit/peak ratios and NLL
//!                           delta vs the f32 arenas
//!   kv_memory/*             paged-KV residency under the Poisson
//!                           mixed-format load: peak resident bytes vs the
//!                           dense-equivalent `slots × seq_len` allocation
//!                           (8-position pages so residency tracks the
//!                           short mixed contexts), plus pool utilization
//!   prefix_sharing/*        multi-turn conversational trace (each turn
//!                           re-sends its conversation's head plus a new
//!                           tail) served with KV prefix sharing off vs
//!                           on: prefill tokens saved, prefix hits,
//!                           retained/shared pages, and TTFT p50 per
//!                           mode, plus the shared-vs-unshared TTFT ratio
//!   ttft / inter_token      per-format time-to-first-token and inter-token
//!                           gap percentiles from the continuous mixed run
//!                           (the lock-free span histograms)
//!   observability/*         lifecycle-tracing overhead: the same closed-
//!                           loop mixed-format load with the trace sink off
//!                           vs on, min-of-3 walls each
//!   degradation/*           graceful degradation under overload: open-loop
//!                           Poisson generation arrivals at 1×/2×/4× the
//!                           pool's measured closed-loop service rate, with
//!                           the shed ladder enabled (bounded ingress
//!                           queue) vs disabled — p99 latency of served
//!                           requests plus rejection / downshift / deferral
//!                           counts per overload point
//!
//! Writes a machine-readable summary to `BENCH_serving.json` (CI archives
//! it; the acceptance numbers — tokens/sec scaling with worker count,
//! continuous-vs-gather queue-latency reduction, batched-decode speedup
//! over rows=1, paged-KV peak residency ≤ the dense-equivalent bytes,
//! per-format TTFT/inter-token percentiles, `tracing_overhead_pct` ≤ 3,
//! `prefix_sharing.shared.prefill_tokens_saved` > 0 on the conversational
//! trace, `kv_quant.mxint8_vs_f32.admit_ratio_vs_f32` ≥ 3 with a finite
//! NLL delta — live there).
//!
//! Inner GEMM threading is pinned to 1 unless `MFQAT_THREADS` is set, so
//! worker-pool scaling is not confounded by kernel-level parallelism.

use mfqat::backend::forward::{forward_cached, KvCache, RowTag};
use mfqat::backend::{KvFormat, KvPageCfg, NativeWeights};
use mfqat::coordinator::ElasticEngine;
use mfqat::eval::generate::{generate_native_batch, SampleCfg};
use mfqat::formats::ElementFormat;
use mfqat::model::{ModelDims, ParamSet};
use mfqat::server::{GenBatching, Policy, Server, ServerConfig};
use mfqat::util::json::Json;
use mfqat::util::Rng;
use std::time::{Duration, Instant};

/// Small serving model: large enough that a batch costs real work, small
/// enough that the whole worker×format matrix runs in CI.
fn bench_dims() -> ModelDims {
    let mut dims = ModelDims::new("srvbench", 256, 64, 2, 2, 32);
    dims.train_batch = 4;
    dims
}

fn quantiles(lats: &mut [f64]) -> (f64, f64) {
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = lats.len();
    let p50 = lats[n / 2];
    let p99 = lats[((n as f64 * 0.99) as usize).min(n - 1)];
    (p50, p99)
}

/// Closed-loop load harness shared by the score and generate sections:
/// `threads` client threads each issue `per_thread` blocking requests via
/// `work` (which returns the server-reported latency), so concurrency ==
/// `threads`. Returns `(wall_s, p50_s, p99_s)`.
fn closed_loop<W>(
    client: &mfqat::server::Client,
    threads: usize,
    per_thread: usize,
    work: W,
) -> (f64, f64, f64)
where
    W: Fn(&mfqat::server::Client, usize, usize) -> Duration + Sync,
{
    let t0 = Instant::now();
    let lats = std::sync::Mutex::new(Vec::<f64>::new());
    std::thread::scope(|s| {
        for t in 0..threads {
            let client = client.clone();
            let lats = &lats;
            let work = &work;
            s.spawn(move || {
                for i in 0..per_thread {
                    let latency = work(&client, t, i);
                    lats.lock().unwrap().push(latency.as_secs_f64());
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut lats = lats.into_inner().unwrap();
    let (p50, p99) = quantiles(&mut lats);
    (wall, p50, p99)
}

fn start_pool_traced(
    workers: usize,
    batching: GenBatching,
    decode_slots: usize,
    kv_page: KvPageCfg,
    trace: bool,
) -> (Server, mfqat::server::Client, usize) {
    let dims = bench_dims();
    let width = dims.seq_len + 1;
    let (server, client) = Server::start(
        width,
        move || {
            let manifest = dims.to_manifest();
            let params = ParamSet::init(&manifest, 5);
            let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
            ElasticEngine::native(dims, ck, 256 << 20)
        },
        ServerConfig {
            policy: Policy::Fixed(ElementFormat::int(8)),
            gather_window: Duration::from_millis(1),
            workers,
            batching,
            decode_slots,
            kv_page,
            trace,
            ..Default::default()
        },
    )
    .unwrap();
    (server, client, width)
}

fn start_pool_kv(
    workers: usize,
    batching: GenBatching,
    decode_slots: usize,
    kv_page: KvPageCfg,
) -> (Server, mfqat::server::Client, usize) {
    start_pool_traced(workers, batching, decode_slots, kv_page, false)
}

fn start_pool_mode(
    workers: usize,
    batching: GenBatching,
) -> (Server, mfqat::server::Client, usize) {
    start_pool_kv(workers, batching, 0, KvPageCfg::from_env())
}

fn start_pool(workers: usize) -> (Server, mfqat::server::Client, usize) {
    start_pool_mode(workers, GenBatching::Continuous)
}

fn main() {
    // Pin kernel threading so worker-count scaling measures the pool, not
    // the GEMM fan-out (override by setting MFQAT_THREADS explicitly).
    if std::env::var("MFQAT_THREADS").is_err() {
        std::env::set_var("MFQAT_THREADS", "1");
    }
    let dims = bench_dims();
    let width = dims.seq_len + 1;
    let mut summary = Json::obj();
    summary.set("simd_level", Json::from(mfqat::backend::simd::level().name()));

    // Deterministic request rows.
    let rows: Vec<Vec<i32>> = (0..64u64)
        .map(|r| {
            (0..width)
                .map(|i| (((r * 31 + i as u64 * 13 + 7) % 256) as i32))
                .collect()
        })
        .collect();

    // ------------------------------------------- score scaling by workers
    let client_threads = 4usize;
    let per_thread = 24usize;
    let formats = [ElementFormat::int(8), ElementFormat::int(4)];
    let mut score_json = Json::obj();
    for fmt in formats {
        let mut fmt_json = Json::obj();
        let mut rps_by_workers: Vec<(usize, f64)> = Vec::new();
        for workers in [1usize, 2, 4] {
            let (server, client, _) = start_pool(workers);
            // Warm the format cache outside the measurement.
            client.score(&rows[0], Some(fmt)).unwrap();
            let (wall, p50, p99) = closed_loop(&client, client_threads, per_thread, |c, t, i| {
                c.score(&rows[(t * per_thread + i) % rows.len()], Some(fmt))
                    .unwrap()
                    .latency
            });
            let reqs = (client_threads * per_thread) as f64;
            let rps = reqs / wall;
            println!(
                "score/{}/workers{workers}: {reqs:.0} reqs in {wall:.2}s  \
                 {rps:.1} req/s  p50 {:.2}ms  p99 {:.2}ms",
                fmt.name(),
                p50 * 1e3,
                p99 * 1e3
            );
            let mut e = Json::obj();
            e.set("req_per_s", Json::from(rps));
            e.set("p50_ms", Json::from(p50 * 1e3));
            e.set("p99_ms", Json::from(p99 * 1e3));
            fmt_json.set(&format!("workers{workers}"), e);
            rps_by_workers.push((workers, rps));
            drop(client);
            server.shutdown();
        }
        if let (Some((_, r1)), Some((_, r4))) = (
            rps_by_workers.iter().find(|(w, _)| *w == 1),
            rps_by_workers.iter().find(|(w, _)| *w == 4),
        ) {
            fmt_json.set("scaling_4v1", Json::from(r4 / r1));
        }
        score_json.set(&fmt.name(), fmt_json);
    }
    summary.set("score", score_json);

    // --------------------------------------- generate scaling by workers
    let gen_threads = 4usize;
    let gen_per_thread = 3usize;
    let gen_tokens = 16usize;
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 8,
        seed: 11,
    };
    let prompts = ["the color of kova is", "kovaq", "blue sky", "q"];
    let mut gen_json = Json::obj();
    for fmt in formats {
        let mut fmt_json = Json::obj();
        let mut tps_by_workers: Vec<(usize, f64)> = Vec::new();
        for workers in [1usize, 2, 4] {
            let (server, client, _) = start_pool(workers);
            client.score(&rows[0], Some(fmt)).unwrap(); // warm cache
            let (wall, p50, p99) =
                closed_loop(&client, gen_threads, gen_per_thread, |c, t, i| {
                    c.generate(
                        prompts[(t + i) % prompts.len()],
                        gen_tokens,
                        Some(fmt),
                        cfg.clone(),
                    )
                    .unwrap()
                    .latency
                });
            let toks = (gen_threads * gen_per_thread * gen_tokens) as f64;
            let tps = toks / wall;
            println!(
                "generate/{}/workers{workers}: {toks:.0} tok in {wall:.2}s  \
                 {tps:.1} tok/s  p50 {:.1}ms  p99 {:.1}ms",
                fmt.name(),
                p50 * 1e3,
                p99 * 1e3
            );
            let mut e = Json::obj();
            e.set("tok_per_s", Json::from(tps));
            e.set("p50_ms", Json::from(p50 * 1e3));
            e.set("p99_ms", Json::from(p99 * 1e3));
            fmt_json.set(&format!("workers{workers}"), e);
            tps_by_workers.push((workers, tps));
            drop(client);
            server.shutdown();
        }
        if let (Some((_, t1)), Some((_, t4))) = (
            tps_by_workers.iter().find(|(w, _)| *w == 1),
            tps_by_workers.iter().find(|(w, _)| *w == 4),
        ) {
            fmt_json.set("scaling_4v1", Json::from(t4 / t1));
        }
        gen_json.set(&fmt.name(), fmt_json);
    }
    summary.set("generate", gen_json);

    // ------------- continuous vs gather batching under Poisson mixed load
    //
    // Open-loop arrivals (exponential inter-arrival gaps, deterministic
    // RNG) of generation requests pinned round-robin across THREE formats.
    // Gather batching can only group equal-format requests, so mixed
    // traffic serializes into per-format convoys and queue latency grows;
    // continuous batching admits every prompt into the in-flight decode at
    // the next step, whatever format its neighbours run.
    let mix = [
        ElementFormat::int(8),
        ElementFormat::int(6),
        ElementFormat::int(4),
    ];
    let cb_requests = 24usize;
    let cb_tokens = 16usize;
    let mean_gap_ms = 3.0f64;
    let mut cb_json = Json::obj();
    let mut cb_p50: Vec<(&'static str, f64)> = Vec::new();
    for batching in [GenBatching::Gather, GenBatching::Continuous] {
        // Small KV pages (8 positions) so paged residency tracks the short
        // mixed contexts instead of rounding every row up to the window,
        // and 8 decode slots per worker — a burst-capable, mostly-idle
        // pool, the allocation dense KV pays for in full while paging pays
        // per live page (the kv_memory section reads the accounting).
        let (server, client, _) = start_pool_kv(2, batching, 8, KvPageCfg::with_page(8));
        // Warm every format in the mix outside the measurement.
        for fmt in mix {
            client.score(&rows[0], Some(fmt)).unwrap();
        }
        let mut rng = Rng::new(0xC0FFEE);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(cb_requests);
        for i in 0..cb_requests {
            rxs.push(
                client
                    .submit_generate(
                        prompts[i % prompts.len()],
                        cb_tokens,
                        Some(mix[i % mix.len()]),
                        cfg.clone(),
                    )
                    .unwrap(),
            );
            let gap_ms = -(rng.f64().max(1e-9)).ln() * mean_gap_ms;
            std::thread::sleep(Duration::from_secs_f64(gap_ms.min(20.0) / 1e3));
        }
        let mut lats: Vec<f64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().latency.as_secs_f64())
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let tps = (cb_requests * cb_tokens) as f64 / wall;
        let (p50, p99) = quantiles(&mut lats);
        println!(
            "continuous_batching/{}: {} mixed-format reqs  {tps:.1} tok/s  \
             p50 {:.1}ms  p99 {:.1}ms",
            batching.name(),
            cb_requests,
            p50 * 1e3,
            p99 * 1e3
        );
        let mut e = Json::obj();
        e.set("tok_per_s", Json::from(tps));
        e.set("p50_ms", Json::from(p50 * 1e3));
        e.set("p99_ms", Json::from(p99 * 1e3));
        cb_json.set(batching.name(), e);
        cb_p50.push((batching.name(), p50));
        // Paged-KV accounting under the mixed Poisson load (continuous
        // mode only — gather decodes have no persistent session): peak
        // resident bytes vs the dense-equivalent allocation every
        // pre-paging decode session preallocated up front.
        if batching == GenBatching::Continuous {
            let m = server.metrics();
            // Per-format lifecycle spans from the lock-free histograms:
            // time-to-first-token (enqueue → first sampled token, so queue
            // wait is included) and inter-token gap, p50/p99 per element
            // format in the mix.
            let mut ttft_json = Json::obj();
            for (f, h) in m.ttft.iter() {
                let mut e = Json::obj();
                e.set("p50_ms", Json::from(h.quantile(0.5) * 1e3));
                e.set("p99_ms", Json::from(h.quantile(0.99) * 1e3));
                e.set("n", Json::from(h.count()));
                println!(
                    "ttft/{f}: p50 {:.1}ms  p99 {:.1}ms  (n={})",
                    h.quantile(0.5) * 1e3,
                    h.quantile(0.99) * 1e3,
                    h.count()
                );
                ttft_json.set(f, e);
            }
            summary.set("ttft", ttft_json);
            let mut it_json = Json::obj();
            for (f, h) in m.inter_token.iter() {
                let mut e = Json::obj();
                e.set("p50_ms", Json::from(h.quantile(0.5) * 1e3));
                e.set("p99_ms", Json::from(h.quantile(0.99) * 1e3));
                e.set("n", Json::from(h.count()));
                println!(
                    "inter_token/{f}: p50 {:.2}ms  p99 {:.2}ms  (n={})",
                    h.quantile(0.5) * 1e3,
                    h.quantile(0.99) * 1e3,
                    h.count()
                );
                it_json.set(f, e);
            }
            summary.set("inter_token", it_json);
            let mut q = Json::obj();
            q.set("p50_ms", Json::from(m.queue_wait.quantile(0.5) * 1e3));
            q.set("p99_ms", Json::from(m.queue_wait.quantile(0.99) * 1e3));
            q.set("n", Json::from(m.queue_wait.count()));
            q.set("deferrals", Json::from(m.deferrals));
            summary.set("queue_wait", q);
            let kv = m.kv;
            let mut k = Json::obj();
            k.set("page_positions", Json::from(kv.page_positions));
            k.set("dense_equivalent_bytes", Json::from(kv.dense_equivalent_bytes));
            k.set("pool_bytes", Json::from(kv.pool_bytes));
            k.set("resident_peak_bytes", Json::from(m.kv_resident_peak_bytes));
            let over_dense = if kv.dense_equivalent_bytes > 0 {
                m.kv_resident_peak_bytes as f64 / kv.dense_equivalent_bytes as f64
            } else {
                0.0
            };
            // < 1.0 ⇒ paging kept peak KV residency under what the dense
            // layout preallocates for the same session (≤ 0.5 is the
            // acceptance target under this short-context mixed load).
            k.set("resident_peak_over_dense", Json::from(over_dense));
            k.set("pool_utilization_last", Json::from(kv.utilization()));
            println!(
                "kv_memory: page {} pos  peak resident {} B  dense-equivalent {} B  \
                 ratio {:.3}",
                kv.page_positions, m.kv_resident_peak_bytes, kv.dense_equivalent_bytes, over_dense
            );
            summary.set("kv_memory", k);
        }
        drop(client);
        server.shutdown();
    }
    if let (Some((_, gather_p50)), Some((_, cont_p50))) = (
        cb_p50.iter().find(|(m, _)| *m == "gather"),
        cb_p50.iter().find(|(m, _)| *m == "continuous"),
    ) {
        // > 1.0 ⇒ continuous batching cut the p50 request latency under
        // sustained mixed-format generation load.
        cb_json.set(
            "p50_speedup_continuous_vs_gather",
            Json::from(gather_p50 / cont_p50),
        );
    }
    summary.set("continuous_batching", cb_json);

    // ------------------------- prefix sharing: multi-turn conversation trace
    //
    // Four conversations, four turns each; every turn re-sends its
    // conversation's 16-char head plus a short new tail — the serving
    // shape prefix sharing exists for. The same trace runs with sharing
    // off and on (one worker, so every turn after a conversation's first
    // can hit that worker's index): prefill positions skipped, prefix
    // hits, retained pages, and TTFT p50 (enqueue → first token, so the
    // skipped prefill shows up here) per mode, plus the headline shared
    // vs unshared TTFT ratio.
    let conv_heads = [
        "the color of kova is",
        "deep in the blue sky",
        "kovaq speaks the old",
        "a quiet machine hums",
    ];
    let turn_tails = ["", " now", " here", " again"];
    let mut px_json = Json::obj();
    let mut px_ttft: Vec<(bool, f64)> = Vec::new();
    for share in [false, true] {
        let kv = if share {
            KvPageCfg::with_page(8).share(true)
        } else {
            KvPageCfg::with_page(8)
        };
        let (server, client, _) = start_pool_kv(1, GenBatching::Continuous, 4, kv);
        client.score(&rows[0], Some(ElementFormat::int(8))).unwrap(); // warm cache
        let t0 = Instant::now();
        for head in conv_heads {
            // Clip every conversation head to exactly 16 chars (2 full
            // 8-position pages) so each follow-up turn shares its head
            // pages whatever tail it appends, and the longest turn
            // (16 + 6-char tail + 6 decoded) stays inside seq_len.
            let head16: String = head.chars().take(16).collect();
            for tail in turn_tails {
                let prompt = format!("{head16}{tail}");
                client.generate(&prompt, 6, None, cfg.clone()).unwrap();
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.metrics();
        let mut ttft_p50 = 0.0f64;
        for (_, h) in m.ttft.iter() {
            ttft_p50 = h.quantile(0.5); // one fixed format in this trace
        }
        let mode = if share { "shared" } else { "unshared" };
        println!(
            "prefix_sharing/{mode}: {} turns in {wall:.2}s  ttft p50 {:.1}ms  \
             hits {}  prefill saved {} tok  shared {} B  retained {} pages",
            conv_heads.len() * turn_tails.len(),
            ttft_p50 * 1e3,
            m.kv.prefix_hits,
            m.kv.prefill_tokens_saved,
            m.kv.shared_bytes,
            m.kv.retained_pages
        );
        let mut e = Json::obj();
        e.set("wall_s", Json::from(wall));
        e.set("ttft_p50_ms", Json::from(ttft_p50 * 1e3));
        e.set("prefix_hits", Json::from(m.kv.prefix_hits));
        e.set("prefill_tokens_saved", Json::from(m.kv.prefill_tokens_saved));
        e.set("kv_shared_bytes", Json::from(m.kv.shared_bytes));
        e.set("retained_pages", Json::from(m.kv.retained_pages));
        e.set("prefix_evictions", Json::from(m.kv.prefix_evictions));
        px_json.set(mode, e);
        px_ttft.push((share, ttft_p50));
        drop(client);
        server.shutdown();
    }
    if let (Some((_, off)), Some((_, on))) = (
        px_ttft.iter().find(|(s, _)| !*s),
        px_ttft.iter().find(|(s, _)| *s),
    ) {
        // > 1.0 ⇒ skipping shared-prefix prefill cut the median TTFT on
        // the conversational trace (decode tokens are identical either
        // way — the sharing battery proves bit-identity).
        px_json.set("ttft_p50_speedup_shared", Json::from(off / on.max(1e-9)));
    }
    summary.set("prefix_sharing", px_json);

    // ------------------------------------------- lifecycle-tracing overhead
    //
    // The same mixed-format continuous load, closed-loop (no arrival gaps,
    // so the wall is pure serving work), with the trace sink disabled vs
    // enabled. min-of-3 walls each side — tracing fully on must stay within
    // a few percent, and disabled it is a single `Option` check.
    let ov_requests = 24usize;
    let run_mixed = |trace: bool| -> (f64, usize) {
        let mut best = f64::INFINITY;
        let mut events = 0usize;
        for _ in 0..3 {
            let (server, client, _) =
                start_pool_traced(2, GenBatching::Continuous, 8, KvPageCfg::with_page(8), trace);
            for fmt in mix {
                client.score(&rows[0], Some(fmt)).unwrap();
            }
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..ov_requests)
                .map(|i| {
                    client
                        .submit_generate(
                            prompts[i % prompts.len()],
                            cb_tokens,
                            Some(mix[i % mix.len()]),
                            cfg.clone(),
                        )
                        .unwrap()
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
            best = best.min(t0.elapsed().as_secs_f64());
            if let Some(sink) = server.obs().trace() {
                events = events.max(sink.len());
            }
            drop(client);
            server.shutdown();
        }
        (best, events)
    };
    let (wall_off, _) = run_mixed(false);
    let (wall_on, trace_events) = run_mixed(true);
    let overhead_pct = (wall_on - wall_off) / wall_off * 100.0;
    println!(
        "observability: untraced {wall_off:.3}s  traced {wall_on:.3}s  \
         overhead {overhead_pct:+.2}%  ({trace_events} events)"
    );
    let mut ov = Json::obj();
    ov.set("wall_untraced_s", Json::from(wall_off));
    ov.set("wall_traced_s", Json::from(wall_on));
    ov.set("tracing_overhead_pct", Json::from(overhead_pct));
    ov.set("trace_events", Json::from(trace_events));
    summary.set("observability", ov);

    // ----------------------------------- graceful degradation under overload
    //
    // Overload the pool at multiples of its own measured service rate and
    // read what the shed ladder buys: with a bounded ingress queue the
    // server turns excess traffic away (cheap, typed, with a retry hint)
    // and keeps the served-request p99 bounded; without it the backlog —
    // and the tail — grows with the overload. Downshifts (ladder drops
    // precision with depth) and deferrals (backlog waits for a decode row)
    // are the earlier rungs of the same ladder and are reported alongside.
    let deg_requests = 24usize;
    let deg_tokens = 8usize;
    let start_deg = |queue_cap: usize| {
        let dims = bench_dims();
        let (server, client) = Server::start(
            dims.seq_len + 1,
            move || {
                let manifest = dims.to_manifest();
                let params = ParamSet::init(&manifest, 5);
                let ck = params.to_anchor_checkpoint(&manifest, ElementFormat::int(8))?;
                ElasticEngine::native(dims, ck, 256 << 20)
            },
            ServerConfig {
                policy: Policy::default_ladder(),
                gather_window: Duration::from_millis(1),
                workers: 1,
                decode_slots: 2,
                kv_page: KvPageCfg::with_page(8),
                queue_cap,
                ..Default::default()
            },
        )
        .unwrap();
        (server, client)
    };
    let warm_deg = |client: &mfqat::server::Client| {
        for fmt in mix {
            client.score(&rows[0], Some(fmt)).unwrap();
        }
    };
    // Base service rate: one closed-loop burst drained flat out.
    let base_rate = {
        let (server, client) = start_deg(0);
        warm_deg(&client);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..12usize)
            .map(|i| {
                client
                    .submit_generate(prompts[i % prompts.len()], deg_tokens, None, cfg.clone())
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let rate = 12.0 / t0.elapsed().as_secs_f64();
        drop(client);
        server.shutdown();
        rate
    };
    let mut deg_json = Json::obj();
    deg_json.set("base_service_rate_rps", Json::from(base_rate));
    for (mode, queue_cap) in [("shed", 6usize), ("noshed", 0usize)] {
        let mut mode_json = Json::obj();
        for over in [1usize, 2, 4] {
            let (server, client) = start_deg(queue_cap);
            warm_deg(&client);
            let mean_gap_s = 1.0 / (base_rate * over as f64);
            let mut rng = Rng::new(0xDE6 + over as u64);
            let mut rxs = Vec::with_capacity(deg_requests);
            let mut rejected = 0usize;
            for i in 0..deg_requests {
                match client.submit_generate(
                    prompts[i % prompts.len()],
                    deg_tokens,
                    None,
                    cfg.clone(),
                ) {
                    Ok(rx) => rxs.push(rx),
                    Err(_) => rejected += 1, // typed Rejected at the queue boundary
                }
                let gap = -(rng.f64().max(1e-9)).ln() * mean_gap_s;
                std::thread::sleep(Duration::from_secs_f64(gap.min(0.02)));
            }
            let mut lats: Vec<f64> = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().unwrap().latency.as_secs_f64())
                .collect();
            let served = lats.len();
            let p99 = if lats.is_empty() { 0.0 } else { quantiles(&mut lats).1 };
            let m = server.metrics();
            println!(
                "degradation/{mode}/x{over}: served {served}/{deg_requests}  \
                 p99 {:.1}ms  reject {}  downshift {}  defer {}",
                p99 * 1e3,
                m.rejections,
                m.downshifts,
                m.deferrals
            );
            let mut e = Json::obj();
            e.set("p99_ms", Json::from(p99 * 1e3));
            e.set("served", Json::from(served));
            e.set("rejected", Json::from(rejected));
            e.set("rejections", Json::from(m.rejections));
            e.set("downshifts", Json::from(m.downshifts));
            e.set("deferrals", Json::from(m.deferrals));
            mode_json.set(&format!("x{over}"), e);
            drop(client);
            server.shutdown();
        }
        deg_json.set(mode, mode_json);
    }
    summary.set("degradation", deg_json);

    // ------------------------------ raw batched decode (no server) by rows
    let manifest = dims.to_manifest();
    let ck = ParamSet::init(&manifest, 5)
        .to_anchor_checkpoint(&manifest, ElementFormat::int(8))
        .unwrap();
    let w = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(4)).unwrap();
    let mut batch_json = Json::obj();
    let mut tps_by_rows: Vec<(usize, f64)> = Vec::new();
    for rows_n in [1usize, 2, 4, 8] {
        let batch_prompts: Vec<&str> = (0..rows_n)
            .map(|i| prompts[i % prompts.len()])
            .collect();
        // Warm-up then timed runs.
        generate_native_batch(&w, &batch_prompts, gen_tokens, &cfg).unwrap();
        let t0 = Instant::now();
        let iters = 3usize;
        for _ in 0..iters {
            std::hint::black_box(
                generate_native_batch(&w, &batch_prompts, gen_tokens, &cfg).unwrap(),
            );
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = (iters * rows_n * gen_tokens) as f64 / wall;
        println!("batched_decode/rows{rows_n}: {tps:.1} tok/s");
        batch_json.set(&format!("rows{rows_n}"), Json::from(tps));
        tps_by_rows.push((rows_n, tps));
    }
    if let (Some((_, t1)), Some((_, t8))) = (
        tps_by_rows.iter().find(|(r, _)| *r == 1),
        tps_by_rows.iter().find(|(r, _)| *r == 8),
    ) {
        batch_json.set("batch_speedup_8v1", Json::from(t8 / t1));
    }
    summary.set("batched_decode", batch_json);

    // --------------------------- quantized KV pages: budget, memory, NLL
    //
    // Same engine, same 24-token decode, four KV storage formats. Three
    // readings per format: how many worst-case rows a fixed 128 KiB page
    // budget admits (the concurrency a serving pool buys by packing its
    // KV), the peak resident bytes of the decode itself, and the
    // next-token NLL of a fixed sequence — so the fidelity price of the
    // packed codes sits on the record next to the memory win. Acceptance:
    // `mxint8_vs_f32.admit_ratio_vs_f32` >= 3, peak ratios < 1, every
    // `nll_delta_vs_f32` finite.
    let kv_budget_bytes = 128usize << 10;
    let kv_pp = 16usize;
    let w8 = NativeWeights::packed_from_checkpoint(&dims, &ck, ElementFormat::int(8)).unwrap();
    let kv_toks: Vec<i32> = (0..24u64).map(|i| ((i * 31 + 7) % 256) as i32).collect();
    let mut kvq_json = Json::obj();
    kvq_json.set("budget_bytes", Json::from(kv_budget_bytes));
    kvq_json.set("page_positions", Json::from(kv_pp));
    let mut kv_stats: Vec<(&'static str, usize, usize, f64)> = Vec::new();
    for fmt in [KvFormat::F32, KvFormat::MxInt8, KvFormat::MxFp8, KvFormat::MxInt4] {
        let page_bytes = dims.n_layers * kv_pp * fmt.bytes_per_position(dims.d_model);
        let kv = KvPageCfg::with_page(kv_pp).format(fmt);
        // Admission: worst-case rows the byte budget funds, measured by
        // joining rows until the pool itself refuses.
        let budget_pages = kv_budget_bytes / page_bytes;
        let mut gate = KvCache::with_slots_cfg(&dims, 64, kv.budget(budget_pages));
        let mut admitted = 0usize;
        while gate.join_row(RowTag::of(&w8)).is_ok() {
            admitted += 1;
        }
        // Fidelity + residency: one cached decode of the fixed sequence,
        // scoring each next token from the logits the stored KV produced.
        let mut cache = KvCache::with_rows_cfg(&dims, 1, kv);
        let mut logits = forward_cached(&w8, &mut cache, &kv_toks[..1]).unwrap();
        let mut nll = 0.0f64;
        for i in 1..kv_toks.len() {
            let last = &logits[logits.len() - dims.vocab..];
            let max = last.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
            let z: f64 = last.iter().map(|&v| (v as f64 - max).exp()).sum();
            nll += max + z.ln() - last[kv_toks[i] as usize] as f64;
            logits = forward_cached(&w8, &mut cache, &kv_toks[i..i + 1]).unwrap();
        }
        nll /= (kv_toks.len() - 1) as f64;
        let m = cache.kv_memory();
        println!(
            "kv_quant/{}: page {page_bytes} B  admitted {admitted} rows  \
             peak {} B  nll {nll:.4}",
            fmt.name(),
            m.resident_peak_bytes
        );
        let mut e = Json::obj();
        e.set("page_bytes", Json::from(page_bytes));
        e.set("admitted_rows", Json::from(admitted));
        e.set("resident_peak_bytes", Json::from(m.resident_peak_bytes));
        e.set("compression_x", Json::from(m.compression_ratio()));
        e.set("nll", Json::from(nll));
        kvq_json.set(fmt.name(), e);
        kv_stats.push((fmt.name(), admitted, m.resident_peak_bytes, nll));
    }
    if let Some(&(_, f32_rows, f32_peak, f32_nll)) = kv_stats.iter().find(|s| s.0 == "f32") {
        for (name, rows_q, peak_q, nll_q) in kv_stats.iter().filter(|s| s.0 != "f32") {
            let mut d = Json::obj();
            d.set("admit_ratio_vs_f32", Json::from(*rows_q as f64 / f32_rows as f64));
            d.set("peak_ratio_vs_f32", Json::from(*peak_q as f64 / f32_peak as f64));
            d.set("nll_delta_vs_f32", Json::from(nll_q - f32_nll));
            println!(
                "kv_quant/{name}_vs_f32: admit x{:.2}  peak x{:.3}  nll {:+.4}",
                *rows_q as f64 / f32_rows as f64,
                *peak_q as f64 / f32_peak as f64,
                nll_q - f32_nll
            );
            kvq_json.set(&format!("{name}_vs_f32"), d);
        }
    }
    summary.set("kv_quant", kvq_json);

    // ------------------------------------------------------------ summary
    let path = "BENCH_serving.json";
    std::fs::write(path, summary.pretty()).expect("write BENCH_serving.json");
    println!("\nwrote {path}");
}
